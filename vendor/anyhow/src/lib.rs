//! A minimal, dependency-free subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository is fully offline: no crates.io
//! registry is reachable, so the real `anyhow` crate cannot be fetched. This
//! vendored shim implements exactly the surface the workspace uses —
//! [`Error`], [`Result`], the [`Context`] extension trait (for both `Result`
//! and `Option`), and the `anyhow!` / `bail!` / `ensure!` macros — with the
//! same observable formatting semantics:
//!
//! - `{e}` prints the outermost message,
//! - `{e:#}` prints the whole context chain joined by `": "`,
//! - `?` converts any `std::error::Error + Send + Sync + 'static`,
//! - [`Error::new`] preserves the concrete error value so
//!   [`Error::downcast_ref`] can recover it through any number of
//!   `.context(..)` layers (the subset of anyhow's downcasting the
//!   coordinator's error taxonomy relies on).
//!
//! If a cargo registry becomes available, swapping this path dependency for
//! the real crate is a one-line change in `rust/Cargo.toml`.

use std::any::Any;
use std::fmt;

/// An error carrying a chain of context messages (outermost first) and,
/// when built from a concrete error value, that value for downcasting.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

/// `Result<T, anyhow::Error>` — the crate-wide alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Capture a concrete error value, keeping it for [`Error::downcast_ref`].
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        let chain = Error::from_std(&error).chain;
        Error { chain, payload: Some(Box::new(error)) }
    }

    /// Wrap the error in an outer context message (the payload survives).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Capture a standard error, flattening its source chain.
    fn from_std<E: std::error::Error + ?Sized>(error: &E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain, payload: None }
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The concrete error this was built from via [`Error::new`] (or `?` on
    /// a typed error), if it was an `E`. Context layers do not hide it.
    pub fn downcast_ref<E: fmt::Display + fmt::Debug + Send + Sync + 'static>(
        &self,
    ) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that keeps
// the blanket `From` below coherent (the same trick the real crate uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod private {
    /// Sealed conversion used by [`super::Context`] so `.context()` works on
    /// `Result<_, E>` for both std errors and `anyhow::Error` itself.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::new(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error (or `None`) case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("opening config").context("starting up");
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: opening config: file missing");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(parse().unwrap(), 12);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }

    #[test]
    fn context_chains_on_anyhow_error() {
        fn inner() -> Result<()> {
            bail!("root {}", 7);
        }
        fn outer() -> Result<()> {
            inner().context("outer")
        }
        let e = outer().unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 7");
    }

    #[test]
    fn ensure_both_arities() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x > 1);
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert!(check(5).is_ok());
        assert!(format!("{}", check(0).unwrap_err()).contains("Condition failed"));
        assert_eq!(format!("{}", check(12).unwrap_err()), "x too big: 12");
    }

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn downcast_survives_context_and_question_mark() {
        fn inner() -> Result<()> {
            Err(Typed(7))?;
            Ok(())
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: typed error 7");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());

        let e = Error::new(Typed(3)).context("a").context("b");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(3)));
    }

    #[test]
    fn message_errors_downcast_to_nothing() {
        let e = anyhow!("plain {}", 1);
        assert!(e.downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn debug_shows_causes() {
        let e: Error = io_err().into();
        let e = e.context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("file missing"));
    }
}

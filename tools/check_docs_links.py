#!/usr/bin/env python3
"""Verify that relative markdown links in the repo's docs resolve.

CI runs this (``make check-docs``) over ``README.md`` and ``docs/*.md``
so the architecture book cannot accumulate dead cross-references as the
tree moves. Per file it extracts every inline markdown link/image
target, skips what cannot be checked locally, and fails listing each
broken link with its file and line.

Checked:   relative targets (``docs/PROTOCOL.md``, ``../README.md``,
           ``rust/tests/protocol_doc.rs``), with any ``#anchor`` suffix
           stripped before the existence test.
Skipped:   absolute URLs (``http(s)://``, ``mailto:``, any scheme),
           pure in-page anchors (``#section``), and targets that
           resolve outside the repository root — GitHub-web-relative
           links such as the CI badge's ``../../actions/...`` have no
           on-disk counterpart to test.
Ignored:   fenced code blocks, so protocol examples and shell snippets
           cannot produce false link syntax.

Stdlib only — this must run on a bare CI python.

Usage:
  python3 tools/check_docs_links.py [FILE_OR_DIR ...]
  # no arguments: README.md + docs/ relative to the repo root
"""

from __future__ import annotations

import argparse
import os
import re
import sys

# inline links and images: [text](target) / ![alt](target); the target
# ends at the first whitespace (an optional "title" follows) or ')'
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def iter_links(text):
    """Yield ``(line_number, target)`` for every inline link outside
    fenced code blocks."""
    in_fence = False
    for lineno, line in enumerate(text.splitlines(), start=1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def classify(target, md_dir, root):
    """Return ``("skip", reason)`` or ``("check", resolved_path)``."""
    if SCHEME_RE.match(target):
        return "skip", "absolute URL"
    if target.startswith("#"):
        return "skip", "in-page anchor"
    path = target.split("#", 1)[0]
    if not path:
        return "skip", "empty target"
    resolved = os.path.normpath(os.path.join(md_dir, path))
    rel = os.path.relpath(resolved, root)
    if rel.startswith(".."):
        # e.g. the CI badge's GitHub-web-relative ../../actions/... —
        # nothing on disk to verify
        return "skip", "escapes the repository root"
    return "check", resolved


def check_file(md_path, root):
    """Return a list of ``(line_number, target)`` broken links."""
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    broken = []
    md_dir = os.path.dirname(os.path.abspath(md_path))
    for lineno, target in iter_links(text):
        kind, resolved = classify(target, md_dir, root)
        if kind == "check" and not os.path.exists(resolved):
            broken.append((lineno, target))
    return broken


def collect_markdown(paths):
    """Expand files/dirs into a sorted list of markdown files."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            for name in sorted(os.listdir(p)):
                if name.endswith(".md"):
                    out.append(os.path.join(p, name))
        else:
            out.append(p)
    return out


def run(paths, root):
    """Check every file; print findings; return the exit code."""
    files = collect_markdown(paths)
    if not files:
        print("check_docs_links: no markdown files to check", file=sys.stderr)
        return 1
    failures = 0
    for md in files:
        if not os.path.exists(md):
            print(f"check_docs_links: {md}: no such file", file=sys.stderr)
            failures += 1
            continue
        for lineno, target in check_file(md, root):
            print(f"{md}:{lineno}: broken link -> {target}", file=sys.stderr)
            failures += 1
    checked = len(files)
    if failures:
        print(f"check_docs_links: {failures} broken link(s) across {checked} file(s)",
              file=sys.stderr)
        return 1
    print(f"check_docs_links: OK ({checked} file(s))")
    return 0


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "paths",
        nargs="*",
        help="markdown files or directories (default: README.md and docs/)",
    )
    parser.add_argument(
        "--root",
        default=None,
        help="repository root for escape detection (default: this script's parent dir)",
    )
    args = parser.parse_args(argv)
    root = os.path.abspath(
        args.root
        if args.root
        else os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    paths = args.paths or [os.path.join(root, "README.md"), os.path.join(root, "docs")]
    return run(paths, root)


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Bench-trajectory gate: diff a fresh BENCH_preprocess.json against the
committed baseline.

CI regenerates BENCH_preprocess.json on every run (``make
bench-preprocess``) and uploads it as an artifact; this script is the
step in between that actually *reads* the trajectory. It compares every
per-matrix ``*_secs`` timing field (lower is better) present and
non-null in BOTH files, computes the geometric mean of the
current/baseline ratios, and fails the job when that geomean exceeds
the regression threshold (default +25%).

Degenerate states exit 0 by design:
- the committed seed baseline is schema-only (all measurement fields
  null) until the first real-hardware artifact is copied over it;
- a current file produced without a toolchain is equally null.

Stdlib only — this must run on a bare CI python.

Usage:
  python3 tools/bench_compare.py --baseline OLD.json --current NEW.json \
      [--threshold 1.25]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

# timing fields compared per matrix entry (all seconds, lower = better)
SECS_FIELDS = (
    "reorder_hbp_secs",
    "reorder_sort2d_secs",
    "reorder_dp2d_secs",
    "build_serial_secs",
    "build_parallel_secs",
    "build_sort2d_secs",
    "build_dp2d_secs",
)


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def by_id(doc):
    out = {}
    for entry in doc.get("matrices") or []:
        mid = entry.get("id")
        if isinstance(mid, str):
            out[mid] = entry
    return out


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def compare(baseline, current):
    """Return (rows, all_ratios): one row per matrix id present in both
    files, each row (id, n_fields, per-matrix geomean ratio, worst field,
    worst ratio); ratios are current/baseline over comparable fields."""
    base_m, cur_m = by_id(baseline), by_id(current)
    rows, all_ratios = [], []
    for mid in sorted(base_m, key=lambda s: (len(s), s)):
        if mid not in cur_m:
            continue
        ratios = {}
        for field in SECS_FIELDS:
            b, c = base_m[mid].get(field), cur_m[mid].get(field)
            if isinstance(b, (int, float)) and isinstance(c, (int, float)) and b > 0 and c > 0:
                ratios[field] = c / b
        if not ratios:
            continue
        worst_field = max(ratios, key=ratios.get)
        rows.append(
            (mid, len(ratios), geomean(list(ratios.values())), worst_field, ratios[worst_field])
        )
        all_ratios.extend(ratios.values())
    return rows, all_ratios


def render(rows, all_ratios, threshold):
    lines = ["## Preprocessing bench trajectory", ""]
    if not all_ratios:
        lines += [
            "No comparable (non-null) timing fields between baseline and "
            "current run — gate skipped.",
            "",
            "This is expected while the committed `BENCH_preprocess.json` "
            "is still the schema-only seed; copy a real CI artifact over "
            "it to start the trajectory.",
        ]
        return lines, 0
    overall = geomean(all_ratios)
    lines += [
        "| matrix | fields | geomean cur/base | worst field | worst ratio |",
        "|---|---|---|---|---|",
    ]
    for mid, n, g, worst_field, worst in rows:
        lines.append(f"| {mid} | {n} | {g:.3f}x | {worst_field} | {worst:.3f}x |")
    verdict = "REGRESSION" if overall > threshold else "ok"
    lines += [
        "",
        f"**Overall geomean: {overall:.3f}x over {len(all_ratios)} fields "
        f"(threshold {threshold:.2f}x) — {verdict}**",
    ]
    return lines, 1 if overall > threshold else 0


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument("--current", required=True, help="freshly generated JSON")
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.25,
        help="max allowed geomean current/baseline ratio (default 1.25 = +25%%)",
    )
    args = ap.parse_args(argv)

    try:
        baseline = load(args.baseline)
        current = load(args.current)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: cannot read inputs: {e}", file=sys.stderr)
        return 2

    rows, all_ratios = compare(baseline, current)
    lines, status = render(rows, all_ratios, args.threshold)

    text = "\n".join(lines)
    print(text)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a", encoding="utf-8") as f:
            f.write(text + "\n")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Bench-trajectory gate: diff freshly generated bench JSON against the
committed baselines.

CI regenerates ``BENCH_preprocess.json`` (``make bench-preprocess``),
``BENCH_autotune.json`` (``make bench-autotune``) and ``BENCH_spmm.json``
(``make bench-spmm``) on every run and uploads them as artifacts; this
script is the step in between that actually *reads* the trajectory. ``--baseline``/``--current`` may be
repeated to gate several baseline/current pairs in one invocation (the
flags pair up positionally). Per pair it compares every per-matrix
``*_secs`` timing field (lower is better; fields are discovered
dynamically, so any bench schema works) present and non-null in BOTH
files, computes the geometric mean of the current/baseline ratios, and
fails the job when any pair's geomean exceeds the regression threshold
(default +25%).

Degenerate states exit 0 by design:
- a committed seed baseline that is schema-only (all measurement fields
  null) until the first real-hardware artifact is copied over it — but a
  visible WARNING line is emitted (stdout + ``$GITHUB_STEP_SUMMARY``) so
  an un-armed gate can't masquerade as a passing one;
- a current file produced without a toolchain is equally null.

Stdlib only — this must run on a bare CI python.

Usage:
  python3 tools/bench_compare.py --baseline OLD.json --current NEW.json \
      [--baseline OLD2.json --current NEW2.json ...] [--threshold 1.25]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

# The preprocessing bench's timing schema (kept as documentation and for
# schema-aware tooling/tests). Comparison does NOT depend on this list:
# any per-matrix field ending in ``_secs`` is discovered dynamically.
SECS_FIELDS = (
    "reorder_hbp_secs",
    "reorder_sort2d_secs",
    "reorder_dp2d_secs",
    "build_serial_secs",
    "build_parallel_secs",
    "build_sort2d_secs",
    "build_dp2d_secs",
)


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def by_id(doc):
    out = {}
    for entry in doc.get("matrices") or []:
        mid = entry.get("id")
        if isinstance(mid, str):
            out[mid] = entry
    return out


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def secs_fields(*entries):
    """Timing fields present in any of the entries (sorted for
    deterministic output)."""
    fields = set()
    for e in entries:
        fields.update(k for k in e if k.endswith("_secs"))
    return sorted(fields)


def compare(baseline, current):
    """Return (rows, all_ratios): one row per matrix id present in both
    files, each row (id, n_fields, per-matrix geomean ratio, worst field,
    worst ratio); ratios are current/baseline over comparable fields."""
    base_m, cur_m = by_id(baseline), by_id(current)
    rows, all_ratios = [], []
    for mid in sorted(base_m, key=lambda s: (len(s), s)):
        if mid not in cur_m:
            continue
        ratios = {}
        for field in secs_fields(base_m[mid], cur_m[mid]):
            b, c = base_m[mid].get(field), cur_m[mid].get(field)
            if isinstance(b, (int, float)) and isinstance(c, (int, float)) and b > 0 and c > 0:
                ratios[field] = c / b
        if not ratios:
            continue
        worst_field = max(ratios, key=ratios.get)
        rows.append(
            (mid, len(ratios), geomean(list(ratios.values())), worst_field, ratios[worst_field])
        )
        all_ratios.extend(ratios.values())
    return rows, all_ratios


def baseline_armed(doc):
    """Whether the baseline carries any real measurement: at least one
    per-matrix ``*_secs`` field that is a positive number. A schema-only
    seed (every timing field null) is NOT armed — the gate passes
    vacuously until a real artifact is committed over it."""
    for entry in doc.get("matrices") or []:
        for k, v in entry.items():
            if k.endswith("_secs") and isinstance(v, (int, float)) and v > 0:
                return True
    return False


def render(name, rows, all_ratios, threshold, armed=True):
    lines = [f"## Bench trajectory: {name}", ""]
    if not all_ratios:
        if not armed:
            lines += [
                "⚠️ **WARNING: committed baseline is still the all-null "
                "schema-only seed — the regression gate for this bench is "
                "NOT armed.**",
                "",
                "Copy a real CI artifact (the uploaded bench JSON) over the "
                "committed baseline to start the trajectory.",
            ]
        else:
            lines += [
                "No comparable (non-null) timing fields between baseline and "
                "current run — gate skipped.",
                "",
                "The baseline has measurements but the current run produced "
                "none that overlap (toolchain missing, or the schema moved).",
            ]
        return lines, 0
    overall = geomean(all_ratios)
    lines += [
        "| matrix | fields | geomean cur/base | worst field | worst ratio |",
        "|---|---|---|---|---|",
    ]
    for mid, n, g, worst_field, worst in rows:
        lines.append(f"| {mid} | {n} | {g:.3f}x | {worst_field} | {worst:.3f}x |")
    verdict = "REGRESSION" if overall > threshold else "ok"
    lines += [
        "",
        f"**Overall geomean: {overall:.3f}x over {len(all_ratios)} fields "
        f"(threshold {threshold:.2f}x) — {verdict}**",
    ]
    return lines, 1 if overall > threshold else 0


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--baseline",
        action="append",
        required=True,
        help="committed baseline JSON (repeatable; pairs with --current positionally)",
    )
    ap.add_argument(
        "--current",
        action="append",
        required=True,
        help="freshly generated JSON (repeatable; pairs with --baseline positionally)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.25,
        help="max allowed geomean current/baseline ratio (default 1.25 = +25%%)",
    )
    args = ap.parse_args(argv)

    if len(args.baseline) != len(args.current):
        print(
            f"bench_compare: {len(args.baseline)} --baseline vs "
            f"{len(args.current)} --current (must pair up)",
            file=sys.stderr,
        )
        return 2

    status = 0
    sections = []
    for base_path, cur_path in zip(args.baseline, args.current):
        try:
            baseline = load(base_path)
            current = load(cur_path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_compare: cannot read inputs: {e}", file=sys.stderr)
            return 2
        name = current.get("bench") or baseline.get("bench") or os.path.basename(cur_path)
        rows, all_ratios = compare(baseline, current)
        lines, pair_status = render(
            name, rows, all_ratios, args.threshold, armed=baseline_armed(baseline)
        )
        status = max(status, pair_status)
        sections.append("\n".join(lines))

    text = "\n\n".join(sections)
    print(text)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a", encoding="utf-8") as f:
            f.write(text + "\n")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

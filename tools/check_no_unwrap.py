#!/usr/bin/env python3
"""Fail on ``.unwrap()`` in the coordinator's non-test Rust code.

The serving path treats a panic as an outage: every lock acquisition
recovers from poison and every fallible path returns a typed protocol
error (see ``rust/src/coordinator/error.rs``). Clippy already enforces
``clippy::unwrap_used`` for the same tree, but only when a Rust
toolchain is present — this stdlib-only checker keeps the gate cheap,
toolchain-free, and runnable anywhere CI (or a contributor) has python.

Checked:   every ``.unwrap()`` call in ``rust/src/coordinator/*.rs``
           outside test code.
Skipped:   comment lines (``//`` and doc comments) and trailing ``//``
           comments; everything from the first ``#[cfg(test)]`` line to
           the end of the file (the tree keeps its test modules last,
           and tests may unwrap freely).
Not flagged: ``unwrap_or``, ``unwrap_or_else``, ``unwrap_or_default``
           — the pattern requires the exact ``.unwrap()`` call.

Stdlib only — this must run on a bare CI python.

Usage:
  python3 tools/check_no_unwrap.py [FILE_OR_DIR ...]
  # no arguments: rust/src/coordinator/ relative to the repo root
"""

from __future__ import annotations

import argparse
import os
import re
import sys

UNWRAP_RE = re.compile(r"\.unwrap\(\)")
CFG_TEST_RE = re.compile(r"^\s*#\[cfg\(test\)\]")


def strip_comment(line):
    """Drop a trailing ``//`` comment (good enough without a full lexer:
    the tree's string literals do not embed ``//``)."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def iter_violations(text):
    """Yield ``(line_number, stripped_line)`` for each non-test, non-
    comment ``.unwrap()`` call."""
    for lineno, line in enumerate(text.splitlines(), start=1):
        if CFG_TEST_RE.match(line):
            # test modules sit at the end of each file; everything from
            # here on may unwrap freely
            return
        if line.lstrip().startswith("//"):
            continue
        if UNWRAP_RE.search(strip_comment(line)):
            yield lineno, line.strip()


def collect_rust(paths):
    """Expand files/dirs into a sorted list of Rust sources."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            for name in sorted(os.listdir(p)):
                if name.endswith(".rs"):
                    out.append(os.path.join(p, name))
        else:
            out.append(p)
    return out


def run(paths, root):
    """Check every file; print findings; return the exit code."""
    files = collect_rust(paths)
    if not files:
        print("check_no_unwrap: no rust files to check", file=sys.stderr)
        return 1
    failures = 0
    for rs in files:
        if not os.path.exists(rs):
            print(f"check_no_unwrap: {rs}: no such file", file=sys.stderr)
            failures += 1
            continue
        with open(rs, encoding="utf-8") as f:
            text = f.read()
        for lineno, line in iter_violations(text):
            rel = os.path.relpath(rs, root)
            print(f"{rel}:{lineno}: .unwrap() on the serving path -> {line}",
                  file=sys.stderr)
            failures += 1
    checked = len(files)
    if failures:
        print(f"check_no_unwrap: {failures} violation(s) across {checked} file(s)",
              file=sys.stderr)
        return 1
    print(f"check_no_unwrap: OK ({checked} file(s))")
    return 0


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "paths",
        nargs="*",
        help="rust files or directories (default: rust/src/coordinator/)",
    )
    parser.add_argument(
        "--root",
        default=None,
        help="repository root for relative paths (default: this script's parent dir)",
    )
    args = parser.parse_args(argv)
    root = os.path.abspath(
        args.root
        if args.root
        else os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    paths = args.paths or [os.path.join(root, "rust", "src", "coordinator")]
    return run(paths, root)


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Validate Prometheus text exposition produced by the `metrics` op.

The serving coordinator renders its whole metrics surface — counters,
gauges, and cumulative stage histograms, global and per shard — as
Prometheus text exposition (``coordinator::telemetry::prom_text``,
served by the ``metrics`` protocol op and ``hbp stats --format prom``).
A scraper is a machine, so the format is a contract; this stdlib-only
checker enforces the parts of it a drifting emitter is most likely to
break:

- every line is a ``# HELP``/``# TYPE`` comment or a sample
  ``name[{labels}] value`` with legal metric/label names and quoting;
- every sampled family is declared by exactly one ``# TYPE`` (and at
  most one ``# HELP``) *before* its first sample, with a legal type;
- no duplicate series (same name and label set);
- histograms are complete and coherent per label set: ``_bucket``
  series are cumulative (non-decreasing in ``le`` order), terminate in
  ``le="+Inf"``, the ``+Inf`` bucket equals ``_count``, and ``_sum`` /
  ``_count`` are present;
- values parse as floats (``+Inf``/``-Inf``/``NaN`` included).

Stdlib only — this must run on a bare CI python.

Usage:
  python3 tools/check_prom.py FILE         # validate a saved exposition
  ... | python3 tools/check_prom.py        # validate stdin
  python3 tools/check_prom.py --serve BIN  # start BIN serve, send one
                                           # spmv, scrape the metrics
                                           # op, validate the live text
"""

from __future__ import annotations

import argparse
import json
import re
import socket
import subprocess
import sys

METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# one label: name="value" with \\, \" and \n as the only escapes
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\[\\"n])*)"')
TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}
HISTOGRAM_SUFFIXES = ("_bucket", "_sum", "_count")


def parse_value(raw):
    """A sample value: float syntax plus Prometheus' infinity spellings."""
    if raw in ("+Inf", "Inf"):
        return float("inf")
    if raw == "-Inf":
        return float("-inf")
    if raw == "NaN":
        return float("nan")
    return float(raw)  # raises ValueError on garbage


def family_of(name, types):
    """Resolve a sample name to its declared family: histogram series
    carry a suffix, every other family is sampled under its own name."""
    if name in types:
        return name
    for suffix in HISTOGRAM_SUFFIXES:
        base = name[: -len(suffix)] if name.endswith(suffix) else None
        if base and types.get(base) == "histogram":
            return base
    return None


def parse_labels(raw, lineno, errors):
    """``key="value",...`` → sorted tuple of pairs (the series key)."""
    out = []
    pos = 0
    while pos < len(raw):
        m = LABEL_RE.match(raw, pos)
        if not m:
            errors.append(f"line {lineno}: bad label syntax at {raw[pos:]!r}")
            return tuple(out)
        if not LABEL_NAME_RE.match(m.group(1)):
            errors.append(f"line {lineno}: bad label name {m.group(1)!r}")
        out.append((m.group(1), m.group(2)))
        pos = m.end()
        if pos < len(raw):
            if raw[pos] != ",":
                errors.append(f"line {lineno}: expected ',' between labels: {raw!r}")
                return tuple(out)
            pos += 1
    return tuple(sorted(out))


def validate(text):
    """Return a list of violation strings (empty = valid exposition)."""
    errors = []
    helps = {}   # family -> lineno of its HELP
    types = {}   # family -> declared type
    series = {}  # (name, labels) -> (lineno, value)
    order = []   # sample order, for bucket monotonicity

    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) < 3 or parts[0] != "#" or parts[1] not in ("HELP", "TYPE"):
                errors.append(f"line {lineno}: malformed comment {line!r}")
                continue
            kind, name = parts[1], parts[2]
            if not METRIC_NAME_RE.match(name):
                errors.append(f"line {lineno}: bad metric name {name!r}")
                continue
            if kind == "HELP":
                if name in helps:
                    errors.append(f"line {lineno}: duplicate HELP for {name}")
                helps[name] = lineno
            else:
                declared = parts[3] if len(parts) > 3 else ""
                if declared not in TYPES:
                    errors.append(f"line {lineno}: unknown type {declared!r} for {name}")
                if name in types:
                    errors.append(f"line {lineno}: duplicate TYPE for {name}")
                types[name] = declared
            continue

        # a sample: name[{labels}] value
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})? (\S+)$", line)
        if not m:
            errors.append(f"line {lineno}: unparseable sample {line!r}")
            continue
        name, labels_raw, value_raw = m.group(1), m.group(3), m.group(4)
        labels = parse_labels(labels_raw, lineno, errors) if labels_raw else ()
        try:
            value = parse_value(value_raw)
        except ValueError:
            errors.append(f"line {lineno}: bad sample value {value_raw!r}")
            continue
        fam = family_of(name, types)
        if fam is None:
            errors.append(f"line {lineno}: sample {name} has no preceding TYPE")
            continue
        key = (name, labels)
        if key in series:
            errors.append(
                f"line {lineno}: duplicate series {name}{dict(labels)} "
                f"(first at line {series[key][0]})"
            )
            continue
        series[key] = (lineno, value)
        order.append((name, labels, value))

    errors.extend(check_histograms(types, series, order))
    return errors


def check_histograms(types, series, order):
    """Per histogram family and label set: cumulative buckets ending in
    an ``+Inf`` that equals ``_count``, with ``_sum`` present."""
    errors = []
    for fam, declared in types.items():
        if declared != "histogram":
            continue
        # group buckets by their non-`le` labels, preserving text order
        groups = {}
        for name, labels, value in order:
            if name != fam + "_bucket":
                continue
            le = dict(labels).get("le")
            if le is None:
                errors.append(f"{fam}: bucket series without an le label")
                continue
            rest = tuple(kv for kv in labels if kv[0] != "le")
            groups.setdefault(rest, []).append((le, value))
        if not groups:
            errors.append(f"{fam}: declared histogram but no _bucket series")
        for rest, buckets in groups.items():
            where = f"{fam}{dict(rest)}"
            try:
                bounds = [parse_value(le) for le, _ in buckets]
            except ValueError:
                errors.append(f"{where}: unparseable le bound")
                continue
            if bounds != sorted(bounds):
                errors.append(f"{where}: buckets not in increasing le order")
            counts = [v for _, v in buckets]
            if any(prev > nxt for prev, nxt in zip(counts, counts[1:])):
                errors.append(f"{where}: bucket counts decrease (not cumulative)")
            if buckets[-1][0] != "+Inf":
                errors.append(f"{where}: bucket run must end with le=\"+Inf\"")
                continue
            count = series.get((fam + "_count", rest))
            if count is None:
                errors.append(f"{where}: no _count series")
            elif count[1] != buckets[-1][1]:
                errors.append(
                    f"{where}: +Inf bucket {buckets[-1][1]} != _count {count[1]}"
                )
            if (fam + "_sum", rest) not in series:
                errors.append(f"{where}: no _sum series")
    return errors


def scrape_live(binary):
    """Start ``binary serve`` on an ephemeral port, push one request
    through it, and return the `metrics` op's exposition text."""
    proc = subprocess.Popen(
        [binary, "serve", "--addr", "127.0.0.1:0", "--no-cache",
         "--scale", "ci", "--matrices", "m1"],
        stdout=subprocess.DEVNULL,
        stderr=subprocess.PIPE,
        text=True,
    )
    try:
        addr = None
        for line in proc.stderr:
            if line.startswith("hbp-spmv serving on "):
                addr = line.split()[-1]
                break
        if addr is None:
            raise RuntimeError("server exited before announcing its address")
        host, port = addr.rsplit(":", 1)
        with socket.create_connection((host, int(port)), timeout=30) as sock:
            f = sock.makefile("rw", encoding="utf-8", newline="\n")
            # one real request so the histograms carry samples
            f.write('{"op":"list"}\n')
            f.flush()
            cols = json.loads(f.readline())["matrices"][0]["cols"]
            f.write(json.dumps({"op": "spmv", "matrix": "m1", "x": [1.0] * cols}))
            f.write("\n")
            f.flush()
            if not json.loads(f.readline()).get("ok"):
                raise RuntimeError("spmv against the live server failed")
            f.write('{"op":"metrics"}\n')
            f.flush()
            reply = json.loads(f.readline())
        if not reply.get("ok"):
            raise RuntimeError(f"metrics op failed: {reply}")
        return reply["prom"]
    finally:
        proc.kill()
        proc.wait()


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("file", nargs="?", help="exposition text (default: stdin)")
    parser.add_argument(
        "--serve",
        metavar="BIN",
        help="start BIN serve, scrape the metrics op, validate the live text",
    )
    args = parser.parse_args(argv)

    if args.serve:
        text = scrape_live(args.serve)
        what = f"live metrics op of {args.serve}"
    elif args.file:
        with open(args.file, encoding="utf-8") as f:
            text = f.read()
        what = args.file
    else:
        text = sys.stdin.read()
        what = "stdin"

    errors = validate(text)
    if errors:
        for e in errors:
            print(f"check_prom: {e}", file=sys.stderr)
        print(f"check_prom: {len(errors)} violation(s) in {what}", file=sys.stderr)
        return 1
    n_series = sum(1 for l in text.splitlines() if l and not l.startswith("#"))
    print(f"check_prom: OK ({what}: {n_series} series)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

//! End-to-end driver (the EXPERIMENTS.md §E2E run): proves all three
//! layers compose on a real workload.
//!
//! 1. Generate Table-I matrices, preprocess into HBP (L3 preprocessing).
//! 2. Open the AOT artifact store and run the **PJRT path**: the L1
//!    Pallas kernel (lowered by `make artifacts`) executes every block,
//!    rust scatters + combines — verified against the pure-rust engine.
//! 3. Start the serving coordinator (router + batcher + TCP), fire a
//!    batched closed-loop workload from concurrent clients, and report
//!    latency percentiles + throughput.
//!
//! ```text
//! make artifacts && cargo run --release --offline --example e2e_serve
//! ```

use hbp_spmv::coordinator::server::{serve_background, Client};
use hbp_spmv::coordinator::{BatcherConfig, Coordinator, Router};
use hbp_spmv::gen::{matrix_by_id, Scale};
use hbp_spmv::partition::PartitionConfig;
use hbp_spmv::preprocess::{build_hbp_parallel, HashReorder};
use hbp_spmv::runtime::{artifacts_dir, ArtifactStore, PjrtSpmv};
use hbp_spmv::util::cli::Args;
use hbp_spmv::util::stats::percentile;
use hbp_spmv::util::timer::fmt_duration;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1, &[]);
    let scale = Scale::parse(args.str_or("scale", "ci")).expect("bad --scale");
    let threads = std::thread::available_parallelism()?.get();
    let clients = args.usize_or("clients", 8);
    let requests_per_client = args.usize_or("requests", 25);

    println!("=== e2e: three-layer HBP SpMV serving ===\n");

    // ---- phase 1: PJRT path (L1 kernel through the runtime) ----
    let (meta, m) = matrix_by_id("m1", scale).unwrap();
    println!(
        "[1] matrix {} ({}): {}x{}, {} nnz",
        meta.id, meta.name, m.rows, m.cols, m.nnz()
    );
    let cfg = PartitionConfig::default();
    let hbp = build_hbp_parallel(&m, cfg, &HashReorder::default(), threads);
    println!("    preprocessed into {} blocks", hbp.blocks.len());

    let store = ArtifactStore::open(artifacts_dir())?;
    println!(
        "    artifact store: platform={}, {} executables, L buckets {:?}",
        store.platform(),
        store.execs.len(),
        store.spmv_l_buckets()
    );
    let pjrt = PjrtSpmv::prepare(&store, &hbp)?;
    let x = hbp_spmv::gen::random::vector(m.cols, 11);
    let mut y_pjrt = vec![0.0; m.rows];
    let t = hbp_spmv::util::Timer::start();
    pjrt.spmv(&x, &mut y_pjrt)?;
    let pjrt_secs = t.elapsed_secs();
    println!(
        "    PJRT SpMV over {} blocks ({} over-bucket fallbacks): {}",
        pjrt.num_blocks(),
        pjrt.fallback_blocks,
        fmt_duration(pjrt_secs)
    );

    let mut y_ref = vec![0.0; m.rows];
    m.spmv(&x, &mut y_ref);
    // f32 kernel vs f64 reference: tolerance scaled accordingly
    let max_rel = y_pjrt
        .iter()
        .zip(&y_ref)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
        .fold(0.0f64, f64::max);
    println!("    max rel error vs f64 CSR: {max_rel:.2e}");
    anyhow::ensure!(max_rel < 1e-3, "PJRT path diverged");
    println!("    L1 (pallas kernel) -> L3 (rust combine) verified ✓\n");

    // ---- phase 2: serving coordinator under load ----
    let mut router = Router::new(cfg, threads);
    for id in ["m1", "m3", "m9"] {
        let (meta, m) = matrix_by_id(id, scale).unwrap();
        router.register(meta.id, m)?;
        let p = router.get(meta.id)?;
        println!(
            "[2] registered {} ({}): preprocess {}",
            meta.id,
            meta.name,
            fmt_duration(p.preprocess_secs)
        );
    }
    let dims: Vec<(String, usize)> = router
        .names()
        .iter()
        .map(|n| (n.to_string(), router.get(n).unwrap().cols))
        .collect();
    let coordinator = Arc::new(Coordinator::new(router, BatcherConfig::default()));
    let addr = serve_background(coordinator.clone())?;
    println!("    serving on {addr}\n");

    // closed-loop clients over TCP
    let t = hbp_spmv::util::Timer::start();
    let latencies: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let dims = dims.clone();
                s.spawn(move || {
                    let mut client = Client::connect(addr).expect("connect");
                    let mut lats = Vec::with_capacity(requests_per_client);
                    for i in 0..requests_per_client {
                        let (name, cols) = &dims[(c + i) % dims.len()];
                        let x = hbp_spmv::gen::random::vector(*cols, (c * 1000 + i) as u64);
                        let t = hbp_spmv::util::Timer::start();
                        let y = client.spmv(name, &x).expect("spmv");
                        lats.push(t.elapsed_secs());
                        assert!(!y.is_empty());
                    }
                    lats
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t.elapsed_secs();

    let total = latencies.len();
    println!("[3] {total} requests from {clients} clients in {}", fmt_duration(wall));
    println!("    throughput: {:.1} req/s", total as f64 / wall);
    println!(
        "    latency p50 {}  p95 {}  p99 {}",
        fmt_duration(percentile(&latencies, 50.0)),
        fmt_duration(percentile(&latencies, 95.0)),
        fmt_duration(percentile(&latencies, 99.0)),
    );
    let snap = coordinator.metrics.snapshot();
    println!(
        "    server-side: {} ok, {} errors, {:.3} GFLOPS sustained",
        snap.requests, snap.errors, snap.gflops
    );
    anyhow::ensure!(snap.errors == 0, "server reported errors");
    anyhow::ensure!(snap.requests as usize == total);
    println!("\nall layers compose: artifacts -> PJRT -> engines -> batcher -> TCP ✓");
    Ok(())
}

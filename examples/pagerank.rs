//! PageRank on a Kronecker graph — the graph-processing workload the
//! paper's introduction motivates (power iteration = repeated SpMV, so
//! the preprocessing cost amortizes and the SpMV speedup compounds).
//!
//! ```text
//! cargo run --release --offline --example pagerank [-- --scale small]
//! ```

use hbp_spmv::exec::{CsrParallel, HbpEngine};
use hbp_spmv::gen::{matrix_by_id, Scale};
use hbp_spmv::partition::PartitionConfig;
use hbp_spmv::preprocess::{build_hbp_parallel, HashReorder};
use hbp_spmv::solvers::{pagerank, power::column_stochastic};
use hbp_spmv::util::cli::Args;
use hbp_spmv::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1, &[]);
    let scale = Scale::parse(args.str_or("scale", "ci")).expect("bad --scale");
    let threads = std::thread::available_parallelism()?.get();

    // kron_g500-logn18 profile (m4): the paper's flagship scattered matrix
    let (meta, adj) = matrix_by_id("m4", scale).unwrap();
    let m = column_stochastic(&adj);
    println!(
        "PageRank on {} ({}x{}, {} nnz)\n",
        meta.name,
        m.rows,
        m.cols,
        m.nnz(),
    );

    let cfg = PartitionConfig::default();
    let hbp = build_hbp_parallel(&m, cfg, &HashReorder::default(), threads);
    let hbp_engine = HbpEngine::new(hbp, threads, 0.25);
    let csr_engine = CsrParallel::new(m.clone(), threads);

    let (rank_hbp, s_hbp) = pagerank(&hbp_engine, 0.85, 1e-10, 200);
    let (rank_csr, s_csr) = pagerank(&csr_engine, 0.85, 1e-10, 200);
    assert!(s_hbp.converged && s_csr.converged);

    println!(
        "hbp: {} iters, spmv {}  ",
        s_hbp.iterations,
        fmt_duration(s_hbp.spmv_secs)
    );
    println!(
        "csr: {} iters, spmv {}  ",
        s_csr.iterations,
        fmt_duration(s_csr.spmv_secs)
    );
    println!("spmv speedup: {:.2}x", s_csr.spmv_secs / s_hbp.spmv_secs);

    // results must agree between engines
    assert!(
        hbp_spmv::formats::dense::allclose(&rank_hbp, &rank_csr, 1e-8, 1e-12),
        "engines disagree on PageRank"
    );

    // top-5 ranked vertices
    let mut idx: Vec<usize> = (0..rank_hbp.len()).collect();
    idx.sort_by(|&a, &b| rank_hbp[b].partial_cmp(&rank_hbp[a]).unwrap());
    println!("\ntop vertices:");
    for &i in idx.iter().take(5) {
        println!("  v{i:<8} rank {:.6}", rank_hbp[i]);
    }
    Ok(())
}

//! Quickstart: build a matrix, preprocess it into HBP, run SpMV, verify.
//!
//! ```text
//! cargo run --release --offline --example quickstart
//! ```

use hbp_spmv::exec::{CsrParallel, HbpEngine, SpmvEngine};
use hbp_spmv::gen::{matrix_by_id, Scale};
use hbp_spmv::partition::PartitionConfig;
use hbp_spmv::preprocess::build_hbp_parallel;
use hbp_spmv::preprocess::HashReorder;
use hbp_spmv::util::timer::{fmt_duration, time};

fn main() -> anyhow::Result<()> {
    let threads = std::thread::available_parallelism()?.get();

    // 1. A Table-I matrix (ASIC_680k profile) at CI scale.
    let (meta, m) = matrix_by_id("m2", Scale::Ci).expect("suite id");
    println!(
        "matrix {} ({}): {}x{}, {} nnz",
        meta.id,
        meta.name,
        m.rows,
        m.cols,
        m.nnz()
    );

    // 2. Preprocess: 2D partition + nonlinear-hash reorder (the paper's
    //    cheap alternative to sorting / dynamic programming).
    let cfg = PartitionConfig::default(); // N=512 rows, M=4096 cols, omega=32
    let (hbp, prep) = time(|| build_hbp_parallel(&m, cfg, &HashReorder::default(), threads));
    println!(
        "preprocessed into {} blocks in {} ({} bytes)",
        hbp.blocks.len(),
        fmt_duration(prep),
        hbp.storage_bytes()
    );

    // 3. SpMV through the HBP engine (mixed fixed/competitive schedule).
    let x = hbp_spmv::gen::random::vector(m.cols, 7);
    let engine = HbpEngine::new(hbp, threads, 0.25);
    let mut y = vec![0.0; m.rows];
    let phases = engine.spmv_phases(&x, &mut y);
    println!(
        "hbp spmv: {} (spmv {} + combine {}) = {:.3} GFLOPS",
        fmt_duration(phases.total()),
        fmt_duration(phases.spmv),
        fmt_duration(phases.combine),
        engine.gflops(phases.total())
    );

    // 4. Verify against the CSR baseline.
    let csr = CsrParallel::new(m.clone(), threads);
    let mut expect = vec![0.0; m.rows];
    let csr_phases = csr.spmv_phases(&x, &mut expect);
    println!(
        "csr spmv: {} = {:.3} GFLOPS",
        fmt_duration(csr_phases.total()),
        csr.gflops(csr_phases.total())
    );
    assert!(
        hbp_spmv::formats::dense::allclose(&y, &expect, 1e-9, 1e-11),
        "HBP result diverged from CSR"
    );
    println!("verified: HBP == CSR ✓");
    Ok(())
}

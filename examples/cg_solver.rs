//! Conjugate-gradient solve of a sparse SPD system — the "mathematical
//! solutions for sparse linear equations" workload from the paper's
//! introduction. The FEM-stencil matrix (barrier2-3 profile) is the
//! paper's CSR-friendly case, so this example also demonstrates honest
//! engine selection: HBP does not always win (see Fig. 8 discussion).
//!
//! ```text
//! cargo run --release --offline --example cg_solver
//! ```

use hbp_spmv::exec::{CsrParallel, HbpEngine, SpmvEngine};
use hbp_spmv::formats::{Coo, Csr};
use hbp_spmv::gen::{matrix_by_id, Scale};
use hbp_spmv::partition::PartitionConfig;
use hbp_spmv::preprocess::{build_hbp_parallel, HashReorder};
use hbp_spmv::util::cli::Args;
use hbp_spmv::util::timer::fmt_duration;

/// Make an SPD system from a generator matrix: A = M^T M + I (classic
/// normal-equations trick; keeps the sparsity structure family).
fn spd_from(m: &Csr) -> Csr {
    // B = M^T M is expensive for big matrices; use A = (M + M^T)/2 + c*I
    // with c chosen to dominate the row sums => diagonally dominant SPD.
    let t = m.transpose();
    let mut coo = Coo::new(m.rows, m.cols);
    for r in 0..m.rows {
        let (cols, vals) = m.row(r);
        for (c, v) in cols.iter().zip(vals) {
            coo.push(r, *c as usize, 0.5 * v);
        }
        let (tcols, tvals) = t.row(r);
        for (c, v) in tcols.iter().zip(tvals) {
            coo.push(r, *c as usize, 0.5 * v);
        }
    }
    coo.normalize();
    // diagonal dominance
    let sym = coo.to_csr();
    let mut coo2 = sym.to_coo();
    for r in 0..sym.rows {
        let (_, vals) = sym.row(r);
        let rowsum: f64 = vals.iter().map(|v| v.abs()).sum();
        coo2.push(r, r, rowsum + 1.0);
    }
    coo2.to_csr()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1, &[]);
    let scale = Scale::parse(args.str_or("scale", "ci")).expect("bad --scale");
    let threads = std::thread::available_parallelism()?.get();

    let (meta, gen_m) = matrix_by_id("m3", scale).unwrap(); // barrier2-3 profile
    let a = spd_from(&gen_m);
    println!(
        "CG on SPD system from {} profile: {}x{}, {} nnz\n",
        meta.name,
        a.rows,
        a.cols,
        a.nnz()
    );

    // right-hand side with known solution x* = 1
    let ones = vec![1.0; a.cols];
    let mut b = vec![0.0; a.rows];
    a.spmv(&ones, &mut b);

    let cfg = PartitionConfig::default();
    let hbp = build_hbp_parallel(&a, cfg, &HashReorder::default(), threads);
    let engines: Vec<Box<dyn SpmvEngine>> = vec![
        Box::new(HbpEngine::new(hbp, threads, 0.25)),
        Box::new(CsrParallel::new(a.clone(), threads)),
    ];

    for e in &engines {
        let mut x = vec![0.0; a.rows];
        let stats = hbp_spmv::solvers::cg(e.as_ref(), &b, &mut x, 1e-10, 500);
        let err = x
            .iter()
            .map(|v| (v - 1.0).abs())
            .fold(0.0f64, f64::max);
        println!(
            "{:4}: {} iters, residual {:.2e}, max|x-1| {:.2e}, spmv time {}",
            e.name(),
            stats.iterations,
            stats.residual,
            err,
            fmt_duration(stats.spmv_secs)
        );
        assert!(err < 1e-6, "CG did not converge to the known solution");
    }
    println!("\nboth engines converge to x* = 1 ✓");
    Ok(())
}

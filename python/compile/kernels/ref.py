"""Pure-jnp oracles for the L1 kernels.

The correctness contract: every Pallas kernel must match its oracle to
float32 tolerance on arbitrary shapes/values (pytest + hypothesis sweeps
in ``python/tests/test_kernel.py``). The oracles are deliberately written
in the most obvious form — no tiling, no grids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["block_spmv_ref", "combine_ref", "dense_spmv_ref"]


def block_spmv_ref(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """Oracle for ``hbp_spmv.block_spmv``: ``out[g, w] = sum_k
    vals[g, k, w] * x[cols[g, k, w]]``."""
    return jnp.sum(vals * x[cols], axis=1)


def combine_ref(parts: jax.Array) -> jax.Array:
    """Oracle for ``hbp_spmv.combine``: sum partials over the block axis."""
    return jnp.sum(parts, axis=0)


def dense_spmv_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """Dense ground truth for model-level tests."""
    return a @ x

"""L1 Pallas kernel: group-ELL block SpMV.

The TPU re-expression of the paper's HBP warp kernel (DESIGN.md
"Hardware adaptation"): a CUDA warp walking ``add_sign`` chains becomes a
dense ``(L, W)`` tile per group — row ``k`` of the tile holds the ``k``-th
nonzero of every lane's row (HBP's round-major order), zero-padded to the
group's bucketed max length ``L``. The nonlinear hash keeps the lanes of a
group near-equal in length, which directly bounds the tile padding and
hence VMEM traffic and FLOPs.

BlockSpec schedule (the HBM<->VMEM plan that CUDA expressed with
threadblocks + shared memory):

- grid over groups ``g``;
- ``cols``/``vals``: one ``(1, L, W)`` tile per step — streamed;
- ``x``: the block's full column segment ``(S,)`` pinned in VMEM for every
  step — the shared-memory vector segment of the paper (S = 4096 doubles
  there; f32 here);
- out: one ``(1, W)`` tile per step (per-slot sums; the rust combine step
  applies ``output_hash`` and reduces over column blocks).

VMEM per step = L*W*(4+4) + S*4 + W*4 bytes; at the default
(L=256, W=32, S=4096) that is ~80 KiB — far under the ~16 MiB/core VMEM
budget, leaving room for multi-way double buffering of the streamed tiles.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated on CPU and the real-TPU roofline is
estimated analytically in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_spmv", "combine", "KernelSpec"]


class KernelSpec:
    """Shape bucket of one AOT-compiled executable."""

    def __init__(self, groups: int, lmax: int, warp: int, seg: int):
        self.groups = groups  # G: warp-groups in the (batched) block
        self.lmax = lmax      # L: padded lane length bucket
        self.warp = warp      # W: lanes per group (omega)
        self.seg = seg        # S: x-segment length (cols_per_block)

    def name(self) -> str:
        return f"spmv_g{self.groups}_l{self.lmax}_w{self.warp}_s{self.seg}"

    def vmem_bytes_per_step(self) -> int:
        """VMEM footprint of one grid step (cols+vals tiles, x segment,
        out tile) — the L1 profiling quantity in EXPERIMENTS.md §Perf."""
        return self.lmax * self.warp * (4 + 4) + self.seg * 4 + self.warp * 4

    def flops_per_step(self) -> int:
        return 2 * self.lmax * self.warp


def _kernel(cols_ref, vals_ref, x_ref, o_ref):
    """One group: gather the x segment at each lane's columns and reduce
    down the L axis. All operands are VMEM-resident tiles."""
    cols = cols_ref[0]            # [L, W] i32, block-local columns
    vals = vals_ref[0]            # [L, W] f32, 0 in padding slots
    x = x_ref[...]                # [S]    f32, the block's vector segment
    # padding slots have vals == 0, so their gathered garbage is nulled
    o_ref[0, :] = jnp.sum(vals * x[cols], axis=0)


def block_spmv(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """Group-ELL block SpMV.

    Args:
      cols: ``i32[G, L, W]`` block-local column indices (0 where padded).
      vals: ``f32[G, L, W]`` values (0 where padded).
      x:    ``f32[S]`` the block's vector segment.

    Returns:
      ``f32[G, W]`` per-slot sums (execution order; the caller scatters
      through ``output_hash``).
    """
    g, lmax, warp = cols.shape
    seg = x.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, lmax, warp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lmax, warp), lambda i: (i, 0, 0)),
            pl.BlockSpec((seg,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, warp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, warp), jnp.float32),
        interpret=True,
    )(cols, vals, x)


def _combine_kernel(parts_ref, o_ref):
    """Reduce partial vectors over the block axis for one row tile."""
    o_ref[...] = jnp.sum(parts_ref[...], axis=0)


def combine(parts: jax.Array, tile: int = 512) -> jax.Array:
    """Combine phase: sum ``f32[K, R]`` partial vectors into ``f32[R]``.

    Grid over row tiles of ``tile`` elements; each step reduces a
    ``(K, tile)`` VMEM block. R must be a multiple of ``tile`` (the rust
    exporter pads row blocks).
    """
    k, r = parts.shape
    assert r % tile == 0, f"R={r} not a multiple of tile={tile}"
    return pl.pallas_call(
        _combine_kernel,
        grid=(r // tile,),
        in_specs=[pl.BlockSpec((k, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(parts)


@functools.lru_cache(maxsize=None)
def jitted_block_spmv(groups: int, lmax: int, warp: int, seg: int):
    """A jitted block_spmv for a fixed shape bucket (test convenience)."""
    spec = (
        jax.ShapeDtypeStruct((groups, lmax, warp), jnp.int32),
        jax.ShapeDtypeStruct((groups, lmax, warp), jnp.float32),
        jax.ShapeDtypeStruct((seg,), jnp.float32),
    )
    return jax.jit(block_spmv).lower(*spec).compile()

"""L2 JAX model: the blocked-SpMV compute graph.

Composes the L1 Pallas kernels into the paper's two-step SpMV (Fig. 1):
block SpMV over every (row-block, col-block) tile, slot->row scatter via
``output_hash``, then the combine reduction across column blocks. Lowered
once by ``aot.py``; Python never runs on the request path.

Two entry points:

- :func:`block_spmv` — the per-block kernel (re-exported from L1). The
  rust runtime dispatches *this* per block/batch; combine happens in rust
  where the block list is dynamic.
- :func:`row_block_spmv` — a fixed-shape composition (NB column blocks of
  one row block: kernels + scatter + combine *in-graph*). This is the
  whole-graph artifact proving L1/L2 compose, used by the e2e example.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import hbp_spmv
from compile.kernels.hbp_spmv import block_spmv

__all__ = ["block_spmv", "row_block_spmv", "batched_block_spmv"]


def batched_block_spmv(cols: jax.Array, vals: jax.Array, xsegs: jax.Array) -> jax.Array:
    """SpMV over a batch of NB same-bucket blocks in one kernel launch.

    The batch is folded into the grid axis: ``[NB, G, L, W] -> [NB*G, L,
    W]`` and the per-block x segments are concatenated; column indices
    must already be offset by ``b * S`` (the rust exporter does this).

    Args:
      cols:  ``i32[NB, G, L, W]`` with the ``b*S`` offset pre-applied.
      vals:  ``f32[NB, G, L, W]``.
      xsegs: ``f32[NB, S]``.

    Returns:
      ``f32[NB, G, W]`` per-slot sums.
    """
    nb, g, lmax, warp = cols.shape
    out = block_spmv(
        cols.reshape(nb * g, lmax, warp),
        vals.reshape(nb * g, lmax, warp),
        xsegs.reshape(-1),
    )
    return out.reshape(nb, g, warp)


def row_block_spmv(
    cols: jax.Array,
    vals: jax.Array,
    xsegs: jax.Array,
    inv_perm: jax.Array,
) -> jax.Array:
    """One row block, NB column blocks, fully in-graph.

    Per column block: block kernel -> scatter slot sums to pre-hash rows
    (``inv_perm`` = ``output_hash``) -> stack partials -> combine kernel.

    Args:
      cols:     ``i32[NB, G, L, W]`` block-local columns.
      vals:     ``f32[NB, G, L, W]``.
      xsegs:    ``f32[NB, S]`` one segment per column block.
      inv_perm: ``i32[NB, G*W]`` slot -> original local row.

    Returns:
      ``f32[G*W]`` the row block's output rows.
    """
    nb, g, lmax, warp = cols.shape
    rows = g * warp

    def one(b):
        slot_sums = block_spmv(cols[b], vals[b], xsegs[b]).reshape(rows)
        # scatter: partial[orig_row] = slot_sums[slot]
        return jnp.zeros(rows, jnp.float32).at[inv_perm[b]].set(slot_sums)

    parts = jnp.stack([one(b) for b in range(nb)])  # [NB, rows]
    return hbp_spmv.combine(parts, tile=min(512, rows))

"""AOT lowering: JAX (L2+L1) -> HLO text -> ``artifacts/``.

Run once by ``make artifacts``; the rust runtime
(``rust/src/runtime/``) loads the HLO text via
``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU
client. Python never runs on the request path.

Interchange is HLO **text**, not a serialized ``HloModuleProto``: jax >=
0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids (see /opt/xla-example/README.md).

Emitted executables (shape buckets; see ``manifest.json``):

- ``spmv_g{G}_l{L}_w{W}_s{S}`` — the L1 block kernel, one per L bucket
  and batch size (batch NB folds into G: G' = NB*G, S' = NB*S).
- ``combine_k{K}_r{R}`` — the combine reduction.
- ``row_block_nb{NB}_...`` — the in-graph L2 composition for the e2e
  example.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402
from compile.kernels import hbp_spmv  # noqa: E402

# Default partition config mirrors rust PartitionConfig::default():
# rows_per_block=512, cols_per_block=4096, warp=32 -> G=16, S=4096.
GROUPS = 16
WARP = 32
SEG = 4096
L_BUCKETS = (4, 8, 16, 32, 64, 128, 256)
BATCHES = (1, 8)
COMBINE_K = 8
ROW_BLOCK_NB = 4
ROW_BLOCK_L = 32


def to_hlo_text(fn, *specs) -> str:
    """Lower a jittable fn at the given ShapeDtypeStructs to HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spmv_entry(groups: int, lmax: int, warp: int, seg: int) -> dict:
    spec = hbp_spmv.KernelSpec(groups, lmax, warp, seg)
    text = to_hlo_text(
        hbp_spmv.block_spmv,
        jax.ShapeDtypeStruct((groups, lmax, warp), jnp.int32),
        jax.ShapeDtypeStruct((groups, lmax, warp), jnp.float32),
        jax.ShapeDtypeStruct((seg,), jnp.float32),
    )
    return {
        "name": spec.name(),
        "kind": "spmv",
        "groups": groups,
        "lmax": lmax,
        "warp": warp,
        "seg": seg,
        "vmem_bytes_per_step": spec.vmem_bytes_per_step(),
        "text": text,
    }


def combine_entry(k: int, rows: int) -> dict:
    text = to_hlo_text(
        lambda p: hbp_spmv.combine(p, tile=min(512, rows)),
        jax.ShapeDtypeStruct((k, rows), jnp.float32),
    )
    return {"name": f"combine_k{k}_r{rows}", "kind": "combine", "k": k, "rows": rows, "text": text}


def row_block_entry(nb: int, groups: int, lmax: int, warp: int, seg: int) -> dict:
    text = to_hlo_text(
        model.row_block_spmv,
        jax.ShapeDtypeStruct((nb, groups, lmax, warp), jnp.int32),
        jax.ShapeDtypeStruct((nb, groups, lmax, warp), jnp.float32),
        jax.ShapeDtypeStruct((nb, seg), jnp.float32),
        jax.ShapeDtypeStruct((nb, groups * warp), jnp.int32),
    )
    return {
        "name": f"row_block_nb{nb}_g{groups}_l{lmax}_w{warp}_s{seg}",
        "kind": "row_block",
        "nb": nb,
        "groups": groups,
        "lmax": lmax,
        "warp": warp,
        "seg": seg,
        "text": text,
    }


def build(out_dir: str, l_buckets=L_BUCKETS, batches=BATCHES) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for lmax in l_buckets:
        for nb in batches:
            # batch folds into the grid: G' = NB*G, S' = NB*S
            entries.append(spmv_entry(GROUPS * nb, lmax, WARP, SEG * nb))
    entries.append(combine_entry(COMBINE_K, 512))
    entries.append(row_block_entry(ROW_BLOCK_NB, GROUPS, ROW_BLOCK_L, WARP, SEG))

    manifest = {"groups": GROUPS, "warp": WARP, "seg": SEG, "executables": []}
    for e in entries:
        text = e.pop("text")
        fname = e["name"] + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        e["file"] = fname
        e["sha256"] = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["executables"].append(e)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--quick", action="store_true", help="small bucket set for tests")
    args = ap.parse_args()
    l_buckets = (4, 16) if args.quick else L_BUCKETS
    batches = (1,) if args.quick else BATCHES
    manifest = build(args.out, l_buckets, batches)
    n = len(manifest["executables"])
    print(f"wrote {n} HLO executables + manifest.json to {args.out}")


if __name__ == "__main__":
    main()

"""L1 kernel correctness: Pallas (interpret) vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; assert_allclose at f32 tolerance.
This is the core correctness signal for the compute layer.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.kernels import hbp_spmv, ref  # noqa: E402

RNG = np.random.default_rng(0)


def make_block(g, lmax, w, s, density=0.7, seed=0):
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, s, size=(g, lmax, w)).astype(np.int32)
    vals = rng.standard_normal((g, lmax, w)).astype(np.float32)
    # zero-pad a fraction of slots like a real group-ELL export
    mask = rng.random((g, lmax, w)) < density
    vals = np.where(mask, vals, 0.0).astype(np.float32)
    x = rng.standard_normal(s).astype(np.float32)
    return jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)


class TestBlockSpmv:
    def test_basic_shape(self):
        cols, vals, x = make_block(2, 4, 8, 16)
        out = hbp_spmv.block_spmv(cols, vals, x)
        assert out.shape == (2, 8)
        np.testing.assert_allclose(
            out, ref.block_spmv_ref(cols, vals, x), rtol=1e-5, atol=1e-5
        )

    def test_default_bucket_shape(self):
        # the shape the AOT path ships: G=16, W=32, S=4096
        cols, vals, x = make_block(16, 32, 32, 4096, seed=3)
        out = hbp_spmv.block_spmv(cols, vals, x)
        np.testing.assert_allclose(
            out, ref.block_spmv_ref(cols, vals, x), rtol=1e-4, atol=1e-4
        )

    def test_all_padding_is_zero(self):
        cols, vals, x = make_block(2, 8, 4, 32, density=0.0, seed=1)
        out = hbp_spmv.block_spmv(cols, vals, x)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((2, 4), np.float32))

    def test_single_group_single_lane(self):
        cols = jnp.zeros((1, 4, 1), jnp.int32)
        vals = jnp.ones((1, 4, 1), jnp.float32)
        x = jnp.array([2.5], jnp.float32)
        out = hbp_spmv.block_spmv(cols, vals, x)
        np.testing.assert_allclose(out, [[10.0]], rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        g=st.integers(1, 6),
        lmax=st.integers(1, 24),
        w=st.integers(1, 16),
        s=st.sampled_from([8, 64, 333, 1024]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, g, lmax, w, s, seed):
        cols, vals, x = make_block(g, lmax, w, s, seed=seed)
        out = hbp_spmv.block_spmv(cols, vals, x)
        np.testing.assert_allclose(
            out, ref.block_spmv_ref(cols, vals, x), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(scale=st.sampled_from([1e-6, 1.0, 1e6]), seed=st.integers(0, 1000))
    def test_value_scales(self, scale, seed):
        cols, vals, x = make_block(2, 8, 8, 64, seed=seed)
        vals = vals * scale
        out = hbp_spmv.block_spmv(cols, vals, x)
        np.testing.assert_allclose(
            out, ref.block_spmv_ref(cols, vals, x), rtol=1e-4, atol=1e-4 * scale
        )

    def test_duplicate_columns_accumulate(self):
        # two entries of the same lane hitting the same column
        cols = jnp.array([[[3], [3], [0], [0]]], jnp.int32)  # [1,4,1]
        vals = jnp.array([[[1.0], [2.0], [0.0], [0.0]]], jnp.float32)
        x = jnp.array([9.0, 0.0, 0.0, 4.0], jnp.float32)
        out = hbp_spmv.block_spmv(cols, vals, x)
        np.testing.assert_allclose(out, [[12.0]], rtol=1e-6)


class TestCombine:
    def test_matches_ref(self):
        parts = jnp.asarray(RNG.standard_normal((8, 512)).astype(np.float32))
        out = hbp_spmv.combine(parts)
        np.testing.assert_allclose(out, ref.combine_ref(parts), rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        k=st.integers(1, 12),
        tiles=st.integers(1, 4),
        tile=st.sampled_from([8, 64, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, k, tiles, tile, seed):
        rng = np.random.default_rng(seed)
        parts = jnp.asarray(rng.standard_normal((k, tiles * tile)).astype(np.float32))
        out = hbp_spmv.combine(parts, tile=tile)
        np.testing.assert_allclose(out, ref.combine_ref(parts), rtol=1e-4, atol=1e-5)

    def test_rejects_misaligned(self):
        parts = jnp.zeros((2, 100), jnp.float32)
        with pytest.raises(AssertionError):
            hbp_spmv.combine(parts, tile=512)


class TestKernelSpec:
    def test_vmem_accounting(self):
        spec = hbp_spmv.KernelSpec(16, 256, 32, 4096)
        # 256*32*8 + 4096*4 + 32*4 = 65536 + 16384 + 128
        assert spec.vmem_bytes_per_step() == 82048
        assert spec.vmem_bytes_per_step() < 16 * 2**20, "must fit VMEM"
        assert spec.flops_per_step() == 2 * 256 * 32

    def test_name_stable(self):
        assert hbp_spmv.KernelSpec(16, 64, 32, 4096).name() == "spmv_g16_l64_w32_s4096"

    def test_jitted_cache(self):
        a = hbp_spmv.jitted_block_spmv(1, 4, 4, 8)
        b = hbp_spmv.jitted_block_spmv(1, 4, 4, 8)
        assert a is b

"""AOT pipeline tests: HLO text emission + manifest integrity."""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import aot  # noqa: E402


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), l_buckets=(4, 16), batches=(1,))
    return out, manifest


class TestAotBuild:
    def test_manifest_lists_all_files(self, built):
        out, manifest = built
        assert manifest["groups"] == aot.GROUPS
        assert manifest["warp"] == aot.WARP
        assert manifest["seg"] == aot.SEG
        for e in manifest["executables"]:
            path = out / e["file"]
            assert path.exists(), e["file"]
            assert path.stat().st_size > 100

    def test_hlo_is_text_not_proto(self, built):
        out, manifest = built
        for e in manifest["executables"]:
            head = (out / e["file"]).read_text()[:200]
            assert "HloModule" in head, f"{e['file']} is not HLO text"

    def test_expected_bucket_set(self, built):
        _, manifest = built
        names = {e["name"] for e in manifest["executables"]}
        assert "spmv_g16_l4_w32_s4096" in names
        assert "spmv_g16_l16_w32_s4096" in names
        assert any(n.startswith("combine_") for n in names)
        assert any(n.startswith("row_block_") for n in names)

    def test_manifest_json_roundtrip(self, built):
        out, manifest = built
        on_disk = json.loads((out / "manifest.json").read_text())
        assert on_disk == manifest

    def test_spmv_entries_record_vmem(self, built):
        _, manifest = built
        for e in manifest["executables"]:
            if e["kind"] == "spmv":
                assert e["vmem_bytes_per_step"] > 0
                assert e["vmem_bytes_per_step"] < 16 * 2**20

"""Shared pytest configuration for the L1/L2 suite.

- Registers hypothesis profiles: ``ci`` (small example counts, no
  deadlines — keeps the kernel sweep under a few minutes on CPU jax) and
  ``dev`` (the default counts). Select with ``HYPOTHESIS_PROFILE=ci``.
- When hypothesis is not installed (the offline dev image ships without
  it), the property-based test modules are skipped at collection time so
  the deterministic tests still run.
- Makes ``compile`` importable regardless of the pytest invocation CWD.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# Modules that import hypothesis at module scope.
_HYPOTHESIS_MODULES = ["test_kernel.py", "test_model.py", "test_ref.py"]

try:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=10, deadline=None)
    settings.register_profile("dev", deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
    collect_ignore = []
except ImportError:
    collect_ignore = list(_HYPOTHESIS_MODULES)

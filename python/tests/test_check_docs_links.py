"""The docs link checker (tools/check_docs_links.py): pure-stdlib
module, tested deterministically — no jax/hypothesis involvement."""

import importlib.util
import os

_TOOL = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "tools",
    "check_docs_links.py",
)
_spec = importlib.util.spec_from_file_location("check_docs_links", _TOOL)
check_docs_links = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs_links)

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return str(p)


def test_good_relative_links_pass(tmp_path):
    _write(tmp_path, "docs/OTHER.md", "# other\n")
    md = _write(tmp_path, "docs/INDEX.md", "[other](OTHER.md) and [up](../README.md)\n")
    _write(tmp_path, "README.md", "# readme\n")
    assert check_docs_links.run([md], str(tmp_path)) == 0


def test_broken_link_fails_with_location(tmp_path, capsys):
    md = _write(tmp_path, "docs/INDEX.md", "line one\n[ghost](MISSING.md)\n")
    assert check_docs_links.run([md], str(tmp_path)) == 1
    err = capsys.readouterr().err
    assert "INDEX.md:2" in err
    assert "MISSING.md" in err


def test_anchors_urls_and_root_escapes_are_skipped(tmp_path):
    md = _write(
        tmp_path,
        "docs/INDEX.md",
        "\n".join(
            [
                "[web](https://example.com/x)",
                "[mail](mailto:a@b.c)",
                "[anchor](#section)",
                "[badge](../../actions/workflows/ci.yml)",  # escapes root
                "[real](OTHER.md#some-heading)",  # anchor stripped, file checked
            ]
        ),
    )
    _write(tmp_path, "docs/OTHER.md", "# ok\n")
    assert check_docs_links.run([md], str(tmp_path)) == 0


def test_anchor_stripping_still_detects_missing_files(tmp_path):
    md = _write(tmp_path, "docs/INDEX.md", "[x](GONE.md#anchor)\n")
    assert check_docs_links.run([md], str(tmp_path)) == 1


def test_code_fences_are_ignored(tmp_path):
    md = _write(
        tmp_path,
        "docs/INDEX.md",
        "```sh\n[not a link](NOPE.md)\n```\nreal text\n",
    )
    assert check_docs_links.run([md], str(tmp_path)) == 0


def test_directory_argument_expands_to_markdown_files(tmp_path):
    _write(tmp_path, "docs/A.md", "[b](B.md)\n")
    _write(tmp_path, "docs/B.md", "[bad](NOWHERE.md)\n")
    assert check_docs_links.run([str(tmp_path / "docs")], str(tmp_path)) == 1


def test_missing_input_file_fails(tmp_path):
    assert check_docs_links.run([str(tmp_path / "ABSENT.md")], str(tmp_path)) == 1


def test_image_links_are_checked(tmp_path):
    md = _write(tmp_path, "docs/INDEX.md", "![fig](fig.png)\n")
    assert check_docs_links.run([md], str(tmp_path)) == 1
    _write(tmp_path, "docs/fig.png", "png-bytes")
    assert check_docs_links.run([md], str(tmp_path)) == 0


def test_the_real_repo_docs_are_clean():
    """The committed README + docs/ must pass their own gate."""
    paths = [os.path.join(_REPO, "README.md"), os.path.join(_REPO, "docs")]
    assert check_docs_links.run(paths, _REPO) == 0

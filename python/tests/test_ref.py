"""Oracle sanity: the pure-jnp references against plain numpy loops.

The Pallas kernels are checked against ``ref.py``; this file closes the
loop by checking ``ref.py`` against straight-line numpy — so a bug in the
oracle cannot silently validate a matching bug in the kernel.
"""

import os
import sys

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.kernels import ref  # noqa: E402


def numpy_block_spmv(cols, vals, x):
    g, lmax, w = cols.shape
    out = np.zeros((g, w), np.float64)
    for gi in range(g):
        for k in range(lmax):
            for wi in range(w):
                out[gi, wi] += float(vals[gi, k, wi]) * float(x[cols[gi, k, wi]])
    return out


class TestOracles:
    @settings(max_examples=15, deadline=None)
    @given(
        g=st.integers(1, 3),
        lmax=st.integers(1, 6),
        w=st.integers(1, 5),
        s=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_block_spmv_ref_vs_numpy(self, g, lmax, w, s, seed):
        rng = np.random.default_rng(seed)
        cols = rng.integers(0, s, size=(g, lmax, w)).astype(np.int32)
        vals = rng.standard_normal((g, lmax, w)).astype(np.float32)
        x = rng.standard_normal(s).astype(np.float32)
        got = ref.block_spmv_ref(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
        expect = numpy_block_spmv(cols, vals, x)
        np.testing.assert_allclose(np.asarray(got), expect, rtol=1e-4, atol=1e-4)

    def test_combine_ref_vs_numpy(self):
        rng = np.random.default_rng(3)
        parts = rng.standard_normal((5, 100)).astype(np.float32)
        got = ref.combine_ref(jnp.asarray(parts))
        np.testing.assert_allclose(np.asarray(got), parts.sum(axis=0), rtol=1e-5, atol=1e-6)

    def test_dense_ref(self):
        a = jnp.asarray(np.eye(4, dtype=np.float32) * 2.0)
        x = jnp.asarray(np.arange(4, dtype=np.float32))
        np.testing.assert_allclose(ref.dense_spmv_ref(a, x), [0.0, 2.0, 4.0, 6.0])

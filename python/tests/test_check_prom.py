"""The Prometheus exposition gate (tools/check_prom.py): pure-stdlib
module, tested deterministically — no jax/hypothesis involvement.

The live-scrape path (``--serve``) needs the built ``hbp`` binary and
is exercised by ``make check-prom`` in CI; these tests pin down the
validator itself with hand-built fixtures, one per grammar rule.
"""

import importlib.util
import os

_TOOL = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "tools",
    "check_prom.py",
)
_spec = importlib.util.spec_from_file_location("check_prom", _TOOL)
check_prom = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_prom)


def _histogram(name, labels="", buckets=((0.001, 3), (0.1, 5)), total=5, sum_=0.07):
    """A complete, coherent histogram family in exposition text."""
    sel = "{" + labels + ",le=\"%s\"}" if labels else "{le=\"%s\"}"
    plain = "{" + labels + "}" if labels else ""
    lines = [
        f"# HELP {name} test histogram",
        f"# TYPE {name} histogram",
    ]
    for bound, count in buckets:
        lines.append(f"{name}_bucket{sel % bound} {count}")
    lines.append(f"{name}_bucket{sel % '+Inf'} {total}")
    lines.append(f"{name}_sum{plain} {sum_}")
    lines.append(f"{name}_count{plain} {total}")
    return lines


VALID = "\n".join(
    [
        "# HELP hbp_requests_total answered requests",
        "# TYPE hbp_requests_total counter",
        "hbp_requests_total 5",
        "# HELP hbp_queue_depth queued requests",
        "# TYPE hbp_queue_depth gauge",
        "hbp_queue_depth 0",
        "# HELP hbp_shard_requests_total per-shard answered requests",
        "# TYPE hbp_shard_requests_total counter",
        'hbp_shard_requests_total{shard="0"} 3',
        'hbp_shard_requests_total{shard="1"} 2',
        *_histogram("hbp_request_latency_seconds"),
        *_histogram("hbp_shard_execute_seconds", labels='shard="0"'),
    ]
) + "\n"


def test_valid_exposition_passes():
    assert check_prom.validate(VALID) == []


def test_main_validates_a_file(tmp_path, capsys):
    p = tmp_path / "metrics.prom"
    p.write_text(VALID)
    assert check_prom.main([str(p)]) == 0
    assert "OK" in capsys.readouterr().out


def test_sample_without_type_declaration_fails():
    errors = check_prom.validate("hbp_mystery_total 5\n")
    assert any("no preceding TYPE" in e for e in errors)


def test_non_cumulative_buckets_fail():
    text = VALID.replace(
        'hbp_request_latency_seconds_bucket{le="0.1"} 5',
        'hbp_request_latency_seconds_bucket{le="0.1"} 2',
    )
    errors = check_prom.validate(text)
    assert any("not cumulative" in e for e in errors)


def test_missing_inf_bucket_fails():
    text = "\n".join(
        [
            "# TYPE h histogram",
            'h_bucket{le="1"} 2',
            "h_sum 0.5",
            "h_count 2",
        ]
    )
    errors = check_prom.validate(text)
    assert any('le="+Inf"' in e for e in errors)


def test_inf_bucket_disagreeing_with_count_fails():
    text = VALID.replace("hbp_request_latency_seconds_count 5",
                         "hbp_request_latency_seconds_count 9")
    errors = check_prom.validate(text)
    assert any("+Inf bucket" in e and "_count" in e for e in errors)


def test_missing_sum_fails():
    text = VALID.replace("hbp_request_latency_seconds_sum 0.07\n", "")
    errors = check_prom.validate(text)
    assert any("no _sum" in e for e in errors)


def test_duplicate_series_fails():
    text = VALID + "hbp_requests_total 6\n"
    errors = check_prom.validate(text)
    assert any("duplicate series" in e for e in errors)


def test_bad_label_syntax_fails():
    text = "\n".join(
        [
            "# TYPE h counter",
            "h{shard=0} 1",  # unquoted label value
        ]
    )
    errors = check_prom.validate(text)
    assert any("bad label syntax" in e for e in errors)


def test_bad_value_fails():
    errors = check_prom.validate("# TYPE h counter\nh one\n")
    assert any("bad sample value" in e for e in errors)


def test_inf_and_nan_values_parse():
    text = "\n".join(
        [
            "# TYPE g gauge",
            "g NaN",
            "# TYPE f gauge",
            "f +Inf",
        ]
    )
    assert check_prom.validate(text) == []


def test_histograms_grouped_per_label_set():
    # shard 0 coherent, shard 1 has +Inf != count: only shard 1 flagged
    lines = [
        "# TYPE h histogram",
        'h_bucket{shard="0",le="1"} 2',
        'h_bucket{shard="0",le="+Inf"} 2',
        'h_sum{shard="0"} 0.1',
        'h_count{shard="0"} 2',
        'h_bucket{shard="1",le="1"} 1',
        'h_bucket{shard="1",le="+Inf"} 1',
        'h_sum{shard="1"} 0.2',
        'h_count{shard="1"} 7',
    ]
    errors = check_prom.validate("\n".join(lines))
    assert len(errors) == 1
    assert "shard" in errors[0] and "1" in errors[0]

"""The CI bench-trajectory gate (tools/bench_compare.py): pure-stdlib
module, tested deterministically — no jax/hypothesis involvement."""

import importlib.util
import json
import os
import sys

_TOOL = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "tools",
    "bench_compare.py",
)
_spec = importlib.util.spec_from_file_location("bench_compare", _TOOL)
bench_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_compare)


def _doc(entries, bench="preprocess"):
    return {"bench": bench, "scale": "ci", "matrices": entries}


def _entry(mid, **secs):
    e = {"id": mid, "rows": 10, "cols": 10, "nnz": 20}
    for f in bench_compare.SECS_FIELDS:
        e[f] = secs.get(f)
    return e


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def _run(tmp_path, baseline, current, threshold=None, summary=None, monkeypatch=None):
    argv = [
        "--baseline",
        _write(tmp_path, "base.json", baseline),
        "--current",
        _write(tmp_path, "cur.json", current),
    ]
    if threshold is not None:
        argv += ["--threshold", str(threshold)]
    if monkeypatch is not None:
        if summary is not None:
            monkeypatch.setenv("GITHUB_STEP_SUMMARY", summary)
        else:
            monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    return bench_compare.main(argv)


def test_all_null_seed_baseline_passes(tmp_path, monkeypatch):
    baseline = _doc([_entry("m1"), _entry("m2")])  # schema-only seed
    current = _doc([_entry("m1", build_serial_secs=0.5)])
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 0


def test_all_null_seed_baseline_warns_visibly(tmp_path, monkeypatch, capsys):
    # a schema-only seed passes, but loudly: the section must carry an
    # explicit not-armed WARNING on stdout AND in the step summary
    baseline = _doc([_entry("m1"), _entry("m2")])
    current = _doc([_entry("m1", build_serial_secs=0.5)])
    summary = tmp_path / "summary.md"
    assert (
        _run(tmp_path, baseline, current, summary=str(summary), monkeypatch=monkeypatch) == 0
    )
    out = capsys.readouterr().out
    assert "WARNING" in out and "NOT armed" in out
    text = summary.read_text()
    assert "WARNING" in text and "NOT armed" in text


def test_armed_baseline_with_no_overlap_does_not_warn_not_armed(tmp_path, monkeypatch, capsys):
    # baseline HAS measurements; the current run just produced none that
    # overlap — this is the generic skip, not the seed warning
    baseline = _doc([_entry("m1", build_serial_secs=1.0)])
    current = _doc([_entry("m1")])
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 0
    out = capsys.readouterr().out
    assert "NOT armed" not in out
    assert "gate skipped" in out


def test_baseline_armed_helper():
    assert not bench_compare.baseline_armed(_doc([_entry("m1"), _entry("m2")]))
    assert bench_compare.baseline_armed(_doc([_entry("m1", build_serial_secs=0.1)]))
    # zero or negative timings don't arm (a 0.0 baseline can't gate ratios)
    assert not bench_compare.baseline_armed(_doc([_entry("m1", build_serial_secs=0.0)]))
    assert not bench_compare.baseline_armed({})


def test_armed_pair_with_comparison_emits_no_warning(tmp_path, monkeypatch, capsys):
    baseline = _doc([_entry("m1", build_serial_secs=1.0)])
    current = _doc([_entry("m1", build_serial_secs=1.1)])
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 0
    out = capsys.readouterr().out
    assert "WARNING" not in out
    assert "Overall geomean" in out


def test_within_threshold_passes(tmp_path, monkeypatch):
    baseline = _doc([_entry("m1", build_serial_secs=1.0, reorder_hbp_secs=0.1)])
    current = _doc([_entry("m1", build_serial_secs=1.2, reorder_hbp_secs=0.11)])
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 0


def test_large_regression_fails(tmp_path, monkeypatch):
    baseline = _doc([_entry("m1", build_serial_secs=1.0), _entry("m2", build_serial_secs=1.0)])
    current = _doc([_entry("m1", build_serial_secs=2.0), _entry("m2", build_serial_secs=2.0)])
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 1


def test_speedup_passes_and_threshold_is_configurable(tmp_path, monkeypatch):
    baseline = _doc([_entry("m1", build_serial_secs=2.0)])
    current = _doc([_entry("m1", build_serial_secs=1.0)])
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 0
    # a tight custom threshold turns a mild slowdown into a failure
    baseline = _doc([_entry("m1", build_serial_secs=1.0)])
    current = _doc([_entry("m1", build_serial_secs=1.1)])
    assert _run(tmp_path, baseline, current, threshold=1.05, monkeypatch=monkeypatch) == 1


def test_step_summary_written(tmp_path, monkeypatch):
    baseline = _doc([_entry("m1", build_serial_secs=1.0)])
    current = _doc([_entry("m1", build_serial_secs=1.0)])
    summary = tmp_path / "summary.md"
    assert (
        _run(tmp_path, baseline, current, summary=str(summary), monkeypatch=monkeypatch) == 0
    )
    text = summary.read_text()
    assert "Bench trajectory: preprocess" in text
    assert "| m1 |" in text


def test_unreadable_input_is_a_distinct_error(tmp_path, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    assert (
        bench_compare.main(
            ["--baseline", str(tmp_path / "missing.json"), "--current", str(tmp_path / "m2.json")]
        )
        == 2
    )


def test_geomean_matches_hand_computation():
    import math

    rows, ratios = bench_compare.compare(
        _doc([_entry("m1", build_serial_secs=1.0, build_parallel_secs=4.0)]),
        _doc([_entry("m1", build_serial_secs=2.0, build_parallel_secs=2.0)]),
    )
    assert sorted(ratios) == [0.5, 2.0]
    assert abs(bench_compare.geomean(ratios) - 1.0) < 1e-12
    (mid, n, g, worst_field, worst) = rows[0]
    assert mid == "m1" and n == 2
    assert worst_field == "build_serial_secs" and abs(worst - 2.0) < 1e-12
    assert abs(g - 1.0) < 1e-12


def test_null_fields_are_skipped_not_zero():
    _, ratios = bench_compare.compare(
        _doc([_entry("m1", build_serial_secs=1.0, reorder_hbp_secs=None)]),
        _doc([_entry("m1", build_serial_secs=1.0, reorder_hbp_secs=0.5)]),
    )
    assert ratios == [1.0]


def _autotune_entry(mid, **fields):
    e = {
        "id": mid,
        "rows": 10,
        "cols": 10,
        "nnz": 20,
        "winner_engine": None,
        "trial_hbp_secs": None,
        "trial_csr_secs": None,
        "trial_2d_secs": None,
        "trial_flat_secs": None,
        "trial_line_secs": None,
        "tune_secs": None,
    }
    e.update(fields)
    return e


def test_timing_fields_are_discovered_dynamically():
    # the autotune schema shares no field names with SECS_FIELDS, yet
    # its *_secs fields are compared; non-secs fields are ignored
    _, ratios = bench_compare.compare(
        _doc(
            [_autotune_entry("m1", trial_hbp_secs=1.0, tune_secs=4.0, winner_engine="hbp")],
            bench="autotune",
        ),
        _doc(
            [_autotune_entry("m1", trial_hbp_secs=2.0, tune_secs=2.0, winner_engine="csr")],
            bench="autotune",
        ),
    )
    assert sorted(ratios) == [0.5, 2.0]


def test_csr_native_trial_fields_pass_through_the_gate(tmp_path, monkeypatch):
    # the CSR-native engine timings added to the autotune schema are
    # picked up by the dynamic *_secs discovery: they compare when
    # present on both sides, and a large regression in one of them
    # fails the gate with that field named as the worst offender
    rows, ratios = bench_compare.compare(
        _doc(
            [_autotune_entry("m1", trial_flat_secs=1.0, trial_line_secs=2.0)],
            bench="autotune",
        ),
        _doc(
            [_autotune_entry("m1", trial_flat_secs=1.0, trial_line_secs=1.0)],
            bench="autotune",
        ),
    )
    assert sorted(ratios) == [0.5, 1.0]
    baseline = _doc([_autotune_entry("m1", trial_flat_secs=1.0)], bench="autotune")
    current = _doc([_autotune_entry("m1", trial_flat_secs=9.0)], bench="autotune")
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 1
    (_, _, _, worst_field, _) = bench_compare.compare(baseline, current)[0][0]
    assert worst_field == "trial_flat_secs"


def test_all_null_autotune_seed_passes(tmp_path, monkeypatch):
    baseline = _doc([_autotune_entry("m1"), _autotune_entry("m2")], bench="autotune")
    current = _doc([_autotune_entry("m1", trial_hbp_secs=0.5)], bench="autotune")
    assert _run(tmp_path, baseline, current, monkeypatch=monkeypatch) == 0


def test_multi_pair_invocation_gates_each_pair(tmp_path, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    ok_base = _write(tmp_path, "ok_base.json", _doc([_entry("m1", build_serial_secs=1.0)]))
    ok_cur = _write(tmp_path, "ok_cur.json", _doc([_entry("m1", build_serial_secs=1.0)]))
    bad_base = _write(
        tmp_path,
        "bad_base.json",
        _doc([_autotune_entry("m1", trial_hbp_secs=1.0)], bench="autotune"),
    )
    bad_cur = _write(
        tmp_path,
        "bad_cur.json",
        _doc([_autotune_entry("m1", trial_hbp_secs=9.0)], bench="autotune"),
    )
    # both pairs fine
    assert (
        bench_compare.main(
            ["--baseline", ok_base, "--current", ok_cur, "--baseline", bad_base, "--current", bad_base]
        )
        == 0
    )
    # one regressing pair fails the whole invocation
    assert (
        bench_compare.main(
            ["--baseline", ok_base, "--current", ok_cur, "--baseline", bad_base, "--current", bad_cur]
        )
        == 1
    )


def test_mismatched_pair_counts_are_a_usage_error(tmp_path, monkeypatch):
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)
    a = _write(tmp_path, "a.json", _doc([_entry("m1")]))
    b = _write(tmp_path, "b.json", _doc([_entry("m1")]))
    assert bench_compare.main(["--baseline", a, "--baseline", b, "--current", a]) == 2


def test_multi_pair_summary_has_one_section_per_bench(tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    pre_base = _write(tmp_path, "p_base.json", _doc([_entry("m1", build_serial_secs=1.0)]))
    pre_cur = _write(tmp_path, "p_cur.json", _doc([_entry("m1", build_serial_secs=1.0)]))
    at_base = _write(
        tmp_path,
        "a_base.json",
        _doc([_autotune_entry("m1", tune_secs=1.0)], bench="autotune"),
    )
    at_cur = _write(
        tmp_path,
        "a_cur.json",
        _doc([_autotune_entry("m1", tune_secs=1.0)], bench="autotune"),
    )
    assert (
        bench_compare.main(
            [
                "--baseline", pre_base, "--current", pre_cur,
                "--baseline", at_base, "--current", at_cur,
            ]
        )
        == 0
    )
    text = summary.read_text()
    assert "Bench trajectory: preprocess" in text
    assert "Bench trajectory: autotune" in text

"""The serving-path unwrap gate (tools/check_no_unwrap.py): pure-stdlib
module, tested deterministically — no jax/hypothesis involvement."""

import importlib.util
import os

_TOOL = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "tools",
    "check_no_unwrap.py",
)
_spec = importlib.util.spec_from_file_location("check_no_unwrap", _TOOL)
check_no_unwrap = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_no_unwrap)

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _write(tmp_path, name, text):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return str(p)


def test_bare_unwrap_fails_with_location(tmp_path, capsys):
    rs = _write(
        tmp_path,
        "src/bad.rs",
        'fn f() {\n    let x = maybe().unwrap();\n    use_it(x);\n}\n',
    )
    assert check_no_unwrap.run([rs], str(tmp_path)) == 1
    err = capsys.readouterr().err
    assert "bad.rs:2" in err
    assert ".unwrap()" in err


def test_recovering_variants_pass(tmp_path):
    rs = _write(
        tmp_path,
        "src/good.rs",
        "\n".join(
            [
                "fn f() {",
                "    let a = lock().unwrap_or_else(|e| e.into_inner());",
                "    let b = opt.unwrap_or(0);",
                "    let c = opt.unwrap_or_default();",
                "}",
            ]
        ),
    )
    assert check_no_unwrap.run([rs], str(tmp_path)) == 0


def test_comments_do_not_trip_the_gate(tmp_path):
    rs = _write(
        tmp_path,
        "src/commented.rs",
        "\n".join(
            [
                "// the old code called .unwrap() here",
                "/// doc: never .unwrap() on the serving path",
                "fn f() { g(); } // was g().unwrap()",
            ]
        ),
    )
    assert check_no_unwrap.run([rs], str(tmp_path)) == 0


def test_test_modules_may_unwrap(tmp_path):
    rs = _write(
        tmp_path,
        "src/tested.rs",
        "\n".join(
            [
                "fn f() -> Option<u32> { None }",
                "#[cfg(test)]",
                "mod tests {",
                "    #[test]",
                "    fn t() { assert_eq!(super::f().unwrap(), 1); }",
                "}",
            ]
        ),
    )
    assert check_no_unwrap.run([rs], str(tmp_path)) == 0


def test_unwrap_before_test_module_still_fails(tmp_path):
    rs = _write(
        tmp_path,
        "src/mixed.rs",
        "\n".join(
            [
                "fn f() { g().unwrap(); }",
                "#[cfg(test)]",
                "mod tests {}",
            ]
        ),
    )
    assert check_no_unwrap.run([rs], str(tmp_path)) == 1


def test_directory_argument_expands_to_rust_files(tmp_path):
    _write(tmp_path, "src/a.rs", "fn a() {}\n")
    _write(tmp_path, "src/b.rs", "fn b() { c().unwrap(); }\n")
    assert check_no_unwrap.run([str(tmp_path / "src")], str(tmp_path)) == 1


def test_missing_input_file_fails(tmp_path):
    assert check_no_unwrap.run([str(tmp_path / "ABSENT.rs")], str(tmp_path)) == 1


def test_the_real_coordinator_is_clean():
    """The committed coordinator tree must pass its own gate."""
    paths = [os.path.join(_REPO, "rust", "src", "coordinator")]
    assert check_no_unwrap.run(paths, _REPO) == 0

"""L2 model tests: the in-graph composition (kernels + scatter + combine)
against a dense reference built from the same block tensors."""

import os
import sys

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402


def make_row_block(nb, g, lmax, w, s, seed):
    """Random group-ELL tensors for one row block + the dense equivalent."""
    rng = np.random.default_rng(seed)
    rows = g * w
    cols = rng.integers(0, s, size=(nb, g, lmax, w)).astype(np.int32)
    vals = rng.standard_normal((nb, g, lmax, w)).astype(np.float32)
    mask = rng.random((nb, g, lmax, w)) < 0.5
    vals = np.where(mask, vals, 0.0).astype(np.float32)
    xsegs = rng.standard_normal((nb, s)).astype(np.float32)
    # a random slot->row permutation per column block
    inv_perm = np.stack([rng.permutation(rows) for _ in range(nb)]).astype(np.int32)

    # dense reference: accumulate every (slot, k) entry into its row
    y = np.zeros(rows, np.float64)
    for b in range(nb):
        for gi in range(g):
            for wi in range(w):
                slot = gi * w + wi
                row = inv_perm[b, slot]
                acc = 0.0
                for k in range(lmax):
                    acc += float(vals[b, gi, k, wi]) * float(xsegs[b, cols[b, gi, k, wi]])
                y[row] += acc
    return (
        jnp.asarray(cols),
        jnp.asarray(vals),
        jnp.asarray(xsegs),
        jnp.asarray(inv_perm),
        y,
    )


class TestRowBlockSpmv:
    def test_small_composition(self):
        cols, vals, xsegs, inv_perm, y = make_row_block(2, 2, 4, 4, 16, seed=0)
        out = model.row_block_spmv(cols, vals, xsegs, inv_perm)
        np.testing.assert_allclose(out, y, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        nb=st.integers(1, 4),
        g=st.integers(1, 3),
        lmax=st.integers(1, 8),
        w=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, nb, g, lmax, w, seed):
        cols, vals, xsegs, inv_perm, y = make_row_block(nb, g, lmax, w, 32, seed)
        out = model.row_block_spmv(cols, vals, xsegs, inv_perm)
        np.testing.assert_allclose(out, y, rtol=1e-3, atol=1e-3)


class TestBatchedBlockSpmv:
    def test_batch_equals_loop(self):
        rng = np.random.default_rng(7)
        nb, g, lmax, w, s = 3, 2, 8, 4, 16
        cols = rng.integers(0, s, size=(nb, g, lmax, w)).astype(np.int32)
        vals = rng.standard_normal((nb, g, lmax, w)).astype(np.float32)
        xsegs = rng.standard_normal((nb, s)).astype(np.float32)
        # offset columns by b*s as the rust exporter would
        offset_cols = cols + (np.arange(nb)[:, None, None, None] * s).astype(np.int32)
        out = model.batched_block_spmv(
            jnp.asarray(offset_cols), jnp.asarray(vals), jnp.asarray(xsegs)
        )
        for b in range(nb):
            single = model.block_spmv(
                jnp.asarray(cols[b]), jnp.asarray(vals[b]), jnp.asarray(xsegs[b])
            )
            np.testing.assert_allclose(out[b], single, rtol=1e-5, atol=1e-6)

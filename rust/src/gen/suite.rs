//! The Table I matrix suite.
//!
//! Registry mapping the paper's matrix ids (m1..m14) to synthetic
//! generator configurations reproducing each matrix's structural profile
//! (see module docs in [`crate::gen`] for the substitution argument).
//!
//! Three scales:
//! - `Scale::Ci`   — dimensions / 64: seconds-fast, used by tests.
//! - `Scale::Small`— dimensions / 8: the default bench scale.
//! - `Scale::Full` — the paper's dimensions (minutes + GBs for m6/m7;
//!   benches expose it behind `--scale full`).
//!
//! The per-matrix `nnz` targets track Table I proportionally at each
//! scale (row *density* per row is preserved, so the row-length
//! distribution — the thing HBP is sensitive to — is scale-invariant).

use super::banded::{banded, BandedConfig};
use super::block_dense::{block_dense, BlockDenseConfig};
use super::circuit::{circuit, CircuitConfig};
use super::rmat::{rmat, RmatConfig};
use crate::formats::Csr;

/// Generation scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Ci,
    Small,
    Full,
}

impl Scale {
    pub fn divisor(self) -> usize {
        match self {
            Scale::Ci => 64,
            Scale::Small => 8,
            Scale::Full => 1,
        }
    }

    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "ci" => Some(Scale::Ci),
            "small" => Some(Scale::Small),
            "full" => Some(Scale::Full),
            _ => None,
        }
    }
}

/// A Table I matrix entry.
#[derive(Clone, Copy, Debug)]
pub struct SuiteMatrix {
    /// Paper id, `"m1"`..`"m14"`.
    pub id: &'static str,
    /// UF collection name the generator substitutes.
    pub name: &'static str,
    /// Paper dimensions (square).
    pub paper_rows: usize,
    /// Paper nnz.
    pub paper_nnz: usize,
    pub symmetric: bool,
    /// Structural family (drives generator choice).
    pub family: Family,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Circuit,
    CircuitRajat,
    Banded,
    BandedDense,
    Kron { logn: u32 },
    DenseTail,
}

/// The 14 Table I matrices.
#[rustfmt::skip]
pub const SUITE: [SuiteMatrix; 14] = [
    SuiteMatrix { id: "m1", name: "ASIC_320k", paper_rows: 321_000, paper_nnz: 1_900_000, symmetric: false, family: Family::Circuit },
    SuiteMatrix { id: "m2", name: "ASIC_680k", paper_rows: 682_000, paper_nnz: 3_800_000, symmetric: false, family: Family::Circuit },
    SuiteMatrix { id: "m3", name: "barrier2-3", paper_rows: 113_000, paper_nnz: 2_100_000, symmetric: false, family: Family::Banded },
    SuiteMatrix { id: "m4", name: "kron_g500-logn18", paper_rows: 262_144, paper_nnz: 21_100_000, symmetric: true, family: Family::Kron { logn: 18 } },
    SuiteMatrix { id: "m5", name: "kron_g500-logn19", paper_rows: 524_288, paper_nnz: 43_500_000, symmetric: true, family: Family::Kron { logn: 19 } },
    SuiteMatrix { id: "m6", name: "kron_g500-logn20", paper_rows: 1_048_576, paper_nnz: 89_200_000, symmetric: true, family: Family::Kron { logn: 20 } },
    SuiteMatrix { id: "m7", name: "kron_g500-logn21", paper_rows: 2_097_152, paper_nnz: 182_000_000, symmetric: true, family: Family::Kron { logn: 21 } },
    SuiteMatrix { id: "m8", name: "mip1", paper_rows: 66_000, paper_nnz: 10_300_000, symmetric: true, family: Family::DenseTail },
    SuiteMatrix { id: "m9", name: "nxp1", paper_rows: 414_000, paper_nnz: 2_700_000, symmetric: false, family: Family::Circuit },
    SuiteMatrix { id: "m10", name: "ohne2", paper_rows: 181_000, paper_nnz: 6_900_000, symmetric: false, family: Family::BandedDense },
    SuiteMatrix { id: "m11", name: "rajat21", paper_rows: 411_000, paper_nnz: 1_800_000, symmetric: false, family: Family::CircuitRajat },
    SuiteMatrix { id: "m12", name: "rajat24", paper_rows: 358_000, paper_nnz: 1_900_000, symmetric: false, family: Family::CircuitRajat },
    SuiteMatrix { id: "m13", name: "rajat29", paper_rows: 643_000, paper_nnz: 3_800_000, symmetric: false, family: Family::CircuitRajat },
    SuiteMatrix { id: "m14", name: "rajat30", paper_rows: 643_000, paper_nnz: 6_200_000, symmetric: false, family: Family::CircuitRajat },
];

/// All suite entries.
pub fn suite() -> &'static [SuiteMatrix] {
    &SUITE
}

/// Look up a suite entry by paper id (`"m4"`) or UF name.
pub fn entry_by_id(id: &str) -> Option<&'static SuiteMatrix> {
    SUITE.iter().find(|m| m.id == id || m.name == id)
}

impl SuiteMatrix {
    /// Scaled dimension.
    pub fn rows_at(&self, scale: Scale) -> usize {
        match self.family {
            Family::Kron { logn } => {
                let drop = scale.divisor().trailing_zeros();
                1usize << logn.saturating_sub(drop)
            }
            _ => (self.paper_rows / scale.divisor()).max(512),
        }
    }

    /// Deterministic per-matrix seed.
    fn seed(&self) -> u64 {
        // stable hash of the id string
        self.id.bytes().fold(0xD15EA5Eu64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64))
    }

    /// Generate the matrix at the given scale.
    pub fn generate(&self, scale: Scale) -> Csr {
        let n = self.rows_at(scale);
        let seed = self.seed();
        let mean_nnz = self.paper_nnz as f64 / self.paper_rows as f64;
        match self.family {
            Family::Kron { .. } => {
                // paper nnz counts the symmetrized, deduped matrix; the
                // edge factor before symmetrization is roughly mean/2
                // (plus dedup losses, compensated empirically by +15%)
                let ef = ((mean_nnz / 2.0) * 1.15).round() as usize;
                rmat(&RmatConfig::graph500((n as f64).log2() as u32, ef.max(2), seed))
            }
            Family::Circuit => {
                let mut cfg = CircuitConfig::asic_like(n, seed);
                // calibrate ordinary-row mean so total nnz ~ target
                cfg.mean_row_nnz = (mean_nnz - 1.0).max(1.0) * 0.55;
                circuit(&cfg)
            }
            Family::CircuitRajat => {
                let mut cfg = CircuitConfig::rajat_like(n, seed);
                cfg.mean_row_nnz = (mean_nnz - 1.0).max(1.0) * 0.6;
                circuit(&cfg)
            }
            Family::Banded => {
                let mut cfg = BandedConfig::barrier_like(n, seed);
                cfg.stencil = mean_nnz.round() as usize;
                banded(&cfg)
            }
            Family::BandedDense => {
                let mut cfg = BandedConfig::ohne_like(n, seed);
                cfg.stencil = mean_nnz.round() as usize;
                banded(&cfg)
            }
            Family::DenseTail => {
                let mut cfg = BlockDenseConfig::mip_like(n, seed);
                // body + dense tail average to mean_nnz
                cfg.body_mean = (mean_nnz * 0.35).max(4.0);
                block_dense(&cfg)
            }
        }
    }
}

/// Generate a suite matrix by id at a scale. Returns `(meta, matrix)`.
pub fn matrix_by_id(id: &str, scale: Scale) -> Option<(&'static SuiteMatrix, Csr)> {
    let e = entry_by_id(id)?;
    Some((e, e.generate(scale)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_table1() {
        assert_eq!(SUITE.len(), 14);
        assert_eq!(entry_by_id("m4").unwrap().name, "kron_g500-logn18");
        assert_eq!(entry_by_id("ohne2").unwrap().id, "m10");
        assert!(entry_by_id("m99").is_none());
    }

    #[test]
    fn ci_scale_generates_all_quickly() {
        for e in suite() {
            let m = e.generate(Scale::Ci);
            m.validate().unwrap();
            assert!(m.rows >= 512, "{}: rows {}", e.id, m.rows);
            assert!(m.nnz() > 0, "{}: empty", e.id);
        }
    }

    #[test]
    fn nnz_tracks_paper_density() {
        // mean row length at CI scale should be within 2x of the paper's
        for e in suite() {
            if matches!(e.family, Family::Kron { .. }) {
                continue; // kron dedup at tiny scales skews density; covered below
            }
            let m = e.generate(Scale::Ci);
            let paper_mean = e.paper_nnz as f64 / e.paper_rows as f64;
            let got_mean = m.nnz() as f64 / m.rows as f64;
            assert!(
                got_mean > paper_mean * 0.4 && got_mean < paper_mean * 2.5,
                "{}: mean row nnz {got_mean:.1} vs paper {paper_mean:.1}",
                e.id
            );
        }
    }

    #[test]
    fn kron_ci_has_power_law() {
        let (_, m) = matrix_by_id("m4", Scale::Ci).unwrap();
        let lens = m.row_lengths();
        let max = *lens.iter().max().unwrap();
        let mean = m.nnz() as f64 / m.rows as f64;
        assert!(max as f64 > 4.0 * mean, "kron skew missing: max={max} mean={mean:.1}");
    }

    #[test]
    fn symmetric_entries_are_symmetric() {
        let (_, m) = matrix_by_id("m8", Scale::Ci).unwrap();
        assert_eq!(m, m.transpose());
    }

    #[test]
    fn scales_are_ordered() {
        let e = entry_by_id("m1").unwrap();
        assert!(e.rows_at(Scale::Ci) < e.rows_at(Scale::Small));
        assert!(e.rows_at(Scale::Small) < e.rows_at(Scale::Full));
        assert_eq!(e.rows_at(Scale::Full), e.paper_rows);
    }
}

//! Banded FEM-stencil generator — substitute for the paper's `barrier2-3`
//! and `ohne2` matrices (semiconductor device simulation).
//!
//! These are 3D device-simulation discretizations: nearly uniform row
//! lengths (a multi-point stencil), all entries within a band around the
//! diagonal. They are the paper's *adversarial* case: CSR is already
//! bandwidth-friendly here and m3 (barrier2-3) is the one matrix where
//! HBP loses to CSR on both devices — our reproduction must preserve that
//! crossover.

use crate::formats::{Coo, Csr};
use crate::util::Rng;

/// Banded stencil parameters.
#[derive(Clone, Copy, Debug)]
pub struct BandedConfig {
    pub n: usize,
    /// Points per stencil row (mean nnz/row), e.g. ~19 for barrier2-3.
    pub stencil: usize,
    /// Half bandwidth: offsets drawn from `[-bw, bw]` around the diagonal.
    pub half_bandwidth: usize,
    /// Fraction of rows with a slightly reduced stencil (boundary nodes).
    pub boundary_frac: f64,
    pub seed: u64,
}

impl BandedConfig {
    pub fn barrier_like(n: usize, seed: u64) -> Self {
        // barrier2-3: 113K rows, 2.1M nnz -> ~18.6 nnz/row
        BandedConfig { n, stencil: 19, half_bandwidth: (n / 40).max(32), boundary_frac: 0.12, seed }
    }

    pub fn ohne_like(n: usize, seed: u64) -> Self {
        // ohne2: 181K rows, 6.9M nnz -> ~38 nnz/row
        BandedConfig { n, stencil: 38, half_bandwidth: (n / 30).max(48), boundary_frac: 0.10, seed }
    }
}

/// Generate a banded stencil matrix in CSR form.
///
/// Each row gets the diagonal plus `stencil-1` entries at a mix of fixed
/// stencil offsets (shared across rows — giving DIA-like diagonals) and
/// a few row-random offsets within the band (FEM meshes are not perfectly
/// regular).
pub fn banded(cfg: &BandedConfig) -> Csr {
    let n = cfg.n;
    let mut rng = Rng::new(cfg.seed);
    let mut coo = Coo::new(n, n);

    // fixed stencil offsets shared by all rows (~90% of the stencil) —
    // real FEM discretizations repeat the same stencil on nearly every
    // row, which is what gives CSR its coalesced x-access on barrier2-3
    // (the paper's one CSR-wins case; Fig. 8 m3)
    let fixed_count = (cfg.stencil * 9 / 10).max(1);
    let mut fixed: Vec<i64> = vec![0];
    while fixed.len() < fixed_count {
        let o = rng.range(1, cfg.half_bandwidth + 1) as i64;
        let o = if rng.chance(0.5) { o } else { -o };
        if !fixed.contains(&o) {
            fixed.push(o);
        }
    }

    for r in 0..n {
        let boundary = rng.chance(cfg.boundary_frac);
        let target = if boundary {
            (cfg.stencil * 2 / 3).max(1)
        } else {
            cfg.stencil
        };
        let mut placed = std::collections::HashSet::new();
        for &o in fixed.iter().take(target) {
            let c = r as i64 + o;
            if c >= 0 && (c as usize) < n && placed.insert(c) {
                let v = if o == 0 {
                    4.0 + rng.f64()
                } else {
                    rng.range_f64(-1.0, 0.0)
                };
                coo.push(r, c as usize, v);
            }
        }
        // random in-band remainder
        let mut guard = 0;
        while placed.len() < target && guard < 8 * target {
            guard += 1;
            let o = rng.range(1, cfg.half_bandwidth + 1) as i64;
            let o = if rng.chance(0.5) { o } else { -o };
            let c = r as i64 + o;
            if c >= 0 && (c as usize) < n && placed.insert(c) {
                coo.push(r, c as usize, rng.range_f64(-1.0, 0.0));
            }
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Stats;

    #[test]
    fn rows_are_uniform_length() {
        let m = banded(&BandedConfig::barrier_like(4000, 3));
        m.validate().unwrap();
        let s = Stats::of_usize(&m.row_lengths());
        // uniform stencil: stddev small relative to mean (the opposite of
        // the circuit profile)
        assert!(s.std < 0.35 * s.mean, "banded profile too skewed: {s:?}");
        assert!(s.mean > 10.0);
    }

    #[test]
    fn entries_stay_in_band() {
        let cfg = BandedConfig::barrier_like(2000, 9);
        let m = banded(&cfg);
        for r in 0..m.rows {
            let (cols, _) = m.row(r);
            for &c in cols {
                let d = (c as i64 - r as i64).unsigned_abs() as usize;
                assert!(d <= cfg.half_bandwidth, "row {r} col {c} outside band");
            }
        }
    }

    #[test]
    fn ohne_denser_than_barrier() {
        let b = banded(&BandedConfig::barrier_like(3000, 1));
        let o = banded(&BandedConfig::ohne_like(3000, 1));
        assert!(o.nnz() > b.nnz() * 3 / 2);
    }

    #[test]
    fn diagonal_dominant_structure() {
        let m = banded(&BandedConfig::barrier_like(500, 21));
        for r in (0..500).step_by(37) {
            assert!(m.get(r, r) >= 4.0, "diagonal at {r} = {}", m.get(r, r));
        }
    }
}

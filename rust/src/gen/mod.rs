//! Synthetic sparse-matrix generators.
//!
//! The paper evaluates on 14 matrices from the University of Florida
//! Sparse Matrix Collection (Table I). The collection is not reachable
//! from this offline environment, so each matrix is substituted by a
//! generator that reproduces the *structural statistics HBP is sensitive
//! to* — row-length distribution (what the nonlinear hash balances),
//! column locality (what 2D-partitioning exploits) and overall dims/nnz —
//! per DESIGN.md §2:
//!
//! - `kron_g500-logn*`  → [`rmat`]: Graph500 Kronecker/R-MAT power-law graphs
//! - `ASIC_*`, `rajat*`, `nxp1` → [`circuit`]: circuit simulation matrices
//!   (near-diagonal short rows + a few ultra-dense power/ground nets)
//! - `barrier2-3`, `ohne2` → [`banded`]: semiconductor-device FEM stencils
//! - `mip1` → [`block_dense`]: optimization matrix with a dense tail block
//! - [`random`]: uniform & power-law matrices for tests and ablations
//!
//! [`suite`] is the Table I registry mapping matrix ids (m1..m14) to
//! generator configs at CI/small/full scales.

pub mod rmat;
pub mod circuit;
pub mod banded;
pub mod block_dense;
pub mod random;
pub mod suite;

pub use suite::{SuiteMatrix, Scale, suite, matrix_by_id};

//! Dense-tail optimization-matrix generator — substitute for the paper's
//! `mip1` (mixed-integer programming, 66K x 66K, 10.3M nnz, symmetric).
//!
//! mip1's signature: a moderately sparse main body plus a *dense trailing
//! block* of coupling constraints — average row length ~156 with a heavy
//! tail, and scattered column access in the dense block. The paper calls
//! out m8 (with m4) as a matrix where scattered vector access makes CSR
//! slow and 2D-partitioning (and HBP) win.

use crate::formats::{Coo, Csr};
use crate::util::Rng;

/// Dense-tail matrix parameters.
#[derive(Clone, Copy, Debug)]
pub struct BlockDenseConfig {
    pub n: usize,
    /// Mean nnz per sparse-body row.
    pub body_mean: f64,
    pub body_max: usize,
    /// Fraction of rows in the dense trailing block.
    pub dense_frac: f64,
    /// Density of the dense block (fraction of n columns hit).
    pub dense_density: f64,
    pub seed: u64,
}

impl BlockDenseConfig {
    pub fn mip_like(n: usize, seed: u64) -> Self {
        BlockDenseConfig {
            n,
            body_mean: 40.0,
            body_max: 300,
            dense_frac: 0.02,
            dense_density: 0.25,
            seed,
        }
    }
}

/// Generate the dense-tail matrix in CSR form (symmetric like mip1).
pub fn block_dense(cfg: &BlockDenseConfig) -> Csr {
    let n = cfg.n;
    let mut rng = Rng::new(cfg.seed);
    let mut coo = Coo::new(n, n);
    let dense_start = n - ((n as f64 * cfg.dense_frac) as usize).max(1);

    for r in 0..dense_start {
        coo.push(r, r, 2.0 + rng.f64());
        let k = rng.exponential(cfg.body_mean, 1, cfg.body_max);
        for c in rng.sample_indices(n, k.min(n)) {
            if c != r {
                // only upper triangle; symmetrize() mirrors
                let (a, b) = if r < c { (r, c) } else { (c, r) };
                coo.push(a, b, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    for r in dense_start..n {
        coo.push(r, r, 2.0 + rng.f64());
        let fanout = (n as f64 * cfg.dense_density) as usize;
        for c in rng.sample_indices(n, fanout.min(n)) {
            if c != r {
                let (a, b) = if r < c { (r, c) } else { (c, r) };
                coo.push(a, b, rng.range_f64(-1.0, 1.0));
            }
        }
    }

    coo.normalize(); // dedup overlapping upper-triangle picks first
    coo.symmetrize();
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_symmetric() {
        let m = block_dense(&BlockDenseConfig::mip_like(800, 7));
        m.validate().unwrap();
        let t = m.transpose();
        assert_eq!(m, t);
    }

    #[test]
    fn has_dense_tail() {
        let cfg = BlockDenseConfig::mip_like(1000, 5);
        let m = block_dense(&cfg);
        let lens = m.row_lengths();
        let body_mean: f64 =
            lens[..900].iter().sum::<usize>() as f64 / 900.0;
        let tail_mean: f64 = lens[980..].iter().sum::<usize>() as f64 / 20.0;
        assert!(
            tail_mean > 3.0 * body_mean,
            "tail {tail_mean} not denser than body {body_mean}"
        );
    }

    #[test]
    fn deterministic() {
        let a = block_dense(&BlockDenseConfig::mip_like(300, 1));
        let b = block_dense(&BlockDenseConfig::mip_like(300, 1));
        assert_eq!(a, b);
    }
}

//! Circuit-simulation matrix generator — substitute for the paper's
//! `ASIC_320k`, `ASIC_680k`, `rajat21/24/29/30` and `nxp1` matrices.
//!
//! Post-layout circuit matrices share a signature the UF collection pages
//! document and the paper's Fig. 6 exploits: almost every row is a short
//! stencil near the diagonal (device connections), while a handful of
//! rows/columns are *enormously* dense — power/ground/clock nets touching
//! a large fraction of all nodes. That mix is what makes per-warp load
//! wildly unbalanced (ASIC_680k's 79% stddev reduction is the paper's
//! best case) and what zero-padding formats choke on.

use crate::formats::{Coo, Csr};
use crate::util::Rng;

/// Circuit matrix parameters.
#[derive(Clone, Copy, Debug)]
pub struct CircuitConfig {
    pub n: usize,
    /// Mean nonzeros per ordinary row (besides the diagonal).
    pub mean_row_nnz: f64,
    /// Max nonzeros per ordinary row.
    pub max_row_nnz: usize,
    /// Column distance window for ordinary entries (locality of nets).
    pub locality: usize,
    /// Fraction of entries escaping the locality window (long wires).
    pub long_range_frac: f64,
    /// Number of dense hub rows (power/ground nets).
    pub hub_rows: usize,
    /// Each hub row touches `n / hub_divisor` columns.
    pub hub_divisor: usize,
    /// Mirror hubs as dense columns too.
    pub hub_cols: bool,
    pub seed: u64,
}

impl CircuitConfig {
    /// A reasonable ASIC_680k-like default at dimension `n`.
    pub fn asic_like(n: usize, seed: u64) -> Self {
        CircuitConfig {
            n,
            mean_row_nnz: 3.5,
            max_row_nnz: 48,
            locality: (n / 64).max(8),
            long_range_frac: 0.05,
            hub_rows: (n / 40_000).max(2),
            hub_divisor: 4,
            hub_cols: true,
            seed,
        }
    }

    /// rajat-like: slightly denser ordinary rows, fewer but wider hubs.
    pub fn rajat_like(n: usize, seed: u64) -> Self {
        CircuitConfig {
            n,
            mean_row_nnz: 3.0,
            max_row_nnz: 80,
            locality: (n / 100).max(8),
            long_range_frac: 0.08,
            hub_rows: (n / 80_000).max(1),
            hub_divisor: 3,
            hub_cols: false,
            seed,
        }
    }
}

/// Generate a circuit-style sparse matrix in CSR form.
pub fn circuit(cfg: &CircuitConfig) -> Csr {
    let n = cfg.n;
    let mut rng = Rng::new(cfg.seed);
    let mut coo = Coo::new(n, n);

    // hub (power/ground) net indices, spread through the matrix
    let hubs = rng.sample_indices(n, cfg.hub_rows.min(n));

    for r in 0..n {
        // diagonal always present (circuit matrices are structurally
        // nonsingular after MNA stamping)
        coo.push(r, r, 1.0 + rng.f64() * 4.0);
        let k = rng.exponential(cfg.mean_row_nnz, 0, cfg.max_row_nnz);
        for _ in 0..k {
            let c = if rng.chance(cfg.long_range_frac) {
                rng.below(n)
            } else {
                // near-diagonal window, clamped
                let lo = r.saturating_sub(cfg.locality);
                let hi = (r + cfg.locality + 1).min(n);
                rng.range(lo, hi)
            };
            coo.push(r, c, rng.range_f64(-1.0, 1.0));
        }
    }

    // dense hub rows / columns
    for &h in &hubs {
        let fanout = n / cfg.hub_divisor.max(1);
        for c in rng.sample_indices(n, fanout) {
            coo.push(h, c, rng.range_f64(-0.1, 0.1));
            if cfg.hub_cols {
                coo.push(c, h, rng.range_f64(-0.1, 0.1));
            }
        }
    }

    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Stats;

    #[test]
    fn has_diagonal_and_hubs() {
        let cfg = CircuitConfig::asic_like(2000, 5);
        let m = circuit(&cfg);
        m.validate().unwrap();
        for r in (0..m.rows).step_by(97) {
            assert!(m.get(r, r) != 0.0, "diagonal missing at {r}");
        }
        let lens = m.row_lengths();
        let max = *lens.iter().max().unwrap();
        assert!(max >= 2000 / 4, "no dense hub row: max={max}");
    }

    #[test]
    fn typical_rows_are_short() {
        let cfg = CircuitConfig::asic_like(4000, 11);
        let m = circuit(&cfg);
        let mut lens = m.row_lengths();
        lens.sort_unstable();
        let median = lens[lens.len() / 2];
        assert!(median <= 12, "median row length {median} too large for a circuit profile");
    }

    #[test]
    fn row_length_skew_is_extreme() {
        // the property Fig 6 depends on: stddev >> mean
        let m = circuit(&CircuitConfig::asic_like(4000, 13));
        let s = Stats::of_usize(&m.row_lengths());
        assert!(s.std > s.mean, "circuit profile should be highly skewed: {s:?}");
    }

    #[test]
    fn rajat_variant_differs_but_valid() {
        let m = circuit(&CircuitConfig::rajat_like(3000, 17));
        m.validate().unwrap();
        assert!(m.nnz() > 3000);
    }

    #[test]
    fn deterministic() {
        let a = circuit(&CircuitConfig::asic_like(500, 3));
        let b = circuit(&CircuitConfig::asic_like(500, 3));
        assert_eq!(a, b);
    }
}

//! Uniform and power-law random matrices — used by tests, property-based
//! checks and the ablation benches where a controllable row-length
//! distribution is needed.

use crate::formats::{Coo, Csr};
use crate::util::Rng;

/// Uniform random sparse matrix: every entry present independently with
/// probability `density` (expected nnz = rows*cols*density).
pub fn uniform(rows: usize, cols: usize, density: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(rows, cols);
    // For small densities, sample per-row counts binomially-ish rather than
    // scanning all cells.
    let mean = cols as f64 * density;
    for r in 0..rows {
        let k = rng.exponential(mean, 0, cols);
        for c in rng.sample_indices(cols, k) {
            coo.push(r, c, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

/// Power-law row lengths: row i has `~ P(l) ∝ l^-alpha` nonzeros at
/// uniformly random columns. `alpha` near 2 gives the heavy skew the
/// nonlinear hash is designed for.
pub fn power_law_rows(rows: usize, cols: usize, alpha: f64, max_row: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(rows, cols);
    for r in 0..rows {
        let k = rng.power_law(alpha, max_row.min(cols));
        for c in rng.sample_indices(cols, k) {
            coo.push(r, c, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

/// Matrix with exactly the given row lengths (columns uniform random) —
/// lets property tests construct adversarial length distributions.
pub fn with_row_lengths(lengths: &[usize], cols: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(lengths.len(), cols);
    for (r, &k) in lengths.iter().enumerate() {
        for c in rng.sample_indices(cols, k.min(cols)) {
            coo.push(r, c, rng.range_f64(-1.0, 1.0));
        }
    }
    coo.to_csr()
}

/// Random dense vector in `[-1, 1)`.
pub fn vector(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_density_roughly_right() {
        let m = uniform(500, 500, 0.02, 3);
        m.validate().unwrap();
        let expected = 500.0 * 500.0 * 0.02;
        let got = m.nnz() as f64;
        assert!(got > expected * 0.5 && got < expected * 1.8, "nnz={got} expected~{expected}");
    }

    #[test]
    fn with_row_lengths_exact() {
        let lens = vec![0, 3, 7, 1, 0, 20];
        let m = with_row_lengths(&lens, 64, 9);
        assert_eq!(m.row_lengths(), lens);
    }

    #[test]
    fn power_law_has_tail_and_head() {
        let m = power_law_rows(2000, 2000, 2.0, 500, 11);
        let lens = m.row_lengths();
        assert!(lens.iter().filter(|&&l| l <= 2).count() > 500);
        assert!(*lens.iter().max().unwrap() > 50);
    }

    #[test]
    fn vector_deterministic() {
        assert_eq!(vector(10, 5), vector(10, 5));
        assert_ne!(vector(10, 5), vector(10, 6));
    }
}

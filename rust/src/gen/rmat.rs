//! R-MAT / Kronecker graph generator — substitute for the paper's
//! `kron_g500-logn{18..21}` matrices.
//!
//! Graph500's synthetic kernel *is* an R-MAT recursion with quadrant
//! probabilities (A,B,C,D) = (0.57, 0.19, 0.19, 0.05); the UF `kron_g500`
//! matrices are instances of it. Generating our own at the same scale
//! reproduces the power-law row-length skew and the scattered column
//! access that make these matrices hard for CSR SpMV (paper §IV-C: m4, m8
//! are the matrices where HBP wins biggest).

use crate::formats::{Coo, Csr};
use crate::util::Rng;

/// R-MAT parameters.
#[derive(Clone, Copy, Debug)]
pub struct RmatConfig {
    /// log2 of the vertex count (matrix dimension = 2^scale).
    pub scale: u32,
    /// Average (directed) edges per vertex before dedup/symmetrization.
    pub edge_factor: usize,
    /// Quadrant probabilities; must sum to ~1.
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Make the matrix symmetric (the paper's kron matrices are).
    pub symmetric: bool,
    pub seed: u64,
}

impl RmatConfig {
    /// Graph500 defaults at a given scale.
    pub fn graph500(scale: u32, edge_factor: usize, seed: u64) -> Self {
        RmatConfig { scale, edge_factor, a: 0.57, b: 0.19, c: 0.19, symmetric: true, seed }
    }
}

/// Generate an R-MAT graph adjacency matrix in CSR form.
///
/// Self-loops are kept (they correspond to diagonal entries), duplicate
/// edges are summed by normalization — matching how kron_g500 instances
/// are materialized as matrices with nnz counted after dedup.
pub fn rmat(cfg: &RmatConfig) -> Csr {
    let n = 1usize << cfg.scale;
    let edges = n * cfg.edge_factor;
    let mut rng = Rng::new(cfg.seed);
    let mut coo = Coo::new(n, n);
    // Slight per-level probability noise (as in Graph500) prevents the
    // artificial griddy structure pure R-MAT produces.
    for _ in 0..edges {
        let (mut r, mut c) = (0usize, 0usize);
        for _level in 0..cfg.scale {
            let u = rng.f64();
            // perturb quadrant probabilities +-5% per level
            let noise = 0.95 + 0.1 * rng.f64();
            let a = cfg.a * noise;
            let b = cfg.b * noise;
            let cq = cfg.c * noise;
            r <<= 1;
            c <<= 1;
            if u < a {
                // top-left
            } else if u < a + b {
                c |= 1;
            } else if u < a + b + cq {
                r |= 1;
            } else {
                r |= 1;
                c |= 1;
            }
        }
        coo.push(r, c, 1.0 + rng.f64());
    }
    if cfg.symmetric {
        coo.symmetrize();
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Stats;

    #[test]
    fn shape_and_scale() {
        let m = rmat(&RmatConfig::graph500(8, 8, 1));
        assert_eq!(m.rows, 256);
        assert_eq!(m.cols, 256);
        // dedup + symmetrize: nnz within sane bounds
        assert!(m.nnz() > 256 * 4, "nnz={}", m.nnz());
        assert!(m.nnz() <= 256 * 8 * 2);
        m.validate().unwrap();
    }

    #[test]
    fn symmetric_when_requested() {
        let m = rmat(&RmatConfig::graph500(7, 6, 3));
        let t = m.transpose();
        assert_eq!(m, t);
    }

    #[test]
    fn power_law_degree_skew() {
        let m = rmat(&RmatConfig::graph500(10, 16, 7));
        let lens = m.row_lengths();
        let s = Stats::of_usize(&lens);
        // R-MAT hallmark: max degree far above mean, many near-empty rows
        assert!(s.max > 8.0 * s.mean, "max={} mean={}", s.max, s.mean);
        let empties = lens.iter().filter(|&&l| l <= 1).count();
        assert!(empties > m.rows / 20, "skew missing: only {empties} near-empty rows");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = rmat(&RmatConfig::graph500(7, 4, 42));
        let b = rmat(&RmatConfig::graph500(7, 4, 42));
        assert_eq!(a, b);
        let c = rmat(&RmatConfig::graph500(7, 4, 43));
        assert_ne!(a, c);
    }
}

//! CSR SpMV engines — the paper's Algorithm 1 baseline.

use super::engine::{PhaseTimes, SpmvEngine};
use crate::formats::Csr;
use crate::util::sync::SharedMut;
use crate::util::Timer;

/// Serial CSR SpMV.
pub struct CsrSerial {
    pub m: Csr,
}

impl CsrSerial {
    pub fn new(m: Csr) -> Self {
        CsrSerial { m }
    }
}

impl SpmvEngine for CsrSerial {
    fn name(&self) -> &str {
        "csr-serial"
    }
    fn rows(&self) -> usize {
        self.m.rows
    }
    fn cols(&self) -> usize {
        self.m.cols
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        let t = Timer::start();
        self.m.spmv(x, y);
        PhaseTimes { spmv: t.elapsed_secs(), combine: 0.0 }
    }
}

/// Row-parallel CSR SpMV: rows are chunked contiguously across worker
/// threads with nnz-balanced boundaries (the standard CUDA `csr_vector`
/// / OpenMP guided analog — a fair, competent baseline, not a strawman).
pub struct CsrParallel {
    pub m: Csr,
    pub threads: usize,
    /// Row chunk boundaries, `threads+1` entries.
    bounds: Vec<usize>,
    /// Persistent workers (§Perf: no per-call spawns).
    pool: crate::util::pool::WorkerPool,
}

impl CsrParallel {
    pub fn new(m: Csr, threads: usize) -> Self {
        let threads = threads.max(1);
        // nnz-balanced contiguous row partition
        let total = m.nnz().max(1);
        let per = total.div_ceil(threads);
        let mut bounds = vec![0usize];
        let mut acc = 0usize;
        for r in 0..m.rows {
            acc += m.row_nnz(r);
            if acc >= per * bounds.len() && bounds.len() < threads {
                bounds.push(r + 1);
            }
        }
        while bounds.len() < threads {
            bounds.push(m.rows);
        }
        bounds.push(m.rows);
        CsrParallel { m, threads, bounds, pool: crate::util::pool::WorkerPool::new(threads) }
    }
}

impl SpmvEngine for CsrParallel {
    fn name(&self) -> &str {
        "csr"
    }
    fn rows(&self) -> usize {
        self.m.rows
    }
    fn cols(&self) -> usize {
        self.m.cols
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.m.cols);
        assert_eq!(y.len(), self.m.rows);
        let t = Timer::start();
        let shared = SharedMut::new(y);
        let m = &self.m;
        self.pool.run_generation(|w, _| {
            let (lo, hi) = (self.bounds[w], self.bounds[w + 1]);
            if lo >= hi {
                return;
            }
            // SAFETY: row chunks [lo, hi) are disjoint per worker.
            let out = unsafe { shared.slice_mut(lo, hi - lo) };
            for (yi, r) in out.iter_mut().zip(lo..hi) {
                let (cols, vals) = m.row(r);
                let mut sum = 0.0;
                for (c, v) in cols.iter().zip(vals) {
                    sum += v * x[*c as usize];
                }
                *yi = sum;
            }
        });
        PhaseTimes { spmv: t.elapsed_secs(), combine: 0.0 }
    }

    /// Value-level update in place. CSR derives nothing from the values
    /// and the row extents never change (ReplaceRow stays within its
    /// row), so the nnz-balanced `bounds` stay valid for every delta
    /// kind — even pattern-changing ones.
    fn update(
        &mut self,
        delta: &crate::preprocess::MatrixDelta,
    ) -> anyhow::Result<crate::preprocess::UpdateReport> {
        let change = crate::preprocess::apply_to_csr(&mut self.m, delta)?;
        Ok(crate::preprocess::UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: 0,
            full_rebuild: false,
        })
    }

    /// SpMM with a vector-inner loop: every matrix element is read once
    /// and applied to the whole batch (k-way reuse of the expensive
    /// stream) — the win the coordinator's same-matrix batching buys.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        super::engine::check_spmm_dims("csr", self.m.rows, self.m.cols, xs, ys);
        if xs.is_empty() {
            return;
        }
        let k = xs.len();
        // collect raw output pointers; each worker writes disjoint rows
        let y_ptrs: Vec<crate::util::sync::SharedMut<f64>> = ys
            .iter_mut()
            .map(|y| crate::util::sync::SharedMut::new(&mut y[..]))
            .collect();
        let m = &self.m;
        self.pool.run_generation(|w, _| {
            let (lo, hi) = (self.bounds[w], self.bounds[w + 1]);
            for r in lo..hi {
                let (cols, vals) = m.row(r);
                // accumulate all k outputs while streaming the row once
                for ki in 0..k {
                    let x = &xs[ki];
                    let mut sum = 0.0;
                    for (c, v) in cols.iter().zip(vals) {
                        sum += v * x[*c as usize];
                    }
                    // SAFETY: rows [lo, hi) are disjoint per worker.
                    unsafe { y_ptrs[ki].write(r, sum) };
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    #[test]
    fn parallel_matches_serial() {
        let m = random::power_law_rows(500, 400, 2.0, 80, 5);
        let x = random::vector(400, 1);
        let serial = CsrSerial::new(m.clone());
        let mut ys = vec![0.0; 500];
        serial.spmv(&x, &mut ys);
        for threads in [1, 2, 3, 8] {
            let par = CsrParallel::new(m.clone(), threads);
            let mut yp = vec![0.0; 500];
            par.spmv(&x, &mut yp);
            assert!(allclose(&ys, &yp, 1e-12, 1e-12), "threads={threads}");
        }
    }

    #[test]
    fn bounds_cover_all_rows() {
        let m = random::uniform(100, 50, 0.1, 7);
        let p = CsrParallel::new(m, 7);
        assert_eq!(p.bounds.len(), 8);
        assert_eq!(p.bounds[0], 0);
        assert_eq!(*p.bounds.last().unwrap(), 100);
        for w in p.bounds.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn spmm_matches_repeated_spmv() {
        let m = random::power_law_rows(200, 150, 2.0, 30, 7);
        let eng = CsrParallel::new(m.clone(), 4);
        let xs: Vec<Vec<f64>> = (0..5).map(|i| random::vector(150, i)).collect();
        let mut ys: Vec<Vec<f64>> = (0..5).map(|_| vec![0.0; 200]).collect();
        eng.spmm(&xs, &mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            let mut expect = vec![0.0; 200];
            eng.spmv(x, &mut expect);
            assert!(allclose(y, &expect, 1e-12, 1e-12));
        }
    }

    #[test]
    fn update_applies_values_in_place() {
        use crate::preprocess::MatrixDelta;
        let m = random::power_law_rows(60, 50, 2.0, 15, 11);
        let mut eng = CsrParallel::new(m.clone(), 3);
        let row = (0..60).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let report = eng.update(&MatrixDelta::new().scale_row(row, 4.0)).unwrap();
        assert_eq!(report.rows_touched, 1);
        let x = random::vector(50, 2);
        let mut y = vec![0.0; 60];
        eng.spmv(&x, &mut y);
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &MatrixDelta::new().scale_row(row, 4.0))
            .unwrap();
        let mut expect = vec![0.0; 60];
        mutated.spmv(&x, &mut expect);
        assert!(allclose(&y, &expect, 1e-12, 1e-12));
    }

    #[test]
    fn spmm_empty_batch() {
        let m = random::uniform(10, 10, 0.3, 1);
        let eng = CsrParallel::new(m, 2);
        eng.spmm(&[], &mut []);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(10, 10);
        let p = CsrParallel::new(m, 4);
        let mut y = vec![9.0; 10];
        p.spmv(&vec![1.0; 10], &mut y);
        assert_eq!(y, vec![0.0; 10]);
    }

    #[test]
    fn more_threads_than_rows() {
        let m = random::uniform(3, 3, 0.9, 2);
        let p = CsrParallel::new(m.clone(), 16);
        let x = random::vector(3, 3);
        let mut y = vec![0.0; 3];
        p.spmv(&x, &mut y);
        let mut expect = vec![0.0; 3];
        m.spmv(&x, &mut expect);
        assert!(allclose(&y, &expect, 1e-12, 1e-12));
    }
}

//! Nonzero-split SpMV — the related-work load-balancing baseline.
//!
//! The paper's §II discusses CSR5 (Liu & Vinter) and the merge-based
//! method (Merrill & Garland, ref [20]): assign each worker an *equal
//! number of nonzeros* regardless of row boundaries, so pathological
//! rows can no longer unbalance the schedule. This engine implements
//! that idea on the CPU substrate: per call, worker `w` owns the nonzero
//! range `[w*nnz/W, (w+1)*nnz/W)`; rows fully inside a range are written
//! directly (disjoint), rows cut by a boundary contribute partial sums
//! that a tiny serial fix-up pass merges (≤ 2 per worker).
//!
//! It completes the baseline set: CSR (row-balanced), plain 2D
//! (block-static), HBP (hash-grouped + competitive), nnz-split
//! (perfectly nnz-balanced, but with none of HBP's locality control).

use super::engine::{check_spmm_dims, PhaseTimes, SpmvEngine, SPMM_TILE};
use crate::formats::Csr;
use crate::util::pool::WorkerPool;
use crate::util::sync::SharedMut;
use crate::util::Timer;
use std::sync::Mutex;

/// Even nonzero split points for `threads` workers: `threads + 1`
/// monotone chunk starts with `splits[w] = w * nnz / threads`. Shared
/// by every nnz-splitting engine (this one and [`super::flat`]).
pub(crate) fn nnz_splits(nnz: usize, threads: usize) -> Vec<usize> {
    (0..=threads).map(|w| w * nnz / threads).collect()
}

/// First row whose nonzero extent contains each split point — the
/// precomputed binary search every chunk walk starts from. A pure
/// function of the row pointer, so it survives every
/// [`crate::preprocess::MatrixDelta`] kind (deltas rewrite `col`/`data`
/// in place; `ptr` never moves).
pub(crate) fn first_rows(m: &Csr, splits: &[usize]) -> Vec<usize> {
    splits
        .iter()
        .map(|&k| match m.ptr.binary_search(&k) {
            Ok(mut r) => {
                // land on the first row starting at k (ties: empty rows)
                while r > 0 && m.ptr[r - 1] == k {
                    r -= 1;
                }
                r.min(m.rows)
            }
            Err(r) => r - 1, // k falls inside row r-1
        })
        .collect()
}

/// Per-worker boundary contribution: `(row, partial_sum)`.
type Boundary = (usize, f64);

/// Boundary contribution of a fused tile pass: `(row, per-vector
/// partial sums)` — only the first `tile` entries of the array are
/// meaningful.
type TileBoundary = (usize, [f64; SPMM_TILE]);

/// Nonzero-split SpMV engine.
pub struct NnzSplitEngine {
    pub m: Csr,
    pub threads: usize,
    /// Per-worker nonzero range starts (`threads+1` entries).
    splits: Vec<usize>,
    /// First row of each worker's range (precomputed binary search).
    first_row: Vec<usize>,
    pool: WorkerPool,
    /// Reused per-worker boundary buffers.
    boundaries: Mutex<Vec<(Option<Boundary>, Option<Boundary>)>>,
}

impl NnzSplitEngine {
    pub fn new(m: Csr, threads: usize) -> Self {
        let threads = threads.max(1);
        let splits = nnz_splits(m.nnz(), threads);
        let first_row = first_rows(&m, &splits);
        NnzSplitEngine {
            m,
            threads,
            splits,
            first_row,
            pool: WorkerPool::new(threads),
            boundaries: Mutex::new(vec![(None, None); threads]),
        }
    }
}

impl SpmvEngine for NnzSplitEngine {
    fn name(&self) -> &str {
        "nnz-split"
    }
    fn rows(&self) -> usize {
        self.m.rows
    }
    fn cols(&self) -> usize {
        self.m.cols
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.m.cols);
        assert_eq!(y.len(), self.m.rows);
        let t = Timer::start();
        y.fill(0.0);
        let mut boundaries = self.boundaries.lock().unwrap();
        boundaries.iter_mut().for_each(|b| *b = (None, None));
        {
            let shared_y = SharedMut::new(y);
            let shared_b = SharedMut::new(&mut boundaries[..]);
            let m = &self.m;
            self.pool.run_generation(|w, _| {
                let (lo, hi) = (self.splits[w], self.splits[w + 1]);
                if lo >= hi {
                    return;
                }
                let mut first: Option<Boundary> = None;
                let mut last: Option<Boundary> = None;
                let mut r = self.first_row[w];
                let mut k = lo;
                while k < hi {
                    // advance past empty rows
                    while m.ptr[r + 1] <= k {
                        r += 1;
                    }
                    let row_end = m.ptr[r + 1].min(hi);
                    let mut sum = 0.0;
                    for j in k..row_end {
                        sum += m.data[j] * x[m.col[j] as usize];
                    }
                    let starts_before = m.ptr[r] < lo;
                    let ends_after = m.ptr[r + 1] > hi;
                    if starts_before {
                        first = Some((r, sum));
                    } else if ends_after {
                        last = Some((r, sum));
                    } else {
                        // row fully owned: direct disjoint write
                        // SAFETY: only this worker owns rows entirely
                        // inside its nnz range.
                        unsafe { shared_y.write(r, sum) };
                    }
                    k = row_end;
                    r += 1;
                }
                // SAFETY: slot w is only touched by worker w.
                unsafe { shared_b.write(w, (first, last)) };
            });
        }
        // serial fix-up: merge boundary partials (<= 2 per worker)
        for &(first, last) in boundaries.iter() {
            for b in [first, last].into_iter().flatten() {
                y[b.0] += b.1;
            }
        }
        PhaseTimes { spmv: t.elapsed_secs(), combine: 0.0 }
    }

    /// Fused SpMM: per tile of at most [`SPMM_TILE`] vectors, one walk
    /// of each worker's nonzero range computes the whole tile's sums —
    /// each `(data, col)` pair is loaded once per pass instead of once
    /// per vector. Boundary rows carry per-vector partials into the
    /// serial fix-up, which also runs once per tile.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        check_spmm_dims("nnz-split", self.m.rows, self.m.cols, xs, ys);
        if xs.len() < 2 {
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                self.spmv(x, y);
            }
            return;
        }
        for y in ys.iter_mut() {
            y.fill(0.0);
        }
        let mut t_lo = 0;
        while t_lo < xs.len() {
            let t_hi = (t_lo + SPMM_TILE).min(xs.len());
            let tile = t_hi - t_lo;
            let x_tile = &xs[t_lo..t_hi];
            let mut bounds: Vec<(Option<TileBoundary>, Option<TileBoundary>)> =
                vec![(None, None); self.threads];
            {
                let y_ptrs: Vec<SharedMut<'_, f64>> = ys[t_lo..t_hi]
                    .iter_mut()
                    .map(|y| SharedMut::new(&mut y[..]))
                    .collect();
                let shared_b = SharedMut::new(&mut bounds[..]);
                let m = &self.m;
                self.pool.run_generation(|w, _| {
                    let (lo, hi) = (self.splits[w], self.splits[w + 1]);
                    if lo >= hi {
                        return;
                    }
                    let mut first: Option<TileBoundary> = None;
                    let mut last: Option<TileBoundary> = None;
                    let mut r = self.first_row[w];
                    let mut k = lo;
                    while k < hi {
                        while m.ptr[r + 1] <= k {
                            r += 1;
                        }
                        let row_end = m.ptr[r + 1].min(hi);
                        let mut sums = [0.0f64; SPMM_TILE];
                        for j in k..row_end {
                            let a = m.data[j];
                            let c = m.col[j] as usize;
                            for (s, x) in sums[..tile].iter_mut().zip(x_tile) {
                                *s += a * x[c];
                            }
                        }
                        let starts_before = m.ptr[r] < lo;
                        let ends_after = m.ptr[r + 1] > hi;
                        if starts_before {
                            first = Some((r, sums));
                        } else if ends_after {
                            last = Some((r, sums));
                        } else {
                            // SAFETY: only this worker owns rows entirely
                            // inside its nnz range; the y_ptrs point at
                            // distinct output vectors.
                            for (v, yp) in y_ptrs.iter().enumerate() {
                                unsafe { yp.write(r, sums[v]) };
                            }
                        }
                        k = row_end;
                        r += 1;
                    }
                    // SAFETY: slot w is only touched by worker w.
                    unsafe { shared_b.write(w, (first, last)) };
                });
            }
            // serial fix-up once per tile: merge boundary partials
            for &(first, last) in bounds.iter() {
                for (row, sums) in [first, last].into_iter().flatten() {
                    for (v, &s) in sums[..tile].iter().enumerate() {
                        ys[t_lo + v][row] += s;
                    }
                }
            }
            t_lo = t_hi;
        }
    }

    /// In-place delta repair. The split geometry (`splits`,
    /// `first_row`) is a pure function of the nonzero count and the row
    /// pointer, and no [`crate::preprocess::MatrixDelta`] kind moves
    /// either (`replace_row` rewrites `col`/`data` within the row's
    /// fixed extent) — so applying the delta to the resident CSR is the
    /// whole repair, for value-only *and* pattern-changing deltas alike.
    fn update(
        &mut self,
        delta: &crate::preprocess::MatrixDelta,
    ) -> anyhow::Result<crate::preprocess::UpdateReport> {
        let change = crate::preprocess::apply_to_csr(&mut self.m, delta)?;
        Ok(crate::preprocess::UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: 0,
            full_rebuild: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    fn check(m: &Csr, threads: usize, seed: u64) {
        let x = random::vector(m.cols, seed);
        let mut expect = vec![0.0; m.rows];
        m.spmv(&x, &mut expect);
        let eng = NnzSplitEngine::new(m.clone(), threads);
        let mut y = vec![0.0; m.rows];
        eng.spmv(&x, &mut y);
        assert!(
            allclose(&y, &expect, 1e-10, 1e-12),
            "nnz-split diverged (threads={threads})"
        );
    }

    #[test]
    fn matches_csr_on_random() {
        for seed in 0..4 {
            let m = random::power_law_rows(300, 250, 2.0, 60, seed);
            check(&m, 1, seed);
            check(&m, 4, seed);
            check(&m, 13, seed);
        }
    }

    #[test]
    fn handles_monster_row() {
        // one row holds ~all nonzeros: the case row-balanced CSR cannot
        // split but nnz-split divides evenly across workers
        let mut lens = vec![1usize; 64];
        lens[20] = 5000;
        let m = random::with_row_lengths(&lens, 600, 3);
        check(&m, 8, 7);
    }

    #[test]
    fn handles_empty_rows_at_boundaries() {
        let lens = vec![0, 0, 10, 0, 0, 7, 0, 3, 0, 0, 0, 25, 0, 1, 0, 0];
        let m = random::with_row_lengths(&lens, 40, 9);
        for threads in [1, 3, 5, 16] {
            check(&m, threads, 11);
        }
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(10, 10);
        let eng = NnzSplitEngine::new(m, 4);
        let mut y = vec![9.0; 10];
        eng.spmv(&vec![1.0; 10], &mut y);
        assert_eq!(y, vec![0.0; 10]);
    }

    #[test]
    fn fused_spmm_matches_repeated_spmv() {
        // monster row included: boundary rows carry tile partials
        let mut lens = vec![2usize; 80];
        lens[30] = 2000;
        let m = random::with_row_lengths(&lens, 300, 5);
        for threads in [1, 4, 9] {
            let eng = NnzSplitEngine::new(m.clone(), threads);
            let k = SPMM_TILE + 2;
            let xs: Vec<Vec<f64>> = (0..k).map(|i| random::vector(300, i as u64)).collect();
            let mut ys: Vec<Vec<f64>> = vec![vec![0.0; 80]; k];
            eng.spmm(&xs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut expect = vec![0.0; 80];
                eng.spmv(x, &mut expect);
                assert!(allclose(y, &expect, 1e-12, 1e-12), "threads={threads}");
            }
        }
    }

    #[test]
    fn suite_matrices() {
        for id in ["m1", "m4"] {
            let (_, m) = crate::gen::matrix_by_id(id, crate::gen::Scale::Ci).unwrap();
            check(&m, 8, 1);
        }
    }

    #[test]
    fn update_applies_values_in_place() {
        use crate::preprocess::MatrixDelta;
        let m = random::power_law_rows(70, 50, 2.0, 15, 13);
        let mut eng = NnzSplitEngine::new(m.clone(), 5);
        let row = (0..70).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let delta = MatrixDelta::new().scale_row(row, -2.0);
        let report = eng.update(&delta).unwrap();
        assert_eq!(report.rows_touched, 1);
        assert!(!report.full_rebuild, "nnz-split repairs in place");
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(50, 2);
        let mut y = vec![0.0; 70];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 70];
        mutated.spmv(&x, &mut expect);
        assert!(allclose(&y, &expect, 1e-12, 1e-12), "post-update spmv diverged");
    }

    #[test]
    fn update_survives_a_pattern_changing_delta() {
        use crate::preprocess::MatrixDelta;
        // replace_row with different columns changes the pattern but
        // not the row pointer, so the split geometry stays valid
        let m = random::power_law_rows(40, 60, 2.0, 12, 3);
        let row = (0..40).find(|&r| m.row_nnz(r) >= 2).unwrap();
        let old_cols = m.row(row).0.to_vec();
        let n = old_cols.len();
        let new_cols: Vec<u32> = (0..60u32).filter(|c| !old_cols.contains(c)).take(n).collect();
        let vals: Vec<f64> = (0..n).map(|i| 0.5 * i as f64 - 1.0).collect();
        let delta = MatrixDelta::new().replace_row(row, new_cols, vals);
        let mut eng = NnzSplitEngine::new(m.clone(), 7);
        eng.update(&delta).unwrap();
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(60, 5);
        let mut y = vec![0.0; 40];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 40];
        mutated.spmv(&x, &mut expect);
        assert!(allclose(&y, &expect, 1e-12, 1e-12), "pattern-delta spmv diverged");
    }
}

//! Mixed fixed/competitive block scheduling (paper §III-C).
//!
//! "Those who are capable work harder": the block list is split into a
//! **fixed** prefix — statically chunked so each worker gets an equal
//! number of blocks, contiguous in column-major order (blocks of the same
//! block-column share a vector segment, the shared-memory reuse argument)
//! — and a **competitive** tail. A worker that finishes its fixed quota
//! takes a *ticket* (atomic fetch-add — the paper's ticket lock) and
//! executes the corresponding competitive block, repeating until the tail
//! is exhausted. Scheduling is therefore driven by *actual execution
//! time*, not by nnz estimates.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A mixed fixed/competitive schedule over `total` items.
#[derive(Clone, Debug)]
pub struct MixedSchedule {
    /// Per-worker fixed item ranges `[start, end)` over `0..fixed_end`.
    pub fixed: Vec<(usize, usize)>,
    /// Start of the competitive tail.
    pub fixed_end: usize,
    pub total: usize,
}

/// Per-worker execution statistics (tests + the competitive ablation).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerStats {
    pub fixed_done: usize,
    pub competitive_done: usize,
    /// Seconds this worker spent busy.
    pub busy_secs: f64,
}

impl WorkerStats {
    /// Fold another generation's counters into this one. A fused SpMM
    /// runs the block schedule once per tile pass (`k` split by the
    /// tile cap), so per-worker totals over the whole batch are the sum
    /// of the per-pass stats.
    pub fn absorb(&mut self, other: &WorkerStats) {
        self.fixed_done += other.fixed_done;
        self.competitive_done += other.competitive_done;
        self.busy_secs += other.busy_secs;
    }
}

/// Accumulate one tile pass's per-worker stats into the batch totals
/// (element-wise per worker; `totals` is sized on first use).
pub fn absorb_stats(totals: &mut Vec<WorkerStats>, pass: &[WorkerStats]) {
    if totals.is_empty() {
        totals.resize(pass.len(), WorkerStats::default());
    }
    assert_eq!(totals.len(), pass.len(), "worker count changed between tile passes");
    for (t, p) in totals.iter_mut().zip(pass) {
        t.absorb(p);
    }
}

/// Build the schedule: `competitive_frac` of the items (rounded) form the
/// tail; the prefix is chunked evenly (±1) across `workers` preserving
/// order.
pub fn mixed_schedule(total: usize, workers: usize, competitive_frac: f64) -> MixedSchedule {
    let workers = workers.max(1);
    let frac = competitive_frac.clamp(0.0, 1.0);
    let comp = ((total as f64) * frac).round() as usize;
    let fixed_end = total - comp.min(total);
    // equal chunks (first `rem` workers get one extra)
    let base = fixed_end / workers;
    let rem = fixed_end % workers;
    let mut fixed = Vec::with_capacity(workers);
    let mut cursor = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        fixed.push((cursor, cursor + len));
        cursor += len;
    }
    debug_assert_eq!(cursor, fixed_end);
    MixedSchedule { fixed, fixed_end, total }
}

/// Execute `work(item)` for every item under the mixed schedule, with one
/// thread per worker. Returns per-worker stats.
///
/// Exactly-once guarantee: fixed ranges partition `0..fixed_end`;
/// competitive items are claimed by `fetch_add` on the shared ticket, so
/// each ticket value is observed by exactly one worker.
pub fn run_mixed<F>(sched: &MixedSchedule, work: F) -> Vec<WorkerStats>
where
    F: Fn(usize) + Sync,
{
    let ticket = AtomicUsize::new(sched.fixed_end);
    let work = &work;
    std::thread::scope(|s| {
        let handles: Vec<_> = sched
            .fixed
            .iter()
            .map(|&(lo, hi)| {
                let ticket = &ticket;
                s.spawn(move || {
                    let t = crate::util::Timer::start();
                    let mut stats = WorkerStats::default();
                    for i in lo..hi {
                        work(i);
                        stats.fixed_done += 1;
                    }
                    loop {
                        let i = ticket.fetch_add(1, Ordering::Relaxed);
                        if i >= sched.total {
                            break;
                        }
                        work(i);
                        stats.competitive_done += 1;
                    }
                    stats.busy_secs = t.elapsed_secs();
                    stats
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn schedule_partitions_exactly() {
        let s = mixed_schedule(100, 7, 0.25);
        assert_eq!(s.fixed_end, 75);
        let mut covered = vec![false; 75];
        for &(lo, hi) in &s.fixed {
            for c in covered.iter_mut().take(hi).skip(lo) {
                assert!(!*c);
                *c = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // equal +-1 chunks
        let sizes: Vec<usize> = s.fixed.iter().map(|&(l, h)| h - l).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn every_item_executed_exactly_once() {
        let total = 1000;
        let counts: Vec<AtomicU32> = (0..total).map(|_| AtomicU32::new(0)).collect();
        let s = mixed_schedule(total, 8, 0.3);
        let stats = run_mixed(&s, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "item {i}");
        }
        let done: usize = stats.iter().map(|w| w.fixed_done + w.competitive_done).sum();
        assert_eq!(done, total);
    }

    #[test]
    fn competitive_absorbs_imbalance() {
        // one worker gets slow fixed items; others should steal the tail
        let total = 64;
        let s = mixed_schedule(total, 4, 0.5);
        let stats = run_mixed(&s, |i| {
            if i < 8 {
                std::thread::sleep(std::time::Duration::from_millis(3));
            }
        });
        // the slow worker (fixed items 0..8) should take fewer competitive
        // items than the sum of the others
        let slow = stats[0].competitive_done;
        let fast: usize = stats[1..].iter().map(|w| w.competitive_done).sum();
        assert!(
            fast > slow,
            "fast workers should claim more of the tail: fast={fast} slow={slow}"
        );
    }

    #[test]
    fn degenerate_schedules() {
        // all-competitive
        let s = mixed_schedule(10, 3, 1.0);
        assert_eq!(s.fixed_end, 0);
        let stats = run_mixed(&s, |_| {});
        let done: usize = stats.iter().map(|w| w.competitive_done).sum();
        assert_eq!(done, 10);
        // all-fixed
        let s = mixed_schedule(10, 3, 0.0);
        assert_eq!(s.fixed_end, 10);
        // empty
        let s = mixed_schedule(0, 3, 0.5);
        let stats = run_mixed(&s, |_| panic!("no items"));
        assert_eq!(stats.len(), 3);
    }

    #[test]
    fn stats_absorb_sums_tile_passes() {
        let s = mixed_schedule(40, 4, 0.25);
        let mut totals = Vec::new();
        for _ in 0..3 {
            let pass = run_mixed(&s, |_| {});
            absorb_stats(&mut totals, &pass);
        }
        assert_eq!(totals.len(), 4);
        let done: usize = totals.iter().map(|w| w.fixed_done + w.competitive_done).sum();
        assert_eq!(done, 3 * 40);
        // fixed quotas are static: each worker's fixed_done is 3x its chunk
        for (w, &(lo, hi)) in totals.iter().zip(&s.fixed) {
            assert_eq!(w.fixed_done, 3 * (hi - lo));
        }
    }

    #[test]
    fn more_workers_than_items() {
        let s = mixed_schedule(2, 16, 0.5);
        let counts: Vec<AtomicU32> = (0..2).map(|_| AtomicU32::new(0)).collect();
        run_mixed(&s, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }
}

//! The HBP SpMV engine — paper Algorithm 3 + the §III-C mixed schedule.
//!
//! Per block (executed by one worker = one warp): for every group, each
//! active lane starts at `begin_ptr[group] + active_rank` and walks its
//! `add_sign` chain, accumulating `data[j] * x_seg[col[j]]` (columns are
//! stored block-local, so `x_seg` is the block's vector segment — the
//! shared-memory tile of the GPU original). Results land in the block's
//! partial vector at the *pre-hash* row (`output_hash[slot]`); the
//! combine phase then reduces partials across column blocks.

use super::combine::{combine_on_pool, combine_sparse_on_pool, CombineIndex};
use super::engine::{PhaseTimes, SpmvEngine};
use super::scheduler::{mixed_schedule, MixedSchedule, WorkerStats};
use crate::preprocess::{Hbp, HbpBlock};
use crate::util::pool::WorkerPool;
use crate::util::sync::SharedMut;
use crate::util::Timer;

/// HBP execution engine.
pub struct HbpEngine {
    pub hbp: Hbp,
    pub threads: usize,
    /// Fraction of blocks in the competitive tail (paper default: the
    /// tail that equalizes *observed* runtime; 0.25 works well, ablated
    /// in `ablation_competitive`).
    pub competitive_frac: f64,
    schedule: MixedSchedule,
    total_slots: usize,
    /// Reused partial-vector buffer (§Perf: on kron matrices the slot
    /// space is several times the matrix rows — the paper's own storage
    /// blow-up — and re-allocating it per call dominated SpMV time).
    /// Zero-init is unnecessary: every slot of every block is written by
    /// Algorithm 3 (zero rows store an explicit 0).
    partials: std::sync::Mutex<Vec<f64>>,
    /// Persistent workers (§Perf: per-call thread spawns dominated both
    /// phases at small scales; see `util::pool`).
    pool: WorkerPool,
    /// Sparsity-aware combine (the paper's Discussion/future-work
    /// optimization): `None` disables it (dense streaming combine).
    combine_index: Option<CombineIndex>,
}

impl HbpEngine {
    pub fn new(hbp: Hbp, threads: usize, competitive_frac: f64) -> Self {
        assert!(hbp.grid.cfg.warp <= 64, "engine lane scratch supports warp <= 64");
        let threads = threads.max(1);
        let schedule = mixed_schedule(hbp.blocks.len(), threads, competitive_frac);
        let total_slots = hbp.blocks.iter().map(|b| b.nrows).sum();
        let combine_index = CombineIndex::build(&hbp);
        // the index only pays off when some blocks take the sparse path
        let combine_index =
            (combine_index.sparse_fraction() > 0.0).then_some(combine_index);
        HbpEngine {
            hbp,
            threads,
            competitive_frac,
            schedule,
            total_slots,
            partials: std::sync::Mutex::new(Vec::new()),
            pool: WorkerPool::new(threads),
            combine_index,
        }
    }

    /// Disable the sparsity-aware combine (ablation / A-B comparison).
    pub fn with_dense_combine(mut self) -> Self {
        self.combine_index = None;
        self
    }

    /// Compute one block's partial vector into `out[0..nrows]`
    /// (Algorithm 3, all groups of the block).
    ///
    /// §Perf: instead of each lane chasing its `add_sign` chain (strided
    /// reads), the group's elements are consumed **linearly in storage
    /// order** — HBP's round-major layout means round `k` holds the
    /// `k`-th element of every live lane consecutively, so one forward
    /// walk with a live-lane list computes all lanes at streaming
    /// bandwidth (the CPU analog of the layout's GPU coalescing).
    /// `add_sign == -1` is used only as the lane-retire marker.
    #[inline]
    pub(crate) fn block_spmv(hbp: &Hbp, b: &HbpBlock, x: &[f64], out: &mut [f64]) {
        let warp = hbp.grid.cfg.warp;
        let (cs, _) = hbp.grid.col_range(b.bj as usize);
        let x_seg = &x[cs..];
        // lane accumulators + live list, reused across groups
        let mut acc = [0.0f64; 64];
        let mut live: [u16; 64] = [0; 64];
        debug_assert!(warp <= 64, "warp larger than lane scratch");
        for g in 0..b.ngroups {
            let slot_lo = g * warp;
            let slot_hi = ((g + 1) * warp).min(b.nrows);
            let mut j = hbp.begin_ptr[b.group_start + g];

            // collect active lanes in slot order; zero rows emit 0 now
            let mut n_live = 0usize;
            for s in slot_lo..slot_hi {
                let orig = hbp.output_hash[b.slot_start + s] as usize;
                if hbp.zero_row[b.slot_start + s] == -1 {
                    out[orig] = 0.0; // Algorithm 3 line 5
                } else {
                    live[n_live] = s as u16;
                    acc[n_live] = 0.0;
                    n_live += 1;
                }
            }

            // round-by-round linear walk; retire lanes whose element is
            // marked -1 (compacting the live list in place)
            while n_live > 0 {
                let mut w = 0usize;
                for r in 0..n_live {
                    let sum = acc[r]
                        + hbp.data[j] * x_seg[hbp.col[j] as usize];
                    let last = hbp.add_sign[j] == -1;
                    j += 1;
                    if last {
                        let s = live[r] as usize;
                        out[hbp.output_hash[b.slot_start + s] as usize] = sum;
                    } else {
                        acc[w] = sum;
                        live[w] = live[r];
                        w += 1;
                    }
                }
                n_live = w;
            }
        }
    }

    /// Public wrapper over [`Self::block_spmv`] for external harnesses
    /// (the atomic-write ablation bench reimplements the write phase).
    pub fn block_spmv_public(hbp: &Hbp, b: &HbpBlock, x: &[f64], out: &mut [f64]) {
        Self::block_spmv(hbp, b, x, out)
    }

    /// Run the SpMV phase only, returning per-worker stats (used by the
    /// competitive-fraction ablation and the Fig. 9 breakdown).
    pub fn spmv_partials(&self, x: &[f64], partials: &mut [f64]) -> Vec<WorkerStats> {
        assert_eq!(partials.len(), self.total_slots);
        let hbp = &self.hbp;
        let shared = SharedMut::new(partials);
        self.pool.run_mixed(&self.schedule, |bidx| {
            let b = &hbp.blocks[bidx];
            // SAFETY: each block owns the disjoint slot range
            // [slot_start, slot_start + nrows); the scheduler guarantees
            // exactly-once execution per block.
            let out = unsafe { shared.slice_mut(b.slot_start, b.nrows) };
            Self::block_spmv(hbp, b, x, out);
        })
    }

    pub fn total_slots(&self) -> usize {
        self.total_slots
    }
}

impl SpmvEngine for HbpEngine {
    fn name(&self) -> &str {
        "hbp"
    }
    fn rows(&self) -> usize {
        self.hbp.rows
    }
    fn cols(&self) -> usize {
        self.hbp.cols
    }
    fn nnz(&self) -> usize {
        self.hbp.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.hbp.cols);
        assert_eq!(y.len(), self.hbp.rows);
        let mut partials = self.partials.lock().unwrap();
        partials.resize(self.total_slots, 0.0);
        let t = Timer::start();
        self.spmv_partials(x, &mut partials);
        let spmv_secs = t.elapsed_secs();
        let t = Timer::start();
        match &self.combine_index {
            Some(idx) => combine_sparse_on_pool(&self.hbp, idx, &partials, y, &self.pool),
            None => combine_on_pool(&self.hbp, &partials, y, &self.pool),
        }
        PhaseTimes { spmv: spmv_secs, combine: t.elapsed_secs() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;
    use crate::partition::PartitionConfig;
    use crate::preprocess::{build_hbp, build_hbp_with, DpReorder, IdentityReorder, SortReorder};

    fn check_engine(m: &crate::formats::Csr, threads: usize, frac: f64) {
        let x = random::vector(m.cols, 42);
        let mut expect = vec![0.0; m.rows];
        m.spmv(&x, &mut expect);
        let hbp = build_hbp(m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, threads, frac);
        let mut y = vec![0.0; m.rows];
        eng.spmv(&x, &mut y);
        assert!(
            allclose(&y, &expect, 1e-10, 1e-12),
            "threads={threads} frac={frac}"
        );
    }

    #[test]
    fn matches_csr_on_random_matrices() {
        for seed in 0..4 {
            let m = random::power_law_rows(150, 180, 2.0, 40, seed);
            check_engine(&m, 1, 0.0);
            check_engine(&m, 4, 0.25);
            check_engine(&m, 8, 1.0);
        }
    }

    #[test]
    fn matches_csr_on_suite_ci() {
        for id in ["m1", "m3", "m4", "m8"] {
            let (_, m) = crate::gen::matrix_by_id(id, crate::gen::Scale::Ci).unwrap();
            let x = random::vector(m.cols, 7);
            let mut expect = vec![0.0; m.rows];
            m.spmv(&x, &mut expect);
            let hbp = build_hbp(&m, PartitionConfig::default());
            let eng = HbpEngine::new(hbp, 4, 0.25);
            let mut y = vec![0.0; m.rows];
            eng.spmv(&x, &mut y);
            assert!(allclose(&y, &expect, 1e-9, 1e-11), "{id}");
        }
    }

    #[test]
    fn all_reorder_strategies_agree() {
        let m = random::power_law_rows(120, 100, 2.2, 30, 17);
        let x = random::vector(100, 5);
        let mut expect = vec![0.0; 120];
        m.spmv(&x, &mut expect);
        for r in [
            &IdentityReorder as &dyn crate::preprocess::Reorder,
            &SortReorder,
            &DpReorder::default(),
        ] {
            let hbp = build_hbp_with(&m, PartitionConfig::test_small(), r);
            let eng = HbpEngine::new(hbp, 3, 0.5);
            let mut y = vec![0.0; 120];
            eng.spmv(&x, &mut y);
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{}", r.name());
        }
    }

    #[test]
    fn sparse_and_dense_combine_agree_end_to_end() {
        // zero-row-heavy matrix: the sparse combine path activates
        let mut lens = vec![0usize; 300];
        for i in (0..300).step_by(5) {
            lens[i] = 8;
        }
        let m = random::with_row_lengths(&lens, 200, 23);
        let x = random::vector(200, 4);
        let cfg = PartitionConfig::test_small();
        let sparse_eng = HbpEngine::new(build_hbp(&m, cfg), 3, 0.25);
        let dense_eng = HbpEngine::new(build_hbp(&m, cfg), 3, 0.25).with_dense_combine();
        let mut ys = vec![0.0; 300];
        let mut yd = vec![0.0; 300];
        sparse_eng.spmv(&x, &mut ys);
        dense_eng.spmv(&x, &mut yd);
        assert_eq!(ys, yd, "sparse combine diverged from dense");
        let mut expect = vec![0.0; 300];
        m.spmv(&x, &mut expect);
        assert!(allclose(&ys, &expect, 1e-10, 1e-12));
    }

    #[test]
    fn zero_rows_produce_zero_output() {
        let m = random::with_row_lengths(&[3, 0, 0, 5, 0, 2], 16, 9);
        let x = random::vector(16, 2);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, 2, 0.5);
        let mut y = vec![7.0; 6];
        eng.spmv(&x, &mut y);
        assert_eq!(y[1], 0.0);
        assert_eq!(y[2], 0.0);
        assert_eq!(y[4], 0.0);
    }

    #[test]
    fn phase_times_populated() {
        let m = random::uniform(200, 200, 0.05, 3);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, 2, 0.25);
        let x = random::vector(200, 1);
        let mut y = vec![0.0; 200];
        let p = eng.spmv_phases(&x, &mut y);
        assert!(p.spmv > 0.0);
        assert!(p.combine > 0.0);
    }
}

//! The HBP SpMV engine — paper Algorithm 3 + the §III-C mixed schedule.
//!
//! Per block (executed by one worker = one warp): for every group, each
//! active lane starts at `begin_ptr[group] + active_rank` and walks its
//! `add_sign` chain, accumulating `data[j] * x_seg[col[j]]` (columns are
//! stored block-local, so `x_seg` is the block's vector segment — the
//! shared-memory tile of the GPU original). Results land in the block's
//! partial vector at the *pre-hash* row (`output_hash[slot]`); the
//! combine phase then reduces partials across column blocks.

use super::combine::{
    combine_on_pool, combine_sparse_on_pool, combine_sparse_tile_on_pool, combine_tile_on_pool,
    CombineIndex,
};
use super::engine::{check_spmm_dims, PhaseTimes, SpmvEngine, SPMM_TILE};
use super::scheduler::{absorb_stats, mixed_schedule, MixedSchedule, WorkerStats};
use crate::formats::Csr;
use crate::partition::{block_map, BlockMap, PartitionConfig};
use crate::preprocess::{
    build_hbp_updatable_profiled, BuildProfile, Hbp, HbpBlock, MatrixDelta, Reorder, UpdateReport,
};
use crate::util::pool::WorkerPool;
use crate::util::sync::SharedMut;
use crate::util::Timer;

/// What [`HbpEngine::update`] needs to repair the resident HBP without
/// a re-upload: the source CSR (kept in lock-step with the HBP), the
/// plan's block map, and the reorder strategy for fallback rebuilds.
struct UpdateSource {
    m: Csr,
    map: BlockMap,
    reorder: Box<dyn Reorder + Send + Sync>,
}

/// HBP execution engine.
pub struct HbpEngine {
    pub hbp: Hbp,
    pub threads: usize,
    /// Fraction of blocks in the competitive tail (paper default: the
    /// tail that equalizes *observed* runtime; 0.25 works well, ablated
    /// in `ablation_competitive`).
    pub competitive_frac: f64,
    schedule: MixedSchedule,
    total_slots: usize,
    /// Reused partial-vector buffer (§Perf: on kron matrices the slot
    /// space is several times the matrix rows — the paper's own storage
    /// blow-up — and re-allocating it per call dominated SpMV time).
    /// Zero-init is unnecessary: every slot of every block is written by
    /// Algorithm 3 (zero rows store an explicit 0).
    partials: std::sync::Mutex<Vec<f64>>,
    /// Persistent workers (§Perf: per-call thread spawns dominated both
    /// phases at small scales; see `util::pool`).
    pool: WorkerPool,
    /// Sparsity-aware combine (the paper's Discussion/future-work
    /// optimization): `None` disables it (dense streaming combine).
    combine_index: Option<CombineIndex>,
    /// Phase breakdown of the construction that produced `hbp`, when the
    /// build ran through a profiled entry point ([`HbpEngine::new_updatable`]).
    build_profile: Option<BuildProfile>,
    /// Present only for engines built through
    /// [`HbpEngine::new_updatable`]; [`HbpEngine::update`] requires it.
    update_src: Option<UpdateSource>,
}

impl HbpEngine {
    pub fn new(hbp: Hbp, threads: usize, competitive_frac: f64) -> Self {
        assert!(hbp.grid.cfg.warp <= 64, "engine lane scratch supports warp <= 64");
        let threads = threads.max(1);
        let schedule = mixed_schedule(hbp.blocks.len(), threads, competitive_frac);
        let total_slots = hbp.blocks.iter().map(|b| b.nrows).sum();
        let combine_index = CombineIndex::build(&hbp);
        // the index only pays off when some blocks take the sparse path
        let combine_index =
            (combine_index.sparse_fraction() > 0.0).then_some(combine_index);
        HbpEngine {
            hbp,
            threads,
            competitive_frac,
            schedule,
            total_slots,
            partials: std::sync::Mutex::new(Vec::new()),
            pool: WorkerPool::new(threads),
            combine_index,
            build_profile: None,
            update_src: None,
        }
    }

    /// Phase wall-times of the build that produced this engine's HBP;
    /// `None` for engines handed a pre-built [`Hbp`].
    pub fn build_profile(&self) -> Option<BuildProfile> {
        self.build_profile
    }

    /// Build an engine that **retains its source** (CSR + plan map +
    /// reorder strategy) so [`HbpEngine::update`] can repair the
    /// resident HBP in place instead of requiring a re-registration.
    /// Costs one CSR copy held alongside the HBP — the serving-path
    /// trade the coordinator makes for every hosted matrix.
    pub fn new_updatable(
        m: Csr,
        cfg: PartitionConfig,
        reorder: Box<dyn Reorder + Send + Sync>,
        threads: usize,
        competitive_frac: f64,
    ) -> Self {
        let (hbp, map, profile) = build_hbp_updatable_profiled(&m, cfg, reorder.as_ref(), threads);
        let mut eng = HbpEngine::new(hbp, threads, competitive_frac);
        eng.build_profile = Some(profile);
        eng.update_src = Some(UpdateSource { m, map, reorder });
        eng
    }

    /// Apply a delta to the resident (CSR, HBP) pair. Pattern-preserving
    /// deltas re-fill only the touched blocks' slices and leave every
    /// derived engine structure (schedule, slot count, combine index)
    /// valid by construction; a pattern-changing delta rebuilds the HBP
    /// and re-derives them. Errors if the engine was built without
    /// [`HbpEngine::new_updatable`] or the delta is invalid (in which
    /// case nothing is modified).
    pub fn update(&mut self, delta: &MatrixDelta) -> anyhow::Result<UpdateReport> {
        let HbpEngine { hbp, update_src, threads, .. } = self;
        let src = update_src.as_mut().ok_or_else(|| {
            anyhow::anyhow!("HBP engine holds no update source (use HbpEngine::new_updatable)")
        })?;
        let reorder: &(dyn Reorder + Sync) = src.reorder.as_ref();
        let report = hbp.apply_delta(&mut src.m, &src.map, delta, reorder, *threads)?;
        if report.full_rebuild {
            src.map = block_map(&src.m, &hbp.grid);
            self.reinit_derived();
        }
        Ok(report)
    }

    /// Source CSR of an updatable engine (kept in lock-step with the
    /// HBP by [`HbpEngine::update`]).
    pub fn source(&self) -> Option<&Csr> {
        self.update_src.as_ref().map(|s| &s.m)
    }

    /// Re-derive the structure-dependent caches after the HBP's block
    /// list changed (full-rebuild fallback).
    fn reinit_derived(&mut self) {
        self.schedule = mixed_schedule(self.hbp.blocks.len(), self.threads, self.competitive_frac);
        self.total_slots = self.hbp.blocks.iter().map(|b| b.nrows).sum();
        let combine_index = CombineIndex::build(&self.hbp);
        self.combine_index = (combine_index.sparse_fraction() > 0.0).then_some(combine_index);
    }

    /// Disable the sparsity-aware combine (ablation / A-B comparison).
    pub fn with_dense_combine(mut self) -> Self {
        self.combine_index = None;
        self
    }

    /// Compute one block's partial vector into `out[0..nrows]`
    /// (Algorithm 3, all groups of the block).
    ///
    /// §Perf: instead of each lane chasing its `add_sign` chain (strided
    /// reads), the group's elements are consumed **linearly in storage
    /// order** — HBP's round-major layout means round `k` holds the
    /// `k`-th element of every live lane consecutively, so one forward
    /// walk with a live-lane list computes all lanes at streaming
    /// bandwidth (the CPU analog of the layout's GPU coalescing).
    /// `add_sign == -1` is used only as the lane-retire marker.
    #[inline]
    pub(crate) fn block_spmv(hbp: &Hbp, b: &HbpBlock, x: &[f64], out: &mut [f64]) {
        let warp = hbp.grid.cfg.warp;
        let (cs, _) = hbp.grid.col_range(b.bj as usize);
        let x_seg = &x[cs..];
        // lane accumulators + live list, reused across groups
        let mut acc = [0.0f64; 64];
        let mut live: [u16; 64] = [0; 64];
        debug_assert!(warp <= 64, "warp larger than lane scratch");
        for g in 0..b.ngroups {
            let slot_lo = g * warp;
            let slot_hi = ((g + 1) * warp).min(b.nrows);
            let mut j = hbp.begin_ptr[b.group_start + g];

            // collect active lanes in slot order; zero rows emit 0 now
            let mut n_live = 0usize;
            for s in slot_lo..slot_hi {
                let orig = hbp.output_hash[b.slot_start + s] as usize;
                if hbp.zero_row[b.slot_start + s] == -1 {
                    out[orig] = 0.0; // Algorithm 3 line 5
                } else {
                    live[n_live] = s as u16;
                    acc[n_live] = 0.0;
                    n_live += 1;
                }
            }

            // round-by-round linear walk; retire lanes whose element is
            // marked -1 (compacting the live list in place)
            while n_live > 0 {
                let mut w = 0usize;
                for r in 0..n_live {
                    let sum = acc[r]
                        + hbp.data[j] * x_seg[hbp.col[j] as usize];
                    let last = hbp.add_sign[j] == -1;
                    j += 1;
                    if last {
                        let s = live[r] as usize;
                        out[hbp.output_hash[b.slot_start + s] as usize] = sum;
                    } else {
                        acc[w] = sum;
                        live[w] = live[r];
                        w += 1;
                    }
                }
                n_live = w;
            }
        }
    }

    /// Public wrapper over `Self::block_spmv` for external harnesses
    /// (the atomic-write ablation bench reimplements the write phase).
    pub fn block_spmv_public(hbp: &Hbp, b: &HbpBlock, x: &[f64], out: &mut [f64]) {
        Self::block_spmv(hbp, b, x, out)
    }

    /// Fused multi-vector variant of [`Self::block_spmv`]: one linear
    /// walk of the block's elements computes a whole tile of products.
    ///
    /// Each element's `(col, data, add_sign)` triple is loaded once and
    /// applied to every vector in the tile — the k-way reuse of the
    /// expensive stream that same-matrix batching buys. `out` is the
    /// block's **column-major partials tile**: vector `v`'s partial for
    /// local row `r` lands at `out[r * tile + v]`, so the per-round
    /// inner loop writes contiguously. The x-tile (`tile` block-column
    /// segments of the inputs) is what stays cache-resident per pass —
    /// the reason callers cap `tile` at [`SPMM_TILE`].
    #[inline]
    pub(crate) fn block_spmm(hbp: &Hbp, b: &HbpBlock, xs: &[&[f64]], out: &mut [f64]) {
        let tile = xs.len();
        debug_assert!(tile >= 1 && tile <= SPMM_TILE, "tile {tile} exceeds cap");
        let warp = hbp.grid.cfg.warp;
        let (cs, _) = hbp.grid.col_range(b.bj as usize);
        // the cache-resident x-tile: this block-column's segment of
        // every vector in the pass
        let mut x_seg: [&[f64]; SPMM_TILE] = [&[]; SPMM_TILE];
        for (seg, x) in x_seg.iter_mut().zip(xs) {
            *seg = &x[cs..];
        }
        // lane accumulators (tile-strided) + live list, reused per group
        let mut acc = [0.0f64; 64 * SPMM_TILE];
        let mut live: [u16; 64] = [0; 64];
        debug_assert!(warp <= 64, "warp larger than lane scratch");
        for g in 0..b.ngroups {
            let slot_lo = g * warp;
            let slot_hi = ((g + 1) * warp).min(b.nrows);
            let mut j = hbp.begin_ptr[b.group_start + g];

            let mut n_live = 0usize;
            for s in slot_lo..slot_hi {
                let orig = hbp.output_hash[b.slot_start + s] as usize;
                if hbp.zero_row[b.slot_start + s] == -1 {
                    out[orig * tile..(orig + 1) * tile].fill(0.0); // Algorithm 3 line 5
                } else {
                    live[n_live] = s as u16;
                    acc[n_live * tile..(n_live + 1) * tile].fill(0.0);
                    n_live += 1;
                }
            }

            // round-by-round linear walk as in block_spmv, with the
            // element's (data, col) amortized over the whole tile
            while n_live > 0 {
                let mut w = 0usize;
                for r in 0..n_live {
                    let a = hbp.data[j];
                    let c = hbp.col[j] as usize;
                    let last = hbp.add_sign[j] == -1;
                    j += 1;
                    if last {
                        let s = live[r] as usize;
                        let orig = hbp.output_hash[b.slot_start + s] as usize;
                        for v in 0..tile {
                            out[orig * tile + v] = acc[r * tile + v] + a * x_seg[v][c];
                        }
                    } else {
                        for v in 0..tile {
                            acc[w * tile + v] = acc[r * tile + v] + a * x_seg[v][c];
                        }
                        live[w] = live[r];
                        w += 1;
                    }
                }
                n_live = w;
            }
        }
    }

    /// Run the SpMV phase only, returning per-worker stats (used by the
    /// competitive-fraction ablation and the Fig. 9 breakdown).
    pub fn spmv_partials(&self, x: &[f64], partials: &mut [f64]) -> Vec<WorkerStats> {
        assert_eq!(partials.len(), self.total_slots);
        let hbp = &self.hbp;
        let shared = SharedMut::new(partials);
        self.pool.run_mixed(&self.schedule, |bidx| {
            let b = &hbp.blocks[bidx];
            // SAFETY: each block owns the disjoint slot range
            // [slot_start, slot_start + nrows); the scheduler guarantees
            // exactly-once execution per block.
            let out = unsafe { shared.slice_mut(b.slot_start, b.nrows) };
            Self::block_spmv(hbp, b, x, out);
        })
    }

    /// Run the fused SpMM phase for one tile pass (`xs.len() <=
    /// SPMM_TILE` vectors), writing the column-major partials tile.
    /// Same mixed schedule and per-worker stats as [`Self::spmv_partials`],
    /// one schedule traversal for the whole tile.
    pub fn spmm_partials(&self, xs: &[&[f64]], partials: &mut [f64]) -> Vec<WorkerStats> {
        let tile = xs.len();
        assert!((1..=SPMM_TILE).contains(&tile), "tile {tile} out of range");
        assert_eq!(partials.len(), self.total_slots * tile);
        let hbp = &self.hbp;
        let shared = SharedMut::new(partials);
        self.pool.run_mixed(&self.schedule, |bidx| {
            let b = &hbp.blocks[bidx];
            // SAFETY: each block owns the disjoint tile-strided slot
            // range; the scheduler guarantees exactly-once execution.
            let out = unsafe { shared.slice_mut(b.slot_start * tile, b.nrows * tile) };
            Self::block_spmm(hbp, b, xs, out);
        })
    }

    /// Fused SpMM over the whole batch: `k` is split into passes of at
    /// most [`SPMM_TILE`] vectors; each pass makes one traversal of the
    /// block schedule and one tile combine. Returns per-worker stats
    /// accumulated across the passes (the batch-level analog of
    /// [`Self::spmv_partials`]'s per-call stats).
    pub fn spmm_tiled(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) -> Vec<WorkerStats> {
        check_spmm_dims("hbp", self.hbp.rows, self.hbp.cols, xs, ys);
        let mut totals: Vec<WorkerStats> = Vec::new();
        let mut partials = self.partials.lock().unwrap();
        let mut lo = 0;
        while lo < xs.len() {
            let hi = (lo + SPMM_TILE).min(xs.len());
            let tile = hi - lo;
            partials.resize(self.total_slots * tile, 0.0);
            let x_tile: Vec<&[f64]> = xs[lo..hi].iter().map(|x| x.as_slice()).collect();
            let pass = self.spmm_partials(&x_tile, &mut partials[..self.total_slots * tile]);
            absorb_stats(&mut totals, &pass);
            let y_tile = &mut ys[lo..hi];
            match &self.combine_index {
                Some(idx) => {
                    combine_sparse_tile_on_pool(&self.hbp, idx, &partials, y_tile, &self.pool)
                }
                None => combine_tile_on_pool(&self.hbp, &partials, y_tile, &self.pool),
            }
            lo = hi;
        }
        totals
    }

    pub fn total_slots(&self) -> usize {
        self.total_slots
    }
}

impl SpmvEngine for HbpEngine {
    fn name(&self) -> &str {
        "hbp"
    }
    fn rows(&self) -> usize {
        self.hbp.rows
    }
    fn cols(&self) -> usize {
        self.hbp.cols
    }
    fn nnz(&self) -> usize {
        self.hbp.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.hbp.cols);
        assert_eq!(y.len(), self.hbp.rows);
        let mut partials = self.partials.lock().unwrap();
        partials.resize(self.total_slots, 0.0);
        let t = Timer::start();
        self.spmv_partials(x, &mut partials);
        let spmv_secs = t.elapsed_secs();
        let t = Timer::start();
        match &self.combine_index {
            Some(idx) => combine_sparse_on_pool(&self.hbp, idx, &partials, y, &self.pool),
            None => combine_on_pool(&self.hbp, &partials, y, &self.pool),
        }
        PhaseTimes { spmv: spmv_secs, combine: t.elapsed_secs() }
    }

    /// Fused SpMM: one pass over the block schedule per tile of at most
    /// [`SPMM_TILE`] vectors (see [`HbpEngine::spmm_tiled`]).
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        check_spmm_dims("hbp", self.hbp.rows, self.hbp.cols, xs, ys);
        if xs.len() < 2 {
            // a single vector gains nothing from the tile machinery
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                self.spmv(x, y);
            }
            return;
        }
        self.spmm_tiled(xs, ys);
    }

    fn update(&mut self, delta: &MatrixDelta) -> anyhow::Result<UpdateReport> {
        HbpEngine::update(self, delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;
    use crate::partition::PartitionConfig;
    use crate::preprocess::{build_hbp, build_hbp_with, DpReorder, IdentityReorder, SortReorder};

    fn check_engine(m: &crate::formats::Csr, threads: usize, frac: f64) {
        let x = random::vector(m.cols, 42);
        let mut expect = vec![0.0; m.rows];
        m.spmv(&x, &mut expect);
        let hbp = build_hbp(m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, threads, frac);
        let mut y = vec![0.0; m.rows];
        eng.spmv(&x, &mut y);
        assert!(
            allclose(&y, &expect, 1e-10, 1e-12),
            "threads={threads} frac={frac}"
        );
    }

    #[test]
    fn matches_csr_on_random_matrices() {
        for seed in 0..4 {
            let m = random::power_law_rows(150, 180, 2.0, 40, seed);
            check_engine(&m, 1, 0.0);
            check_engine(&m, 4, 0.25);
            check_engine(&m, 8, 1.0);
        }
    }

    #[test]
    fn matches_csr_on_suite_ci() {
        for id in ["m1", "m3", "m4", "m8"] {
            let (_, m) = crate::gen::matrix_by_id(id, crate::gen::Scale::Ci).unwrap();
            let x = random::vector(m.cols, 7);
            let mut expect = vec![0.0; m.rows];
            m.spmv(&x, &mut expect);
            let hbp = build_hbp(&m, PartitionConfig::default());
            let eng = HbpEngine::new(hbp, 4, 0.25);
            let mut y = vec![0.0; m.rows];
            eng.spmv(&x, &mut y);
            assert!(allclose(&y, &expect, 1e-9, 1e-11), "{id}");
        }
    }

    #[test]
    fn all_reorder_strategies_agree() {
        let m = random::power_law_rows(120, 100, 2.2, 30, 17);
        let x = random::vector(100, 5);
        let mut expect = vec![0.0; 120];
        m.spmv(&x, &mut expect);
        for r in [
            &IdentityReorder as &dyn crate::preprocess::Reorder,
            &SortReorder,
            &DpReorder::default(),
        ] {
            let hbp = build_hbp_with(&m, PartitionConfig::test_small(), r);
            let eng = HbpEngine::new(hbp, 3, 0.5);
            let mut y = vec![0.0; 120];
            eng.spmv(&x, &mut y);
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{}", r.name());
        }
    }

    #[test]
    fn sparse_and_dense_combine_agree_end_to_end() {
        // zero-row-heavy matrix: the sparse combine path activates
        let mut lens = vec![0usize; 300];
        for i in (0..300).step_by(5) {
            lens[i] = 8;
        }
        let m = random::with_row_lengths(&lens, 200, 23);
        let x = random::vector(200, 4);
        let cfg = PartitionConfig::test_small();
        let sparse_eng = HbpEngine::new(build_hbp(&m, cfg), 3, 0.25);
        let dense_eng = HbpEngine::new(build_hbp(&m, cfg), 3, 0.25).with_dense_combine();
        let mut ys = vec![0.0; 300];
        let mut yd = vec![0.0; 300];
        sparse_eng.spmv(&x, &mut ys);
        dense_eng.spmv(&x, &mut yd);
        assert_eq!(ys, yd, "sparse combine diverged from dense");
        let mut expect = vec![0.0; 300];
        m.spmv(&x, &mut expect);
        assert!(allclose(&ys, &expect, 1e-10, 1e-12));
    }

    #[test]
    fn zero_rows_produce_zero_output() {
        let m = random::with_row_lengths(&[3, 0, 0, 5, 0, 2], 16, 9);
        let x = random::vector(16, 2);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, 2, 0.5);
        let mut y = vec![7.0; 6];
        eng.spmv(&x, &mut y);
        assert_eq!(y[1], 0.0);
        assert_eq!(y[2], 0.0);
        assert_eq!(y[4], 0.0);
    }

    #[test]
    fn updatable_engine_tracks_deltas() {
        use crate::preprocess::{HashReorder, MatrixDelta};
        let m = random::power_law_rows(150, 120, 2.0, 30, 19);
        let mut eng = HbpEngine::new_updatable(
            m.clone(),
            PartitionConfig::test_small(),
            Box::new(HashReorder::default()),
            3,
            0.25,
        );
        let x = random::vector(120, 8);
        let row = (0..150).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let report = eng.update(&MatrixDelta::new().scale_row(row, -3.0)).unwrap();
        assert!(!report.full_rebuild);
        assert!(report.blocks_touched >= 1);
        // engine output matches a CSR oracle on the mutated matrix
        let mut expect = vec![0.0; 150];
        eng.source().unwrap().spmv(&x, &mut expect);
        let mut y = vec![0.0; 150];
        eng.spmv(&x, &mut y);
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
        // and differs from the pre-update product in the scaled row
        let mut before = vec![0.0; 150];
        m.spmv(&x, &mut before);
        assert!((y[row] - before[row]).abs() > 0.0 || before[row] == 0.0);
    }

    #[test]
    fn updatable_engine_survives_pattern_fallback() {
        use crate::preprocess::{HashReorder, MatrixDelta};
        let m = random::power_law_rows(100, 150, 2.0, 30, 23);
        let mut eng = HbpEngine::new_updatable(
            m.clone(),
            PartitionConfig::test_small(),
            Box::new(HashReorder::default()),
            2,
            0.25,
        );
        let row = (0..100).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let n = m.row_nnz(row);
        let old = m.row(row).0.to_vec();
        let new: Vec<u32> = (0..150u32).filter(|c| !old.contains(c)).take(n).collect();
        let report = eng
            .update(&MatrixDelta::new().replace_row(row, new, vec![2.0; n]))
            .unwrap();
        assert!(report.full_rebuild);
        // engine still serves correctly after the rebuild path
        let x = random::vector(150, 2);
        let mut expect = vec![0.0; 100];
        eng.source().unwrap().spmv(&x, &mut expect);
        let mut y = vec![0.0; 100];
        eng.spmv(&x, &mut y);
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
        // a follow-up partial update still works against the refreshed map
        let r2 = eng.update(&MatrixDelta::new().scale_row(row, 0.5)).unwrap();
        assert!(!r2.full_rebuild);
    }

    #[test]
    fn non_updatable_engine_refuses_updates() {
        use crate::preprocess::MatrixDelta;
        let m = random::uniform(20, 20, 0.3, 4);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let mut eng = HbpEngine::new(hbp, 2, 0.25);
        assert!(eng.update(&MatrixDelta::new().zero_row(0)).is_err());
    }

    #[test]
    fn fused_spmm_matches_repeated_spmv_across_tile_boundary() {
        let m = random::power_law_rows(180, 140, 2.0, 35, 29);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, 3, 0.25);
        // k straddles the tile cap so the multi-pass path runs
        let k = SPMM_TILE + 2;
        let xs: Vec<Vec<f64>> = (0..k).map(|i| random::vector(140, i as u64)).collect();
        let mut ys: Vec<Vec<f64>> = vec![vec![0.0; 180]; k];
        eng.spmm(&xs, &mut ys);
        for (x, y) in xs.iter().zip(&ys) {
            let mut expect = vec![0.0; 180];
            eng.spmv(x, &mut expect);
            assert!(allclose(y, &expect, 1e-12, 1e-12));
        }
    }

    #[test]
    fn spmm_tiled_stats_cover_every_block_once_per_pass() {
        let m = random::power_law_rows(150, 150, 2.0, 30, 31);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let blocks = hbp.blocks.len();
        let eng = HbpEngine::new(hbp, 4, 0.25);
        let k = 2 * SPMM_TILE + 3; // three passes
        let xs: Vec<Vec<f64>> = (0..k).map(|i| random::vector(150, i as u64)).collect();
        let mut ys: Vec<Vec<f64>> = vec![vec![0.0; 150]; k];
        let stats = eng.spmm_tiled(&xs, &mut ys);
        assert_eq!(stats.len(), 4);
        let done: usize = stats.iter().map(|w| w.fixed_done + w.competitive_done).sum();
        assert_eq!(done, 3 * blocks, "each pass must execute every block exactly once");
    }

    #[test]
    fn fused_spmm_sparse_and_dense_combine_agree() {
        // zero-row-heavy matrix: the sparse tile combine activates
        let mut lens = vec![0usize; 300];
        for i in (0..300).step_by(5) {
            lens[i] = 8;
        }
        let m = random::with_row_lengths(&lens, 200, 23);
        let cfg = PartitionConfig::test_small();
        let sparse_eng = HbpEngine::new(build_hbp(&m, cfg), 3, 0.25);
        let dense_eng = HbpEngine::new(build_hbp(&m, cfg), 3, 0.25).with_dense_combine();
        let xs: Vec<Vec<f64>> = (0..4).map(|i| random::vector(200, i)).collect();
        let mut ys = vec![vec![0.0; 300]; 4];
        let mut yd = vec![vec![0.0; 300]; 4];
        sparse_eng.spmm(&xs, &mut ys);
        dense_eng.spmm(&xs, &mut yd);
        assert_eq!(ys, yd, "sparse tile combine diverged from dense");
    }

    #[test]
    fn phase_times_populated() {
        let m = random::uniform(200, 200, 0.05, 3);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let eng = HbpEngine::new(hbp, 2, 0.25);
        let x = random::vector(200, 1);
        let mut y = vec![0.0; 200];
        let p = eng.spmv_phases(&x, &mut y);
        assert!(p.spmv > 0.0);
        assert!(p.combine > 0.0);
    }
}

//! The combine phase of two-step SpMV (paper Fig. 1): partial vectors
//! produced per (row-block, col-block) are summed into the result rows.
//!
//! Parallelism: workers own disjoint *row-blocks*, so no two threads
//! touch the same output row — no atomics needed (the paper's Discussion
//! section measured the atomic-write alternative and found it slower
//! than combining; we reproduce that in `ablation_competitive`).

use crate::preprocess::{Hbp, HbpBlock};
use crate::util::sync::SharedMut;

/// Worker body shared by the scoped-thread and pool variants: worker `w`
/// of `threads` owns row-blocks `w, w+threads, ...` (disjoint rows).
fn combine_worker(
    hbp: &Hbp,
    by_bi: &[Vec<usize>],
    partials: &[f64],
    shared: &SharedMut<'_, f64>,
    w: usize,
    threads: usize,
) {
    for bi in (w..by_bi.len()).step_by(threads) {
        let (rs, re) = hbp.grid.row_range(bi);
        if by_bi[bi].is_empty() {
            continue;
        }
        // SAFETY: row-block ranges are disjoint across workers.
        let out = unsafe { shared.slice_mut(rs, re - rs) };
        for &bidx in &by_bi[bi] {
            let b: &HbpBlock = &hbp.blocks[bidx];
            let part = &partials[b.slot_start..b.slot_start + b.nrows];
            for (o, p) in out.iter_mut().zip(part) {
                *o += p;
            }
        }
    }
}

/// Group block indices by row-block.
fn blocks_by_row_block(hbp: &Hbp) -> Vec<Vec<usize>> {
    let mut by_bi: Vec<Vec<usize>> = vec![vec![]; hbp.grid.row_blocks];
    for (i, b) in hbp.blocks.iter().enumerate() {
        by_bi[b.bi as usize].push(i);
    }
    by_bi
}

/// Sum per-block partials into `y` (scoped threads — tests and one-shot
/// callers; the engine uses [`combine_on_pool`]).
///
/// `partials` is slot-indexed per block: block `b`'s contribution to its
/// local row `r` lives at `partials[b.slot_start + r]`.
pub fn combine(hbp: &Hbp, partials: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(y.len(), hbp.rows);
    y.fill(0.0);
    if hbp.blocks.is_empty() {
        return;
    }
    let by_bi = blocks_by_row_block(hbp);
    let threads = threads.clamp(1, hbp.grid.row_blocks.max(1));
    let shared = SharedMut::new(y);
    std::thread::scope(|s| {
        for w in 0..threads {
            let shared = &shared;
            let by_bi = &by_bi;
            s.spawn(move || combine_worker(hbp, by_bi, partials, shared, w, threads));
        }
    });
}

/// [`combine`] on a persistent [`WorkerPool`] — no per-call spawns
/// (§Perf: spawn cost dominated the combine phase at small scales).
pub fn combine_on_pool(
    hbp: &Hbp,
    partials: &[f64],
    y: &mut [f64],
    pool: &crate::util::pool::WorkerPool,
) {
    assert_eq!(y.len(), hbp.rows);
    y.fill(0.0);
    if hbp.blocks.is_empty() {
        return;
    }
    let by_bi = blocks_by_row_block(hbp);
    let threads = pool.workers;
    let shared = SharedMut::new(y);
    pool.run_generation(|w, _| combine_worker(hbp, &by_bi, partials, &shared, w, threads));
}

/// Tile (fused SpMM) variant of [`combine_on_pool`]: one traversal of
/// the block list reduces a whole tile of `ys.len()` output vectors.
///
/// `partials` is the column-major partials tile written by the fused
/// block kernels: block `b`'s contribution to vector `v` at local row
/// `r` lives at `partials[(b.slot_start + r) * tile + v]`. Running the
/// combine once per tile (not once per vector) amortizes the row-block
/// bookkeeping and the partials stream across the batch.
pub fn combine_tile_on_pool(
    hbp: &Hbp,
    partials: &[f64],
    ys: &mut [Vec<f64>],
    pool: &crate::util::pool::WorkerPool,
) {
    let tile = ys.len();
    for y in ys.iter_mut() {
        assert_eq!(y.len(), hbp.rows);
        y.fill(0.0);
    }
    if hbp.blocks.is_empty() || tile == 0 {
        return;
    }
    let by_bi = blocks_by_row_block(hbp);
    let threads = pool.workers;
    let shareds: Vec<SharedMut<'_, f64>> =
        ys.iter_mut().map(|y| SharedMut::new(&mut y[..])).collect();
    pool.run_generation(|w, _| {
        for bi in (w..by_bi.len()).step_by(threads) {
            if by_bi[bi].is_empty() {
                continue;
            }
            let (rs, re) = hbp.grid.row_range(bi);
            // SAFETY: row-block ranges are disjoint across workers, and
            // the `shareds` point at distinct output vectors.
            let mut outs: Vec<&mut [f64]> =
                shareds.iter().map(|s| unsafe { s.slice_mut(rs, re - rs) }).collect();
            for &bidx in &by_bi[bi] {
                let b: &HbpBlock = &hbp.blocks[bidx];
                let part = &partials[b.slot_start * tile..(b.slot_start + b.nrows) * tile];
                for r in 0..b.nrows {
                    let row = &part[r * tile..(r + 1) * tile];
                    for (out, p) in outs.iter_mut().zip(row) {
                        out[r] += p;
                    }
                }
            }
        }
    });
}

/// Tile variant of [`combine_sparse_on_pool`]: the per-block active-row
/// lists of a [`CombineIndex`] drive one reduction over the whole tile.
pub fn combine_sparse_tile_on_pool(
    hbp: &Hbp,
    index: &CombineIndex,
    partials: &[f64],
    ys: &mut [Vec<f64>],
    pool: &crate::util::pool::WorkerPool,
) {
    let tile = ys.len();
    for y in ys.iter_mut() {
        assert_eq!(y.len(), hbp.rows);
        y.fill(0.0);
    }
    if hbp.blocks.is_empty() || tile == 0 {
        return;
    }
    let threads = pool.workers;
    let shareds: Vec<SharedMut<'_, f64>> =
        ys.iter_mut().map(|y| SharedMut::new(&mut y[..])).collect();
    pool.run_generation(|w, _| {
        for bi in (w..index.by_bi.len()).step_by(threads) {
            if index.by_bi[bi].is_empty() {
                continue;
            }
            let (rs, re) = hbp.grid.row_range(bi);
            // SAFETY: as in `combine_tile_on_pool`.
            let mut outs: Vec<&mut [f64]> =
                shareds.iter().map(|s| unsafe { s.slice_mut(rs, re - rs) }).collect();
            for &bidx in &index.by_bi[bi] {
                let b: &HbpBlock = &hbp.blocks[bidx];
                let part = &partials[b.slot_start * tile..(b.slot_start + b.nrows) * tile];
                match &index.active[bidx] {
                    Some(rows) => {
                        for &orig in rows {
                            let r = orig as usize;
                            let row = &part[r * tile..(r + 1) * tile];
                            for (out, p) in outs.iter_mut().zip(row) {
                                out[r] += p;
                            }
                        }
                    }
                    None => {
                        for r in 0..b.nrows {
                            let row = &part[r * tile..(r + 1) * tile];
                            for (out, p) in outs.iter_mut().zip(row) {
                                out[r] += p;
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Precomputed sparsity index for [`combine_sparse_on_pool`]: per block,
/// the local rows that have at least one nonzero in that block. The
/// paper's Discussion observes that "the generated intermediate vectors
/// also exhibit strong sparsity, which suggests that threads are not
/// fully utilized during the merging step" and calls optimizing the
/// combine its future work — this is that optimization: blocks whose
/// active-row fraction is below [`SPARSE_COMBINE_THRESHOLD`] are merged
/// via their active list instead of a full streaming pass.
#[derive(Clone, Debug)]
pub struct CombineIndex {
    /// Per block (same order as `hbp.blocks`): `Some(active local rows)`
    /// when the block is sparse enough, else `None` (dense streaming).
    active: Vec<Option<Vec<u32>>>,
    by_bi: Vec<Vec<usize>>,
}

/// Blocks with fewer active rows than this fraction of their slots use
/// the sparse merge path.
pub const SPARSE_COMBINE_THRESHOLD: f64 = 0.5;

impl CombineIndex {
    pub fn build(hbp: &Hbp) -> CombineIndex {
        let active = hbp
            .blocks
            .iter()
            .map(|b| {
                let mut rows = Vec::new();
                for s in 0..b.nrows {
                    if hbp.zero_row[b.slot_start + s] != -1 {
                        rows.push(hbp.output_hash[b.slot_start + s]);
                    }
                }
                if (rows.len() as f64) < SPARSE_COMBINE_THRESHOLD * b.nrows as f64 {
                    Some(rows)
                } else {
                    None
                }
            })
            .collect();
        CombineIndex { active, by_bi: blocks_by_row_block(hbp) }
    }

    /// Fraction of blocks taking the sparse path (bench reporting).
    pub fn sparse_fraction(&self) -> f64 {
        if self.active.is_empty() {
            return 0.0;
        }
        self.active.iter().filter(|a| a.is_some()).count() as f64 / self.active.len() as f64
    }
}

/// Sparsity-aware combine on the worker pool.
pub fn combine_sparse_on_pool(
    hbp: &Hbp,
    index: &CombineIndex,
    partials: &[f64],
    y: &mut [f64],
    pool: &crate::util::pool::WorkerPool,
) {
    assert_eq!(y.len(), hbp.rows);
    y.fill(0.0);
    if hbp.blocks.is_empty() {
        return;
    }
    let threads = pool.workers;
    let shared = SharedMut::new(y);
    pool.run_generation(|w, _| {
        for bi in (w..index.by_bi.len()).step_by(threads) {
            if index.by_bi[bi].is_empty() {
                continue;
            }
            let (rs, re) = hbp.grid.row_range(bi);
            // SAFETY: row-block ranges are disjoint across workers.
            let out = unsafe { shared.slice_mut(rs, re - rs) };
            for &bidx in &index.by_bi[bi] {
                let b: &HbpBlock = &hbp.blocks[bidx];
                let part = &partials[b.slot_start..b.slot_start + b.nrows];
                match &index.active[bidx] {
                    Some(rows) => {
                        for &orig in rows {
                            out[orig as usize] += part[orig as usize];
                        }
                    }
                    None => {
                        for (o, p) in out.iter_mut().zip(part) {
                            *o += p;
                        }
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;
    use crate::partition::PartitionConfig;
    use crate::preprocess::build_hbp;

    /// Build partials by a trivial serial walk, then check combine sums
    /// them into the right rows for any thread count.
    #[test]
    fn combine_sums_partials_by_row() {
        let m = random::power_law_rows(100, 120, 2.0, 30, 3);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let total_slots: usize = hbp.blocks.iter().map(|b| b.nrows).sum();
        // partials[slot] = 1.0 for every slot: y[r] = #blocks covering r
        let partials = vec![1.0; total_slots];
        let mut expect = vec![0.0; 100];
        for b in &hbp.blocks {
            let (rs, _) = hbp.grid.row_range(b.bi as usize);
            for r in 0..b.nrows {
                expect[rs + r] += 1.0;
            }
        }
        for threads in [1, 2, 5] {
            let mut y = vec![123.0; 100];
            combine(&hbp, &partials, &mut y, threads);
            assert!(allclose(&y, &expect, 1e-12, 1e-12), "threads={threads}");
        }
    }

    #[test]
    fn sparse_combine_matches_dense() {
        // matrix with many zero rows per block -> sparse path exercised
        let mut lens = vec![0usize; 200];
        for i in (0..200).step_by(7) {
            lens[i] = 5;
        }
        let m = random::with_row_lengths(&lens, 120, 11);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let idx = CombineIndex::build(&hbp);
        assert!(idx.sparse_fraction() > 0.5, "sparse path not taken");
        let total_slots: usize = hbp.blocks.iter().map(|b| b.nrows).sum();
        let partials: Vec<f64> = (0..total_slots).map(|i| (i % 13) as f64).collect();
        let pool = crate::util::pool::WorkerPool::new(3);
        let mut dense = vec![0.0; 200];
        let mut sparse = vec![0.0; 200];
        combine(&hbp, &partials, &mut dense, 3);
        combine_sparse_on_pool(&hbp, &idx, &partials, &mut sparse, &pool);
        // sparse path skips inactive slots: those partial entries are
        // nonzero garbage here, so compare only on active rows; build a
        // dense reference that honors the skip
        let mut expect = vec![0.0; 200];
        for (bidx, b) in hbp.blocks.iter().enumerate() {
            let (rs, _) = hbp.grid.row_range(b.bi as usize);
            for s in 0..b.nrows {
                if hbp.zero_row[b.slot_start + s] != -1 {
                    let orig = hbp.output_hash[b.slot_start + s] as usize;
                    expect[rs + orig] += partials[b.slot_start + orig];
                }
            }
            let _ = bidx;
        }
        assert!(allclose(&sparse, &expect, 1e-12, 1e-12));
        // and in the real engine (partials written by Alg 3, inactive
        // slots are exact 0.0) dense == sparse — checked in hbp.rs tests
        let _ = dense;
    }

    #[test]
    fn tile_combine_matches_per_vector_combine() {
        let m = random::power_law_rows(120, 100, 2.0, 25, 6);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let total_slots: usize = hbp.blocks.iter().map(|b| b.nrows).sum();
        let tile = 3;
        // column-major tile: vector v's partial at slot s is (s*7+v)%11
        let tiled: Vec<f64> =
            (0..total_slots * tile).map(|i| ((i / tile) * 7 + i % tile) as f64 % 11.0).collect();
        let pool = crate::util::pool::WorkerPool::new(2);
        let mut ys = vec![vec![9.0; 120]; tile];
        combine_tile_on_pool(&hbp, &tiled, &mut ys, &pool);
        for v in 0..tile {
            let partials: Vec<f64> = (0..total_slots).map(|s| tiled[s * tile + v]).collect();
            let mut expect = vec![0.0; 120];
            combine(&hbp, &partials, &mut expect, 2);
            assert!(allclose(&ys[v], &expect, 1e-12, 1e-12), "vector {v}");
        }
    }

    #[test]
    fn sparse_tile_combine_matches_dense_tile_on_written_partials() {
        // zero-row-heavy matrix so the sparse path activates; zero-row
        // slots hold exact 0.0 (as the fused kernels write them), so
        // dense and sparse tile combines must agree everywhere
        let mut lens = vec![0usize; 200];
        for i in (0..200).step_by(7) {
            lens[i] = 5;
        }
        let m = random::with_row_lengths(&lens, 120, 11);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let idx = CombineIndex::build(&hbp);
        assert!(idx.sparse_fraction() > 0.5, "sparse path not taken");
        let total_slots: usize = hbp.blocks.iter().map(|b| b.nrows).sum();
        let tile = 4;
        let mut tiled = vec![0.0; total_slots * tile];
        for (bidx, b) in hbp.blocks.iter().enumerate() {
            for s in 0..b.nrows {
                if hbp.zero_row[b.slot_start + s] != -1 {
                    let orig = hbp.output_hash[b.slot_start + s] as usize;
                    for v in 0..tile {
                        tiled[(b.slot_start + orig) * tile + v] = (bidx + s * tile + v) as f64;
                    }
                }
            }
        }
        let pool = crate::util::pool::WorkerPool::new(3);
        let mut dense = vec![vec![0.0; 200]; tile];
        let mut sparse = vec![vec![0.0; 200]; tile];
        combine_tile_on_pool(&hbp, &tiled, &mut dense, &pool);
        combine_sparse_tile_on_pool(&hbp, &idx, &tiled, &mut sparse, &pool);
        for v in 0..tile {
            assert!(allclose(&sparse[v], &dense[v], 1e-12, 1e-12), "vector {v}");
        }
    }

    #[test]
    fn empty_hbp_zeroes_output() {
        let m = crate::formats::Csr::empty(10, 10);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        let mut y = vec![5.0; 10];
        combine(&hbp, &[], &mut y, 4);
        assert_eq!(y, vec![0.0; 10]);
    }
}

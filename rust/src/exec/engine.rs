//! The engine abstraction shared by benches, examples and the
//! coordinator's router.

use crate::preprocess::{MatrixDelta, UpdateReport};

/// Timing breakdown of a two-phase (SpMV + combine) execution — the
/// quantities plotted in Fig. 9.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// Seconds in the block-SpMV phase.
    pub spmv: f64,
    /// Seconds in the combine phase (0 for single-phase engines).
    pub combine: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.spmv + self.combine
    }
}

/// Tile-width cap for fused SpMM: a batch of `k > SPMM_TILE` vectors is
/// split into passes of at most this many, so per-lane accumulator
/// scratch stays on the stack and the per-pass x-tile stays
/// cache-resident (the CPU analog of the GPU shared-memory budget).
pub const SPMM_TILE: usize = 8;

/// Validate a batch up front with a precise panic message. Every
/// `spmm` implementation calls this first: without it a mis-sized `ys`
/// row faults deep inside a kernel (an opaque out-of-bounds index), and
/// a mis-sized `xs` row can silently read the wrong element.
pub fn check_spmm_dims(name: &str, rows: usize, cols: usize, xs: &[Vec<f64>], ys: &[Vec<f64>]) {
    assert_eq!(
        xs.len(),
        ys.len(),
        "{name} spmm: {} input vectors but {} outputs",
        xs.len(),
        ys.len()
    );
    for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
        assert_eq!(x.len(), cols, "{name} spmm: xs[{i}] length {} != cols {cols}", x.len());
        assert_eq!(y.len(), rows, "{name} spmm: ys[{i}] length {} != rows {rows}", y.len());
    }
}

/// A sparse matrix-vector multiplication engine.
pub trait SpmvEngine: Sync {
    /// Engine name for bench tables ("csr", "2d", "hbp", ...).
    fn name(&self) -> &str;

    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn nnz(&self) -> usize;

    /// Compute `y = A x`. `y.len() == rows`, `x.len() == cols`.
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_phases(x, y);
    }

    /// As [`SpmvEngine::spmv`] but returning the phase timing breakdown.
    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes;

    /// Multi-vector SpMV (SpMM): `ys[k] = A xs[k]`. The default loops
    /// [`SpmvEngine::spmv`]; engines may override with a vector-inner
    /// loop that reuses each matrix element across the batch — this is
    /// what makes the coordinator's same-matrix batching pay off.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        check_spmm_dims(self.name(), self.rows(), self.cols(), xs, ys);
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            self.spmv(x, y);
        }
    }

    /// GFLOPS for a measured execution time (the paper's `2*nnz/t`).
    fn gflops(&self, secs: f64) -> f64 {
        crate::util::timer::spmv_gflops(self.nnz(), secs)
    }

    /// Apply a value-level matrix update in place so the resident
    /// operand keeps serving without a re-registration. Engines that
    /// hold derived structure repair only what the delta invalidates
    /// (see [`crate::exec::HbpEngine::update`]); the default refuses,
    /// and callers fall back to rebuilding the engine.
    fn update(&mut self, _delta: &MatrixDelta) -> anyhow::Result<UpdateReport> {
        anyhow::bail!("engine {:?} does not support incremental updates", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_total() {
        let p = PhaseTimes { spmv: 1.5, combine: 0.5 };
        assert!((p.total() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn check_spmm_dims_accepts_well_formed_batches() {
        check_spmm_dims("t", 3, 2, &[vec![0.0; 2]], &[vec![0.0; 3]]);
        check_spmm_dims("t", 3, 2, &[], &[]);
    }

    #[test]
    #[should_panic(expected = "ys[0] length")]
    fn check_spmm_dims_rejects_short_output_row() {
        check_spmm_dims("t", 3, 2, &[vec![0.0; 2]], &[vec![0.0; 1]]);
    }

    #[test]
    #[should_panic(expected = "xs[1] length")]
    fn check_spmm_dims_rejects_short_input_row() {
        check_spmm_dims("t", 3, 2, &[vec![0.0; 2], vec![0.0; 9]], &[vec![0.0; 3]; 2]);
    }
}

//! The engine abstraction shared by benches, examples and the
//! coordinator's router.

use crate::preprocess::{MatrixDelta, UpdateReport};

/// Timing breakdown of a two-phase (SpMV + combine) execution — the
/// quantities plotted in Fig. 9.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// Seconds in the block-SpMV phase.
    pub spmv: f64,
    /// Seconds in the combine phase (0 for single-phase engines).
    pub combine: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.spmv + self.combine
    }
}

/// A sparse matrix-vector multiplication engine.
pub trait SpmvEngine: Sync {
    /// Engine name for bench tables ("csr", "2d", "hbp", ...).
    fn name(&self) -> &str;

    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn nnz(&self) -> usize;

    /// Compute `y = A x`. `y.len() == rows`, `x.len() == cols`.
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.spmv_phases(x, y);
    }

    /// As [`SpmvEngine::spmv`] but returning the phase timing breakdown.
    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes;

    /// Multi-vector SpMV (SpMM): `ys[k] = A xs[k]`. The default loops
    /// [`SpmvEngine::spmv`]; engines may override with a vector-inner
    /// loop that reuses each matrix element across the batch — this is
    /// what makes the coordinator's same-matrix batching pay off.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        assert_eq!(xs.len(), ys.len());
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            self.spmv(x, y);
        }
    }

    /// GFLOPS for a measured execution time (the paper's `2*nnz/t`).
    fn gflops(&self, secs: f64) -> f64 {
        crate::util::timer::spmv_gflops(self.nnz(), secs)
    }

    /// Apply a value-level matrix update in place so the resident
    /// operand keeps serving without a re-registration. Engines that
    /// hold derived structure repair only what the delta invalidates
    /// (see [`crate::exec::HbpEngine::update`]); the default refuses,
    /// and callers fall back to rebuilding the engine.
    fn update(&mut self, _delta: &MatrixDelta) -> anyhow::Result<UpdateReport> {
        anyhow::bail!("engine {:?} does not support incremental updates", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_total() {
        let p = PhaseTimes { spmv: 1.5, combine: 0.5 };
        assert!((p.total() - 2.0).abs() < 1e-12);
    }
}

//! Flat SpMV — pure nonzero-splitting with designed load / accumulate /
//! reduce phases (spmv-acc's `flat` algorithm on the CPU substrate).
//!
//! Where [`super::nnz_split`] folds everything into one fused chunk
//! walk with boundary partial sums, `flat` keeps the GPU algorithm's
//! three distinct phases:
//!
//! 1. **load** — worker `w` streams its contiguous nonzero chunk
//!    `[w*nnz/W, (w+1)*nnz/W)` exactly once, staging every product
//!    `data[j] * x[col[j]]` into a shared products buffer (the CPU
//!    analog of the kernel's LDS staging: one coalesced pass over
//!    `data`/`col`, no row logic on the load path).
//! 2. **accumulate** — the same worker sums the staged products of each
//!    row lying entirely inside its chunk and writes the row directly
//!    (disjoint across workers by construction).
//! 3. **reduce** — rows cut by a chunk boundary are summed serially
//!    from the staged products, left to right (≤ `threads - 1` rows, at
//!    most one per interior split).
//!
//! Because every row — owned or cut — is reduced left-to-right with a
//! single accumulator over the same staged products, the output is
//! **bitwise identical to the serial CSR oracle**, the repo-wide
//! parallel = serial invariant (asserted exactly by the conformance and
//! property suites). The chunk geometry (`splits`, `first_row`, the
//! cut-row list) is a pure function of the row pointer, which no
//! [`crate::preprocess::MatrixDelta`] kind can move, so incremental
//! updates repair values in place for every delta kind — the
//! zero-conversion-cost property that makes the CSR-native engines
//! attractive exactly where reordering's preprocessing cost is not
//! worth paying.

use super::engine::{check_spmm_dims, PhaseTimes, SpmvEngine, SPMM_TILE};
use super::nnz_split::{first_rows, nnz_splits};
use crate::formats::Csr;
use crate::util::pool::WorkerPool;
use crate::util::sync::SharedMut;
use crate::util::Timer;
use std::sync::Mutex;

/// Flat SpMV engine: equal-nnz chunks, staged products, serial cut-row
/// reduce.
pub struct FlatEngine {
    pub m: Csr,
    pub threads: usize,
    /// Per-worker nonzero chunk starts (`threads + 1` entries).
    splits: Vec<usize>,
    /// First row of each chunk (precomputed binary search).
    first_row: Vec<usize>,
    /// Rows cut by an interior chunk boundary, ascending and distinct —
    /// the reduce phase's whole work list.
    cut_rows: Vec<usize>,
    pool: WorkerPool,
    /// Staged per-nonzero products (load-phase output, reused across
    /// calls; accumulate and reduce both read it).
    products: Mutex<Vec<f64>>,
}

impl FlatEngine {
    pub fn new(m: Csr, threads: usize) -> Self {
        let threads = threads.max(1);
        let splits = nnz_splits(m.nnz(), threads);
        let first_row = first_rows(&m, &splits);
        // a row is cut iff an interior split lands strictly inside its
        // extent; a split on a row boundary cuts nothing
        let mut cut_rows: Vec<usize> = splits[1..threads]
            .iter()
            .filter_map(|&k| match m.ptr.binary_search(&k) {
                Ok(_) => None,
                Err(r) => Some(r - 1),
            })
            .collect();
        cut_rows.dedup();
        let nnz = m.nnz();
        FlatEngine {
            m,
            threads,
            splits,
            first_row,
            cut_rows,
            pool: WorkerPool::new(threads),
            products: Mutex::new(vec![0.0; nnz]),
        }
    }

    /// How many rows the reduce phase owns (cut by a chunk boundary) —
    /// observability for tests and ablations.
    pub fn cut_row_count(&self) -> usize {
        self.cut_rows.len()
    }
}

impl SpmvEngine for FlatEngine {
    fn name(&self) -> &str {
        "flat"
    }
    fn rows(&self) -> usize {
        self.m.rows
    }
    fn cols(&self) -> usize {
        self.m.cols
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.m.cols);
        assert_eq!(y.len(), self.m.rows);
        let t = Timer::start();
        y.fill(0.0);
        let mut products = self.products.lock().unwrap();
        {
            let shared_y = SharedMut::new(y);
            let shared_p = SharedMut::new(&mut products[..]);
            let m = &self.m;
            self.pool.run_generation(|w, _| {
                let (lo, hi) = (self.splits[w], self.splits[w + 1]);
                if lo >= hi {
                    return;
                }
                // load: stage this chunk's products in one pass
                // SAFETY: chunk ranges are disjoint across workers.
                let p = unsafe { shared_p.slice_mut(lo, hi - lo) };
                for (s, j) in p.iter_mut().zip(lo..hi) {
                    *s = m.data[j] * x[m.col[j] as usize];
                }
                // accumulate: rows entirely inside the chunk
                let mut r = self.first_row[w];
                let mut k = lo;
                while k < hi {
                    // advance past empty rows
                    while m.ptr[r + 1] <= k {
                        r += 1;
                    }
                    let row_end = m.ptr[r + 1].min(hi);
                    if m.ptr[r] >= lo && m.ptr[r + 1] <= hi {
                        let mut sum = 0.0;
                        for &v in &p[(k - lo)..(row_end - lo)] {
                            sum += v;
                        }
                        // SAFETY: only this worker owns rows entirely
                        // inside its chunk.
                        unsafe { shared_y.write(r, sum) };
                    }
                    k = row_end;
                    r += 1;
                }
            });
        }
        let spmv_secs = t.elapsed_secs();
        // reduce: each cut row sums its staged products serially, left
        // to right with one accumulator — the serial oracle's exact
        // association, so parallel output is bitwise serial
        let t = Timer::start();
        for &r in &self.cut_rows {
            let mut sum = 0.0;
            for &v in &products[self.m.ptr[r]..self.m.ptr[r + 1]] {
                sum += v;
            }
            y[r] = sum;
        }
        PhaseTimes { spmv: spmv_secs, combine: t.elapsed_secs() }
    }

    /// Fused SpMM: per tile of at most [`SPMM_TILE`] vectors the
    /// load/accumulate pair runs fused (staging a products tile would
    /// cost `nnz × tile` scratch for no reuse), keeping the per-vector
    /// accumulation order identical to `spmv`; the reduce phase then
    /// recomputes each cut row serially per vector — so fused output
    /// stays bitwise equal to the looped path.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        check_spmm_dims("flat", self.m.rows, self.m.cols, xs, ys);
        if xs.len() < 2 {
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                self.spmv(x, y);
            }
            return;
        }
        for y in ys.iter_mut() {
            y.fill(0.0);
        }
        let mut t_lo = 0;
        while t_lo < xs.len() {
            let t_hi = (t_lo + SPMM_TILE).min(xs.len());
            let tile = t_hi - t_lo;
            let x_tile = &xs[t_lo..t_hi];
            {
                let y_ptrs: Vec<SharedMut<'_, f64>> = ys[t_lo..t_hi]
                    .iter_mut()
                    .map(|y| SharedMut::new(&mut y[..]))
                    .collect();
                let m = &self.m;
                self.pool.run_generation(|w, _| {
                    let (lo, hi) = (self.splits[w], self.splits[w + 1]);
                    if lo >= hi {
                        return;
                    }
                    let mut r = self.first_row[w];
                    let mut k = lo;
                    while k < hi {
                        while m.ptr[r + 1] <= k {
                            r += 1;
                        }
                        let row_end = m.ptr[r + 1].min(hi);
                        if m.ptr[r] >= lo && m.ptr[r + 1] <= hi {
                            let mut sums = [0.0f64; SPMM_TILE];
                            for j in k..row_end {
                                let a = m.data[j];
                                let c = m.col[j] as usize;
                                for (s, x) in sums[..tile].iter_mut().zip(x_tile) {
                                    *s += a * x[c];
                                }
                            }
                            // SAFETY: only this worker owns rows
                            // entirely inside its chunk; the y_ptrs
                            // point at distinct output vectors.
                            for (v, yp) in y_ptrs.iter().enumerate() {
                                unsafe { yp.write(r, sums[v]) };
                            }
                        }
                        k = row_end;
                        r += 1;
                    }
                });
            }
            // reduce: cut rows serially, once per tile
            for &r in &self.cut_rows {
                for (v, x) in x_tile.iter().enumerate() {
                    let mut sum = 0.0;
                    for j in self.m.ptr[r]..self.m.ptr[r + 1] {
                        sum += self.m.data[j] * x[self.m.col[j] as usize];
                    }
                    ys[t_lo + v][r] = sum;
                }
            }
            t_lo = t_hi;
        }
    }

    /// In-place delta repair: the chunk geometry is a row-pointer
    /// function and deltas rewrite `col`/`data` within fixed extents,
    /// so applying the delta to the resident CSR is the whole repair —
    /// value-only and pattern-changing deltas alike, never a rebuild.
    fn update(
        &mut self,
        delta: &crate::preprocess::MatrixDelta,
    ) -> anyhow::Result<crate::preprocess::UpdateReport> {
        let change = crate::preprocess::apply_to_csr(&mut self.m, delta)?;
        Ok(crate::preprocess::UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: 0,
            full_rebuild: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;

    /// Bitwise (not approximate) agreement with the serial CSR oracle.
    fn check_bitwise(m: &Csr, threads: usize, seed: u64) {
        let x = random::vector(m.cols, seed);
        let mut expect = vec![0.0; m.rows];
        m.spmv(&x, &mut expect);
        let eng = FlatEngine::new(m.clone(), threads);
        let mut y = vec![0.0; m.rows];
        eng.spmv(&x, &mut y);
        assert_eq!(y, expect, "flat must be bitwise serial (threads={threads})");
    }

    #[test]
    fn bitwise_matches_serial_csr_on_random() {
        for seed in 0..4 {
            let m = random::power_law_rows(300, 250, 2.0, 60, seed);
            for threads in [1, 4, 13] {
                check_bitwise(&m, threads, seed);
            }
        }
    }

    #[test]
    fn monster_row_is_cut_and_reduced_exactly() {
        let mut lens = vec![1usize; 64];
        lens[20] = 5000;
        let m = random::with_row_lengths(&lens, 600, 3);
        let eng = FlatEngine::new(m.clone(), 8);
        assert!(eng.cut_row_count() >= 1, "the monster row must be cut");
        check_bitwise(&m, 8, 7);
    }

    #[test]
    fn empty_rows_at_chunk_boundaries() {
        let lens = vec![0, 0, 10, 0, 0, 7, 0, 3, 0, 0, 0, 25, 0, 1, 0, 0];
        let m = random::with_row_lengths(&lens, 40, 9);
        for threads in [1, 3, 5, 16] {
            check_bitwise(&m, threads, 11);
        }
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(10, 10);
        let eng = FlatEngine::new(m, 4);
        let mut y = vec![9.0; 10];
        eng.spmv(&vec![1.0; 10], &mut y);
        assert_eq!(y, vec![0.0; 10]);
        assert_eq!(eng.cut_row_count(), 0);
    }

    #[test]
    fn phase_times_split_reduce_from_parallel_work() {
        let m = random::power_law_rows(200, 150, 2.0, 40, 5);
        let eng = FlatEngine::new(m.clone(), 4);
        let x = random::vector(150, 1);
        let mut y = vec![0.0; 200];
        let phases = eng.spmv_phases(&x, &mut y);
        assert!(phases.spmv > 0.0);
        assert!(phases.combine >= 0.0);
    }

    #[test]
    fn fused_spmm_is_bitwise_the_looped_path() {
        let mut lens = vec![2usize; 80];
        lens[30] = 2000;
        let m = random::with_row_lengths(&lens, 300, 5);
        for threads in [1, 4, 9] {
            let eng = FlatEngine::new(m.clone(), threads);
            let k = SPMM_TILE + 2;
            let xs: Vec<Vec<f64>> = (0..k).map(|i| random::vector(300, i as u64)).collect();
            let mut ys: Vec<Vec<f64>> = vec![vec![0.0; 80]; k];
            eng.spmm(&xs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut looped = vec![0.0; 80];
                eng.spmv(x, &mut looped);
                assert_eq!(*y, looped, "threads={threads}");
            }
        }
    }

    #[test]
    fn update_repairs_values_and_pattern_in_place() {
        use crate::preprocess::MatrixDelta;
        let m = random::power_law_rows(90, 70, 2.0, 18, 21);
        let mut eng = FlatEngine::new(m.clone(), 6);
        let row = (0..90).find(|&r| m.row_nnz(r) >= 2).unwrap();
        let delta = MatrixDelta::new().scale_row(row, 3.5);
        let report = eng.update(&delta).unwrap();
        assert!(!report.full_rebuild);
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(70, 4);
        let mut y = vec![0.0; 90];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 90];
        mutated.spmv(&x, &mut expect);
        assert_eq!(y, expect, "post-update flat must stay bitwise serial");
    }
}

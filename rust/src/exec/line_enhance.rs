//! Line-enhance SpMV — row-splitting for short-row bands, dedicated
//! ownership for long-row tails (spmv-acc's `line-enhance` algorithm on
//! the CPU substrate).
//!
//! The GPU original assigns one *line* (row) per lane inside short-row
//! regions and escalates to a whole wavefront per row once rows grow
//! past a threshold. Under the substrate rule (one worker thread = one
//! warp, SIMT lanes collapse into the worker's scalar loop) both modes
//! collapse to the same shape — *a single worker computes a whole row
//! serially* — and what survives is the **assignment policy**:
//!
//! - **short rows** (length ≤ threshold, derived from the row-length
//!   mean and spread at build time) are packed into contiguous,
//!   nnz-balanced bands, one band per worker — the row-splitting half;
//! - **long rows** (the tail) are each assigned whole to the currently
//!   least-loaded worker, heaviest first — the nnz-splitting half,
//!   without ever splitting a row's interior.
//!
//! Every row is therefore summed left-to-right by one owner with one
//! accumulator, so output is **bitwise identical to the serial CSR
//! oracle** — the repo-wide parallel = serial invariant. The assignment
//! is a pure function of row lengths (row-pointer differences), which
//! no [`crate::preprocess::MatrixDelta`] kind can change, so deltas
//! repair the resident CSR in place with zero replanning.

use super::engine::{check_spmm_dims, PhaseTimes, SpmvEngine, SPMM_TILE};
use crate::formats::Csr;
use crate::util::pool::WorkerPool;
use crate::util::sync::SharedMut;
use crate::util::Timer;

/// Line-enhance SpMV engine: banded short rows, balanced long-row
/// tail, whole-row ownership throughout.
pub struct LineEnhanceEngine {
    pub m: Csr,
    pub threads: usize,
    /// Short/long boundary in nonzeros per row, fixed at build time.
    threshold: usize,
    /// Rows each worker owns: its contiguous short band followed by its
    /// share of the long tail.
    rows_of: Vec<Vec<usize>>,
    /// How many rows went down the long-row path (observability).
    long_rows: usize,
    pool: WorkerPool,
}

impl LineEnhanceEngine {
    pub fn new(m: Csr, threads: usize) -> Self {
        let threads = threads.max(1);
        let lens: Vec<usize> = (0..m.rows).map(|r| m.ptr[r + 1] - m.ptr[r]).collect();
        let nnz = m.nnz();
        let mean = if m.rows > 0 { nnz as f64 / m.rows as f64 } else { 0.0 };
        let var = if m.rows > 0 {
            lens.iter().map(|&l| (l as f64 - mean).powi(2)).sum::<f64>() / m.rows as f64
        } else {
            0.0
        };
        // two sigmas above the mean, floored so near-uniform matrices
        // don't classify ordinary rows as tails
        let threshold = (mean + 2.0 * var.sqrt()).ceil().max(16.0) as usize;

        // short rows: contiguous bands balanced by nnz, preserving row
        // order inside each band
        let short_nnz: usize = lens.iter().filter(|&&l| l > 0 && l <= threshold).sum();
        let mut rows_of: Vec<Vec<usize>> = vec![Vec::new(); threads];
        let mut load = vec![0usize; threads];
        let mut band = 0usize;
        let mut acc = 0usize;
        for (r, &len) in lens.iter().enumerate() {
            if len == 0 || len > threshold {
                continue;
            }
            while band + 1 < threads && acc >= (band + 1) * short_nnz / threads {
                band += 1;
            }
            rows_of[band].push(r);
            load[band] += len;
            acc += len;
        }

        // long rows: heaviest first onto the least-loaded worker
        let mut long: Vec<usize> = (0..m.rows).filter(|&r| lens[r] > threshold).collect();
        long.sort_by_key(|&r| std::cmp::Reverse(lens[r]));
        let long_rows = long.len();
        for r in long {
            let w = (0..threads).min_by_key(|&w| load[w]).unwrap_or(0);
            rows_of[w].push(r);
            load[w] += lens[r];
        }

        LineEnhanceEngine {
            m,
            threads,
            threshold,
            rows_of,
            long_rows,
            pool: WorkerPool::new(threads),
        }
    }

    /// The short/long row-length boundary chosen at build time.
    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// How many rows were routed down the long-row (tail) path.
    pub fn long_row_count(&self) -> usize {
        self.long_rows
    }
}

impl SpmvEngine for LineEnhanceEngine {
    fn name(&self) -> &str {
        "line-enhance"
    }
    fn rows(&self) -> usize {
        self.m.rows
    }
    fn cols(&self) -> usize {
        self.m.cols
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.m.cols);
        assert_eq!(y.len(), self.m.rows);
        let t = Timer::start();
        y.fill(0.0);
        {
            let shared_y = SharedMut::new(y);
            let m = &self.m;
            self.pool.run_generation(|w, _| {
                for &r in &self.rows_of[w] {
                    let mut sum = 0.0;
                    for j in m.ptr[r]..m.ptr[r + 1] {
                        sum += m.data[j] * x[m.col[j] as usize];
                    }
                    // SAFETY: each row has exactly one owner.
                    unsafe { shared_y.write(r, sum) };
                }
            });
        }
        // whole-row ownership needs no combine pass
        PhaseTimes { spmv: t.elapsed_secs(), combine: 0.0 }
    }

    /// Fused SpMM: same whole-row ownership, one pass over each row's
    /// nonzeros per tile of at most [`SPMM_TILE`] vectors; the
    /// per-vector accumulation order matches `spmv` exactly, so fused
    /// output is bitwise the looped path.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        check_spmm_dims("line-enhance", self.m.rows, self.m.cols, xs, ys);
        if xs.len() < 2 {
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                self.spmv(x, y);
            }
            return;
        }
        for y in ys.iter_mut() {
            y.fill(0.0);
        }
        let mut t_lo = 0;
        while t_lo < xs.len() {
            let t_hi = (t_lo + SPMM_TILE).min(xs.len());
            let tile = t_hi - t_lo;
            let x_tile = &xs[t_lo..t_hi];
            let y_ptrs: Vec<SharedMut<'_, f64>> = ys[t_lo..t_hi]
                .iter_mut()
                .map(|y| SharedMut::new(&mut y[..]))
                .collect();
            let m = &self.m;
            self.pool.run_generation(|w, _| {
                for &r in &self.rows_of[w] {
                    let mut sums = [0.0f64; SPMM_TILE];
                    for j in m.ptr[r]..m.ptr[r + 1] {
                        let a = m.data[j];
                        let c = m.col[j] as usize;
                        for (s, x) in sums[..tile].iter_mut().zip(x_tile) {
                            *s += a * x[c];
                        }
                    }
                    // SAFETY: one owner per row; distinct output
                    // vectors behind each pointer.
                    for (v, yp) in y_ptrs.iter().enumerate() {
                        unsafe { yp.write(r, sums[v]) };
                    }
                }
            });
            t_lo = t_hi;
        }
    }

    /// In-place delta repair: the row assignment is a row-length
    /// function and deltas rewrite `col`/`data` within fixed extents,
    /// so applying the delta to the resident CSR is the whole repair.
    fn update(
        &mut self,
        delta: &crate::preprocess::MatrixDelta,
    ) -> anyhow::Result<crate::preprocess::UpdateReport> {
        let change = crate::preprocess::apply_to_csr(&mut self.m, delta)?;
        Ok(crate::preprocess::UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: 0,
            full_rebuild: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;

    fn check_bitwise(m: &Csr, threads: usize, seed: u64) {
        let x = random::vector(m.cols, seed);
        let mut expect = vec![0.0; m.rows];
        m.spmv(&x, &mut expect);
        let eng = LineEnhanceEngine::new(m.clone(), threads);
        let mut y = vec![0.0; m.rows];
        eng.spmv(&x, &mut y);
        assert_eq!(y, expect, "line-enhance must be bitwise serial (threads={threads})");
    }

    #[test]
    fn bitwise_matches_serial_csr_on_random() {
        for seed in 0..4 {
            let m = random::power_law_rows(300, 250, 2.0, 60, seed);
            for threads in [1, 4, 13] {
                check_bitwise(&m, threads, seed);
            }
        }
    }

    #[test]
    fn every_row_is_owned_exactly_once() {
        let m = random::power_law_rows(500, 300, 1.8, 120, 6);
        let eng = LineEnhanceEngine::new(m.clone(), 7);
        let mut seen = vec![0usize; m.rows];
        for rows in &eng.rows_of {
            for &r in rows {
                seen[r] += 1;
            }
        }
        for r in 0..m.rows {
            let expect = usize::from(m.row_nnz(r) > 0);
            assert_eq!(seen[r], expect, "row {r} ownership");
        }
    }

    #[test]
    fn skewed_matrix_routes_a_tail_down_the_long_path() {
        let mut lens = vec![2usize; 200];
        lens[17] = 4000;
        lens[90] = 3000;
        let m = random::with_row_lengths(&lens, 800, 8);
        let eng = LineEnhanceEngine::new(m.clone(), 6);
        assert_eq!(eng.long_row_count(), 2);
        assert!(eng.threshold() >= 16);
        check_bitwise(&m, 6, 2);
    }

    #[test]
    fn uniform_matrix_has_no_long_tail() {
        let m = random::with_row_lengths(&[8; 300], 200, 4);
        let eng = LineEnhanceEngine::new(m.clone(), 5);
        assert_eq!(eng.long_row_count(), 0);
        check_bitwise(&m, 5, 5);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(10, 10);
        let eng = LineEnhanceEngine::new(m, 4);
        let mut y = vec![9.0; 10];
        eng.spmv(&vec![1.0; 10], &mut y);
        assert_eq!(y, vec![0.0; 10]);
        assert_eq!(eng.long_row_count(), 0);
    }

    #[test]
    fn fused_spmm_is_bitwise_the_looped_path() {
        let mut lens = vec![3usize; 90];
        lens[44] = 1500;
        let m = random::with_row_lengths(&lens, 250, 12);
        for threads in [1, 4, 9] {
            let eng = LineEnhanceEngine::new(m.clone(), threads);
            let k = SPMM_TILE + 2;
            let xs: Vec<Vec<f64>> = (0..k).map(|i| random::vector(250, i as u64)).collect();
            let mut ys: Vec<Vec<f64>> = vec![vec![0.0; 90]; k];
            eng.spmm(&xs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut looped = vec![0.0; 90];
                eng.spmv(x, &mut looped);
                assert_eq!(*y, looped, "threads={threads}");
            }
        }
    }

    #[test]
    fn update_repairs_values_in_place() {
        use crate::preprocess::MatrixDelta;
        let m = random::power_law_rows(90, 70, 2.0, 18, 31);
        let mut eng = LineEnhanceEngine::new(m.clone(), 6);
        let row = (0..90).find(|&r| m.row_nnz(r) >= 2).unwrap();
        let delta = MatrixDelta::new().scale_row(row, -1.25);
        let report = eng.update(&delta).unwrap();
        assert!(!report.full_rebuild);
        assert_eq!(report.rows_touched, 1);
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(70, 4);
        let mut y = vec![0.0; 90];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 90];
        mutated.spmv(&x, &mut expect);
        assert_eq!(y, expect, "post-update line-enhance must stay bitwise serial");
    }
}

//! SpMV execution engines.
//!
//! The CPU substitution for the paper's CUDA kernels (DESIGN.md §2): one
//! worker thread plays the role of one warp. The *schedule* and *memory
//! layout* — what the paper's contribution actually is — are preserved
//! exactly; only the SIMT lanes are collapsed into the worker's scalar
//! loop (their effect is modeled by [`crate::sim`]).
//!
//! Engines:
//! - [`csr`] — Algorithm 1, serial and row-parallel (the paper's CSR
//!   baseline).
//! - [`spmv2d`] — plain 2D-partitioning without reordering (the paper's
//!   "2D" baseline): block SpMV + combine, static block assignment.
//! - [`hbp`] — Algorithm 3 over the HBP layout with the mixed
//!   fixed/competitive schedule of §III-C.
//! - [`combine`] — the second phase shared by the 2D engines.
//! - [`scheduler`] — the fixed/competitive split + ticket lock.
//! - [`flat`] — pure nnz-splitting with load/accumulate/reduce phases
//!   (spmv-acc's CSR-native `flat`, zero conversion cost).
//! - [`line_enhance`] — row-split short bands + whole-row long tails
//!   (spmv-acc's CSR-native `line-enhance`, zero conversion cost).

pub mod engine;
pub mod csr;
pub mod spmv2d;
pub mod hbp;
pub mod combine;
pub mod scheduler;
pub mod nnz_split;
pub mod flat;
pub mod line_enhance;

pub use engine::{check_spmm_dims, PhaseTimes, SpmvEngine, SPMM_TILE};
pub use csr::{CsrParallel, CsrSerial};
pub use flat::FlatEngine;
pub use hbp::HbpEngine;
pub use line_enhance::LineEnhanceEngine;
pub use nnz_split::NnzSplitEngine;
pub use scheduler::{absorb_stats, mixed_schedule, run_mixed, MixedSchedule, WorkerStats};
pub use spmv2d::Spmv2dEngine;

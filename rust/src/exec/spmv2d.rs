//! Plain 2D-partitioning SpMV — the paper's "2D" baseline.
//!
//! Identical block decomposition and combine phase as HBP, but: no row
//! reordering (rows execute in natural order, so warp groups mix long and
//! short rows), row-major element order within a block (no coalescing
//! layout), and purely static block assignment (no competitive tail).
//! The deltas HBP adds are thus isolated one by one for the benches.

use super::engine::{check_spmm_dims, PhaseTimes, SpmvEngine, SPMM_TILE};
use crate::formats::Csr;
use crate::partition::{block_views, BlockGrid, BlockView, PartitionConfig};
use crate::preprocess::{build_hbp_with, Hbp, IdentityReorder};
use crate::util::sync::SharedMut;
use crate::util::Timer;

/// Plain 2D-partitioning engine.
///
/// Keeps the parent CSR plus per-block row ranges; each block is executed
/// row-major by one worker with static round-robin assignment.
pub struct Spmv2dEngine {
    pub m: Csr,
    pub grid: BlockGrid,
    views: Vec<BlockView>,
    /// An identity-ordered HBP shell reused for the combine phase's
    /// row-block bookkeeping (no reordering applied).
    shell: Hbp,
    pub threads: usize,
    total_slots: usize,
    /// Persistent workers (§Perf: no per-call spawns).
    pool: crate::util::pool::WorkerPool,
    /// Reused partials buffer (§Perf: see `HbpEngine::partials`).
    partials: std::sync::Mutex<Vec<f64>>,
}

impl Spmv2dEngine {
    pub fn new(m: Csr, cfg: PartitionConfig, threads: usize) -> Self {
        let grid = BlockGrid::new(m.rows, m.cols, cfg);
        let views = block_views(&m, &grid);
        let shell = build_hbp_with(&m, cfg, &IdentityReorder);
        let total_slots = shell.blocks.iter().map(|b| b.nrows).sum();
        let threads = threads.max(1);
        Spmv2dEngine {
            m,
            grid,
            views,
            shell,
            threads,
            total_slots,
            pool: crate::util::pool::WorkerPool::new(threads),
            partials: std::sync::Mutex::new(Vec::new()),
        }
    }
}

impl SpmvEngine for Spmv2dEngine {
    fn name(&self) -> &str {
        "2d"
    }
    fn rows(&self) -> usize {
        self.m.rows
    }
    fn cols(&self) -> usize {
        self.m.cols
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn spmv_phases(&self, x: &[f64], y: &mut [f64]) -> PhaseTimes {
        assert_eq!(x.len(), self.m.cols);
        assert_eq!(y.len(), self.m.rows);
        let mut partials = self.partials.lock().unwrap();
        partials.resize(self.total_slots, 0.0);

        let t = Timer::start();
        {
            let shared = SharedMut::new(&mut partials[..]);
            let views = &self.views;
            let m = &self.m;
            let shell = &self.shell;
            self.pool.run_generation(|w, _| {
                // static round-robin over blocks (no stealing)
                for (v, b) in views.iter().zip(&shell.blocks).skip(w).step_by(self.threads) {
                    // SAFETY: disjoint per-block slot ranges.
                    let out = unsafe { shared.slice_mut(b.slot_start, b.nrows) };
                    for (local, &(lo, hi)) in v.row_ranges.iter().enumerate() {
                        let mut sum = 0.0;
                        for k in lo..hi {
                            sum += m.data[k] * x[m.col[k] as usize];
                        }
                        out[local] = sum;
                    }
                }
            });
        }
        let spmv_secs = t.elapsed_secs();

        let t = Timer::start();
        super::combine::combine_on_pool(&self.shell, &partials, y, &self.pool);
        PhaseTimes { spmv: spmv_secs, combine: t.elapsed_secs() }
    }

    /// Fused SpMM: the batch is split into tiles of at most
    /// [`SPMM_TILE`] vectors; per tile, one static round-robin pass over
    /// the block views streams each nonzero's `(data, col)` once and
    /// applies it to the whole tile, writing a column-major partials
    /// tile that a single tile combine then reduces.
    fn spmm(&self, xs: &[Vec<f64>], ys: &mut [Vec<f64>]) {
        check_spmm_dims("2d", self.m.rows, self.m.cols, xs, ys);
        if xs.len() < 2 {
            for (x, y) in xs.iter().zip(ys.iter_mut()) {
                self.spmv(x, y);
            }
            return;
        }
        let mut partials = self.partials.lock().unwrap();
        let mut t_lo = 0;
        while t_lo < xs.len() {
            let t_hi = (t_lo + SPMM_TILE).min(xs.len());
            let tile = t_hi - t_lo;
            partials.resize(self.total_slots * tile, 0.0);
            {
                let shared = SharedMut::new(&mut partials[..]);
                let views = &self.views;
                let m = &self.m;
                let shell = &self.shell;
                let x_tile = &xs[t_lo..t_hi];
                self.pool.run_generation(|w, _| {
                    for (v, b) in views.iter().zip(&shell.blocks).skip(w).step_by(self.threads) {
                        // SAFETY: disjoint per-block tile-strided ranges.
                        let out = unsafe { shared.slice_mut(b.slot_start * tile, b.nrows * tile) };
                        for (local, &(lo, hi)) in v.row_ranges.iter().enumerate() {
                            let row_out = &mut out[local * tile..(local + 1) * tile];
                            row_out.fill(0.0);
                            for k in lo..hi {
                                let a = m.data[k];
                                let c = m.col[k] as usize;
                                for (o, x) in row_out.iter_mut().zip(x_tile) {
                                    *o += a * x[c];
                                }
                            }
                        }
                    }
                });
            }
            super::combine::combine_tile_on_pool(
                &self.shell,
                &partials,
                &mut ys[t_lo..t_hi],
                &self.pool,
            );
            t_lo = t_hi;
        }
    }

    /// Value-level update in place: the block views hold index *ranges*
    /// into the parent arrays, so mutated values are picked up with no
    /// repair at all. Only a pattern change (columns moving between
    /// blocks) invalidates the views and the combine shell — rebuild
    /// both then.
    fn update(
        &mut self,
        delta: &crate::preprocess::MatrixDelta,
    ) -> anyhow::Result<crate::preprocess::UpdateReport> {
        let change = crate::preprocess::apply_to_csr(&mut self.m, delta)?;
        if change.pattern_changed {
            self.views = block_views(&self.m, &self.grid);
            self.shell = build_hbp_with(&self.m, self.grid.cfg, &IdentityReorder);
            self.total_slots = self.shell.blocks.iter().map(|b| b.nrows).sum();
            // both counts describe the rebuilt views: all were written
            return Ok(crate::preprocess::UpdateReport {
                rows_touched: change.touched_rows.len(),
                blocks_touched: self.views.len(),
                blocks_total: self.views.len(),
                full_rebuild: true,
            });
        }
        Ok(crate::preprocess::UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: self.views.len(),
            full_rebuild: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    #[test]
    fn matches_csr() {
        for seed in 0..3 {
            let m = random::power_law_rows(130, 170, 2.0, 40, seed);
            let x = random::vector(170, seed + 10);
            let mut expect = vec![0.0; 130];
            m.spmv(&x, &mut expect);
            for threads in [1, 4] {
                let eng = Spmv2dEngine::new(m.clone(), PartitionConfig::test_small(), threads);
                let mut y = vec![0.0; 130];
                eng.spmv(&x, &mut y);
                assert!(allclose(&y, &expect, 1e-10, 1e-12), "seed={seed} threads={threads}");
            }
        }
    }

    #[test]
    fn views_align_with_shell_blocks() {
        let m = random::uniform(100, 100, 0.05, 5);
        let eng = Spmv2dEngine::new(m, PartitionConfig::test_small(), 2);
        assert_eq!(eng.views.len(), eng.shell.blocks.len());
        for (v, b) in eng.views.iter().zip(&eng.shell.blocks) {
            assert_eq!(v.bi as u32, b.bi);
            assert_eq!(v.bj as u32, b.bj);
            assert_eq!(v.nnz, b.nnz);
        }
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(8, 8);
        let eng = Spmv2dEngine::new(m, PartitionConfig::test_small(), 4);
        let mut y = vec![1.0; 8];
        eng.spmv(&vec![1.0; 8], &mut y);
        assert_eq!(y, vec![0.0; 8]);
    }

    #[test]
    fn fused_spmm_matches_repeated_spmv() {
        let m = random::power_law_rows(160, 130, 2.0, 30, 21);
        for threads in [1, 4] {
            let eng = Spmv2dEngine::new(m.clone(), PartitionConfig::test_small(), threads);
            // k straddles the tile cap so the multi-pass path runs
            let k = SPMM_TILE + 3;
            let xs: Vec<Vec<f64>> = (0..k).map(|i| random::vector(130, i as u64)).collect();
            let mut ys: Vec<Vec<f64>> = vec![vec![0.0; 160]; k];
            eng.spmm(&xs, &mut ys);
            for (x, y) in xs.iter().zip(&ys) {
                let mut expect = vec![0.0; 160];
                eng.spmv(x, &mut expect);
                assert!(allclose(y, &expect, 1e-12, 1e-12), "threads={threads}");
            }
        }
    }

    #[test]
    fn update_value_only_and_pattern_change() {
        use crate::preprocess::MatrixDelta;
        let m = random::power_law_rows(80, 100, 2.0, 25, 13);
        let mut eng = Spmv2dEngine::new(m.clone(), PartitionConfig::test_small(), 2);
        let row = (0..80).find(|&r| m.row_nnz(r) >= 2).unwrap();
        // value-only: views untouched, output tracks the new values
        let r1 = eng.update(&MatrixDelta::new().scale_row(row, -1.5)).unwrap();
        assert!(!r1.full_rebuild);
        let x = random::vector(100, 5);
        let mut y = vec![0.0; 80];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 80];
        eng.m.spmv(&x, &mut expect);
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
        // pattern change: views + shell rebuilt, still correct
        let n = eng.m.row_nnz(row);
        let old = eng.m.row(row).0.to_vec();
        let new: Vec<u32> = (0..100u32).filter(|c| !old.contains(c)).take(n).collect();
        let r2 = eng
            .update(&MatrixDelta::new().replace_row(row, new, vec![1.0; n]))
            .unwrap();
        assert!(r2.full_rebuild);
        let mut y = vec![0.0; 80];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 80];
        eng.m.spmv(&x, &mut expect);
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
    }
}

//! Multithreaded HBP construction.
//!
//! The hash's atomicity means every block (and every row within a block)
//! reorders independently — no cross-block dependency, unlike zero-padding
//! conversions where each thread must know the padded length of everything
//! before it (the paper's §II critique of Regu2D).
//!
//! The build is the plan → fill pipeline of
//! [`crate::preprocess::hbp_build`]: the plan's prefix sums give every
//! block an exact disjoint slice of each output array, so workers fill
//! the final arrays **in place** through [`SharedMut`] (the same
//! disjointness contract as `spmv_partials`) — no per-chunk `Hbp`
//! partials, no stitch copy, and parallel output is bit-identical to
//! serial by construction. Work is scheduled on the persistent
//! process-wide [`WorkerPool`]s (`util::pool::shared_pool`) in
//! nnz-balanced contiguous chunks, instead of spawning threads per call.

use super::hbp_build::{alloc_from_plan, fill_block, fill_hbp_serial, plan_hbp, FillScratch};
use super::hbp_build::{fill_hbp_serial_with, BuildProfile, Hbp, HbpBlock, HbpPlan};
use super::reorder::Reorder;
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use crate::util::pool::{shared_pool, WorkerPool};
use crate::util::sync::SharedMut;
use crate::util::Timer;
use std::sync::atomic::{AtomicU64, Ordering};

/// Hard cap on shared-pool size: generous headroom over the machine's
/// parallelism, but a stop against absurd `--threads` values spawning
/// unbounded *permanent* OS threads through the pool registry.
pub(crate) fn pool_thread_cap() -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    cores.saturating_mul(4).max(32)
}

/// Parallel HBP build over `threads` workers (1 = serial fill; same code
/// path, same output).
pub fn build_hbp_parallel(
    m: &Csr,
    cfg: PartitionConfig,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> Hbp {
    let plan = plan_hbp(m, cfg);
    fill_hbp_parallel(m, &plan, reorder, threads)
}

/// Parallel fill of an existing plan. Public so callers that retain the
/// plan's [`crate::partition::BlockMap`] — the incremental-update path —
/// build without planning twice.
pub fn fill_hbp_parallel(
    m: &Csr,
    plan: &HbpPlan,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> Hbp {
    // ≤1 thread or ≤1 block: fill serially. Note `threads` is NOT
    // clamped to the block count before the pool lookup — that would
    // mint a permanent pool per distinct small block count; extra
    // workers beyond the chunk count simply return immediately.
    let threads = threads.min(pool_thread_cap());
    if threads <= 1 || plan.blocks.len() <= 1 {
        return fill_hbp_serial(m, plan, reorder);
    }
    fill_hbp_on(m, plan, reorder, &shared_pool(threads), None)
}

/// [`fill_hbp_parallel`] that also reports seconds spent inside the
/// reorder strategy (CPU-seconds: summed across workers). The returned
/// HBP is bit-identical to the unprofiled build — profiling only adds
/// clock reads around `order_into`.
pub fn fill_hbp_parallel_profiled(
    m: &Csr,
    plan: &HbpPlan,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> (Hbp, f64) {
    let threads = threads.min(pool_thread_cap());
    if threads <= 1 || plan.blocks.len() <= 1 {
        let mut scratch = FillScratch::profiled();
        let hbp = fill_hbp_serial_with(m, plan, reorder, &mut scratch);
        return (hbp, scratch.reorder_secs());
    }
    let acc = AtomicU64::new(0);
    let hbp = fill_hbp_on(m, plan, reorder, &shared_pool(threads), Some(&acc));
    (hbp, acc.load(Ordering::Relaxed) as f64 / 1e9)
}

/// Parallel build reporting the full phase breakdown — the entry point
/// behind `hbp info --profile` and the coordinator's register-time
/// [`BuildProfile`] metrics.
pub fn build_hbp_profiled(
    m: &Csr,
    cfg: PartitionConfig,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> (Hbp, BuildProfile) {
    let total = Timer::start();
    let (plan, plan_secs) = crate::util::timer::time(|| plan_hbp(m, cfg));
    let fill_t = Timer::start();
    let (hbp, reorder_secs) = fill_hbp_parallel_profiled(m, &plan, reorder, threads);
    let fill_secs = fill_t.elapsed_secs();
    (hbp, BuildProfile { plan_secs, reorder_secs, fill_secs, total_secs: total.elapsed_secs() })
}

/// Parallel HBP build on a caller-owned pool (for engines and services
/// that keep a long-lived [`WorkerPool`]).
pub fn build_hbp_pooled(
    m: &Csr,
    cfg: PartitionConfig,
    reorder: &(dyn Reorder + Sync),
    pool: &WorkerPool,
) -> Hbp {
    let plan = plan_hbp(m, cfg);
    if plan.blocks.is_empty() {
        return fill_hbp_serial(m, &plan, reorder);
    }
    fill_hbp_on(m, &plan, reorder, pool, None)
}

/// Contiguous nnz-balanced chunking of the block list: at most `workers`
/// chunks, preserving column-major order. Also reused by the partial
/// re-fill of `preprocess::update` over its gathered touched-block list.
pub(crate) fn nnz_chunks(blocks: &[HbpBlock], workers: usize) -> Vec<(usize, usize)> {
    let total: usize = blocks.iter().map(|b| b.nnz).sum();
    let target = total.div_ceil(workers).max(1);
    let mut chunks = Vec::with_capacity(workers);
    let mut start = 0;
    let mut acc = 0;
    for (i, b) in blocks.iter().enumerate() {
        acc += b.nnz;
        if acc >= target && i + 1 < blocks.len() && chunks.len() + 1 < workers {
            chunks.push((start, i + 1));
            start = i + 1;
            acc = 0;
        }
    }
    chunks.push((start, blocks.len()));
    chunks
}

/// Phase-2 parallel fill: one generation on the pool, each worker filling
/// its chunk's blocks directly into the final arrays. When `reorder_acc`
/// is supplied, each worker's time inside the reorder strategy is added
/// to it in integer nanoseconds (f64 atomics don't exist; ns fixed-point
/// loses nothing at profile granularity).
fn fill_hbp_on(
    m: &Csr,
    plan: &HbpPlan,
    reorder: &(dyn Reorder + Sync),
    pool: &WorkerPool,
    reorder_acc: Option<&AtomicU64>,
) -> Hbp {
    let mut hbp = alloc_from_plan(m, plan);
    let chunks = nnz_chunks(&plan.blocks, pool.workers.min(plan.blocks.len()).max(1));
    {
        let col = SharedMut::new(&mut hbp.col[..]);
        let data = SharedMut::new(&mut hbp.data[..]);
        let add_sign = SharedMut::new(&mut hbp.add_sign[..]);
        let zero_row = SharedMut::new(&mut hbp.zero_row[..]);
        let output_hash = SharedMut::new(&mut hbp.output_hash[..]);
        let begin_ptr = SharedMut::new(&mut hbp.begin_ptr[..]);
        let chunks = &chunks;
        pool.run_generation(|w, _| {
            let Some(&(lo, hi)) = chunks.get(w) else { return };
            let mut scratch = if reorder_acc.is_some() {
                FillScratch::profiled()
            } else {
                FillScratch::default()
            };
            for (b, e) in plan.blocks[lo..hi].iter().zip(&plan.map.blocks[lo..hi]) {
                // SAFETY: the plan's prefix sums make per-block ranges
                // disjoint, chunks partition the block list, and each
                // chunk is visited by exactly one worker — no two
                // threads ever touch the same index.
                let (c, d, a, z, o, p) = unsafe {
                    (
                        col.slice_mut(b.nnz_start, b.nnz),
                        data.slice_mut(b.nnz_start, b.nnz),
                        add_sign.slice_mut(b.nnz_start, b.nnz),
                        zero_row.slice_mut(b.slot_start, b.nrows),
                        output_hash.slice_mut(b.slot_start, b.nrows),
                        begin_ptr.slice_mut(b.group_start, b.ngroups),
                    )
                };
                let segs = &plan.map.segs[e.seg_start..e.seg_end];
                fill_block(m, &plan.grid, b, segs, reorder, &mut scratch, c, d, a, z, o, p);
            }
            if let Some(acc) = reorder_acc {
                acc.fetch_add((scratch.reorder_secs() * 1e9) as u64, Ordering::Relaxed);
            }
        });
    }
    hbp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::partition::PartitionConfig;
    use crate::preprocess::build_hbp_with;
    use crate::preprocess::reorder::HashReorder;

    #[test]
    fn parallel_equals_serial() {
        let m = random::power_law_rows(300, 300, 2.0, 60, 17);
        let cfg = PartitionConfig::test_small();
        let r = HashReorder::default();
        let serial = build_hbp_with(&m, cfg, &r);
        for threads in [2, 4, 7] {
            let par = build_hbp_parallel(&m, cfg, &r, threads);
            par.validate().unwrap();
            assert_eq!(serial.col, par.col, "threads={threads}");
            assert_eq!(serial.data, par.data);
            assert_eq!(serial.add_sign, par.add_sign);
            assert_eq!(serial.zero_row, par.zero_row);
            assert_eq!(serial.output_hash, par.output_hash);
            assert_eq!(serial.begin_ptr, par.begin_ptr);
            assert_eq!(serial.blocks.len(), par.blocks.len());
        }
    }

    #[test]
    fn more_threads_than_blocks() {
        let m = random::uniform(10, 10, 0.5, 3);
        let cfg = PartitionConfig::test_small();
        let hbp = build_hbp_parallel(&m, cfg, &HashReorder::default(), 64);
        hbp.validate().unwrap();
        assert_eq!(hbp.nnz(), m.nnz());
    }

    #[test]
    fn empty_matrix_parallel() {
        let m = crate::formats::Csr::empty(100, 100);
        let hbp = build_hbp_parallel(&m, PartitionConfig::test_small(), &HashReorder::default(), 4);
        assert!(hbp.blocks.is_empty());
    }

    #[test]
    fn pooled_build_matches_serial() {
        let m = random::power_law_rows(200, 250, 2.0, 50, 23);
        let cfg = PartitionConfig::test_small();
        let r = HashReorder::default();
        let serial = build_hbp_with(&m, cfg, &r);
        let pool = crate::util::pool::WorkerPool::new(3);
        for _ in 0..3 {
            // repeated builds on the same pool must be identical (the
            // persistent-pool path the router/bench loop exercises)
            let par = build_hbp_pooled(&m, cfg, &r, &pool);
            par.validate().unwrap();
            assert_eq!(serial.col, par.col);
            assert_eq!(serial.data, par.data);
            assert_eq!(serial.begin_ptr, par.begin_ptr);
        }
    }

    #[test]
    fn profiled_build_is_bit_identical_and_phases_are_sane() {
        let m = random::power_law_rows(300, 300, 2.0, 60, 17);
        let cfg = PartitionConfig::test_small();
        let r = HashReorder::default();
        let plain = build_hbp_with(&m, cfg, &r);
        for threads in [1usize, 4] {
            let (hbp, p) = build_hbp_profiled(&m, cfg, &r, threads);
            hbp.validate().unwrap();
            assert_eq!(plain.col, hbp.col, "threads={threads}");
            assert_eq!(plain.data, hbp.data);
            assert_eq!(plain.output_hash, hbp.output_hash);
            assert!(p.plan_secs >= 0.0 && p.reorder_secs >= 0.0);
            assert!(p.fill_secs >= 0.0 && p.total_secs > 0.0);
            // phase wall times nest inside the total (reorder is
            // CPU-seconds, so it is only bounded on the serial path)
            assert!(p.plan_secs + p.fill_secs <= p.total_secs + 1e-6);
            if threads == 1 {
                assert!(p.reorder_secs <= p.fill_secs + 1e-6);
            }
        }
    }

    #[test]
    fn nnz_chunks_partition_blocks() {
        let m = random::power_law_rows(300, 300, 2.0, 60, 9);
        let plan = super::plan_hbp(&m, PartitionConfig::test_small());
        for workers in [1usize, 2, 3, 8, 200] {
            let chunks = nnz_chunks(&plan.blocks, workers);
            assert!(chunks.len() <= workers, "workers={workers}");
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, plan.blocks.len());
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0, "chunks must tile contiguously");
                assert!(w[0].0 < w[0].1, "empty chunk");
            }
        }
    }
}

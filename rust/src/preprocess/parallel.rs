//! Multithreaded HBP construction.
//!
//! The hash's atomicity means every block (and every row within a block)
//! reorders independently — no cross-block dependency, unlike zero-padding
//! conversions where each thread must know the padded length of everything
//! before it (the paper's §II critique of Regu2D). Blocks are built in
//! parallel chunks and stitched with pure offset arithmetic.

use super::hbp_build::{append_block, Hbp};
use super::reorder::Reorder;
use crate::formats::Csr;
use crate::partition::{block_views, BlockGrid, PartitionConfig};

/// Parallel HBP build over `threads` workers (1 = serial fallback).
pub fn build_hbp_parallel(
    m: &Csr,
    cfg: PartitionConfig,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> Hbp {
    cfg.validate().expect("invalid partition config");
    let grid = BlockGrid::new(m.rows, m.cols, cfg);
    let views = block_views(m, &grid);
    let threads = threads.clamp(1, views.len().max(1));

    let empty = |grid: BlockGrid| Hbp {
        rows: m.rows,
        cols: m.cols,
        grid,
        blocks: vec![],
        col: vec![],
        data: vec![],
        add_sign: vec![],
        zero_row: vec![],
        output_hash: vec![],
        begin_ptr: vec![],
    };

    if threads <= 1 || views.is_empty() {
        let mut hbp = empty(grid);
        for v in &views {
            append_block(&mut hbp, m, v, reorder);
        }
        return hbp;
    }

    // nnz-balanced contiguous chunking (preserves column-major order)
    let total_nnz: usize = views.iter().map(|v| v.nnz).sum();
    let target = total_nnz.div_ceil(threads);
    let mut chunks: Vec<&[crate::partition::BlockView]> = vec![];
    let mut start = 0;
    let mut acc = 0;
    for (i, v) in views.iter().enumerate() {
        acc += v.nnz;
        if acc >= target && i + 1 < views.len() {
            chunks.push(&views[start..=i]);
            start = i + 1;
            acc = 0;
        }
    }
    chunks.push(&views[start..]);

    // build per-chunk partials in parallel
    let partials: Vec<Hbp> = std::thread::scope(|scope| {
        let handles: Vec<_> = chunks
            .iter()
            .map(|chunk| {
                scope.spawn(move || {
                    let mut part = empty(grid);
                    for v in *chunk {
                        append_block(&mut part, m, v, reorder);
                    }
                    part
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("builder thread panicked")).collect()
    });

    // stitch with offset fixups
    let mut out = empty(grid);
    for mut part in partials {
        let nnz_base = out.col.len();
        let slot_base = out.zero_row.len();
        let group_base = out.begin_ptr.len();
        for b in &mut part.blocks {
            b.nnz_start += nnz_base;
            b.slot_start += slot_base;
            b.group_start += group_base;
        }
        for p in &mut part.begin_ptr {
            *p += nnz_base;
        }
        out.blocks.append(&mut part.blocks);
        out.col.append(&mut part.col);
        out.data.append(&mut part.data);
        out.add_sign.append(&mut part.add_sign);
        out.zero_row.append(&mut part.zero_row);
        out.output_hash.append(&mut part.output_hash);
        out.begin_ptr.append(&mut part.begin_ptr);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::preprocess::reorder::HashReorder;
    use crate::preprocess::build_hbp_with;
    use crate::partition::PartitionConfig;

    #[test]
    fn parallel_equals_serial() {
        let m = random::power_law_rows(300, 300, 2.0, 60, 17);
        let cfg = PartitionConfig::test_small();
        let r = HashReorder::default();
        let serial = build_hbp_with(&m, cfg, &r);
        for threads in [2, 4, 7] {
            let par = build_hbp_parallel(&m, cfg, &r, threads);
            par.validate().unwrap();
            assert_eq!(serial.col, par.col, "threads={threads}");
            assert_eq!(serial.data, par.data);
            assert_eq!(serial.add_sign, par.add_sign);
            assert_eq!(serial.zero_row, par.zero_row);
            assert_eq!(serial.output_hash, par.output_hash);
            assert_eq!(serial.begin_ptr, par.begin_ptr);
            assert_eq!(serial.blocks.len(), par.blocks.len());
        }
    }

    #[test]
    fn more_threads_than_blocks() {
        let m = random::uniform(10, 10, 0.5, 3);
        let cfg = PartitionConfig::test_small();
        let hbp = build_hbp_parallel(&m, cfg, &HashReorder::default(), 64);
        hbp.validate().unwrap();
        assert_eq!(hbp.nnz(), m.nnz());
    }

    #[test]
    fn empty_matrix_parallel() {
        let m = crate::formats::Csr::empty(100, 100);
        let hbp = build_hbp_parallel(&m, PartitionConfig::test_small(), &HashReorder::default(), 4);
        assert!(hbp.blocks.is_empty());
    }
}

//! Row-reordering strategies compared in Fig. 7.
//!
//! Every strategy maps a block's per-row nonzero counts to an execution
//! order (a permutation of local rows). The HBP engine is agnostic to
//! which strategy produced the order — that is what makes the Fig. 6/7
//! comparisons apples-to-apples.

use crate::hash::{sample_params, HashTable, NonlinearHash};

/// A row-reordering strategy.
pub trait Reorder: Sync {
    /// Write `order[slot] = local row` — a permutation of
    /// `0..row_nnz.len()` — into `out` (cleared first, capacity reused).
    /// `row_nnz[i]` = in-block nonzeros of local row `i`; `warp` is
    /// provided because some strategies (DP) group-align. This is the
    /// required method so the allocation-free path is the one every
    /// strategy provides: the plan/fill HBP builder calls it once per
    /// block with a per-worker scratch vector.
    fn order_into(&self, out: &mut Vec<u32>, row_nnz: &[usize], warp: usize);

    /// Allocating convenience wrapper around [`Reorder::order_into`].
    fn order(&self, row_nnz: &[usize], warp: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.order_into(&mut out, row_nnz, warp);
        out
    }

    /// Display name for bench tables.
    fn name(&self) -> &'static str;
}

/// Plain 2D-partitioning: no reordering (the paper's "2D" baseline).
pub struct IdentityReorder;

impl Reorder for IdentityReorder {
    fn order_into(&self, out: &mut Vec<u32>, row_nnz: &[usize], _warp: usize) {
        out.clear();
        out.extend(0..row_nnz.len() as u32);
    }
    fn name(&self) -> &'static str {
        "2d"
    }
}

/// The paper's nonlinear-hash reordering (HBP).
///
/// O(R) with a tiny constant and no comparison sort anywhere — the
/// entire Fig. 7 speedup story. Collisions are resolved by **chaining
/// flattened in slot order** (counting placement): rows hashing to the
/// same slot execute consecutively, exactly the aggregation property the
/// warp grouping needs, in four linear passes that vectorize and
/// parallelize (the paper's argument for why hashing beats sorting on
/// device). The probing variant ([`HashReorder::order_probing`],
/// backed by [`HashTable`]) gives the same grouping quality at higher
/// cost — compared in `benches/ablation_hash_params.rs`.
pub struct HashReorder {
    pub seed: u64,
}

impl Default for HashReorder {
    fn default() -> Self {
        HashReorder { seed: 0x9A5 }
    }
}

impl HashReorder {
    /// Alternative collision strategy: first-free-slot probing (the
    /// union-find table). Same aggregation quality, ~2-3x slower build;
    /// kept for the ablation and as the reference semantics.
    pub fn order_probing(&self, row_nnz: &[usize]) -> Vec<u32> {
        let n = row_nnz.len();
        if n == 0 {
            return vec![];
        }
        let params = sample_params(row_nnz, n, self.seed);
        let h = NonlinearHash::new(params);
        let mut t = HashTable::new(n);
        for (r, &l) in row_nnz.iter().enumerate() {
            t.insert(&h, r as u32, l);
        }
        t.into_output_hash()
    }
}

impl Reorder for HashReorder {
    fn order_into(&self, out: &mut Vec<u32>, row_nnz: &[usize], _warp: usize) {
        let n = row_nnz.len();
        out.clear();
        if n == 0 {
            return;
        }
        let params = sample_params(row_nnz, n, self.seed);
        let h = NonlinearHash::new(params);
        // counting placement: count pass, prefix pass, stable scatter.
        // The counts buffer is thread-local scratch (preprocessing is
        // per-block parallel; allocation here is the Fig. 7 hot path)
        // and keys are recomputed rather than stored — slot() is a few
        // ALU ops, cheaper than a second O(n) array round-trip.
        thread_local! {
            static COUNTS: std::cell::RefCell<(Vec<u32>, Vec<u32>)> =
                const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
        }
        COUNTS.with(|c| {
            let mut scratch = c.borrow_mut();
            let (counts, keys) = &mut *scratch;
            // invariant: `counts` is all-zero between calls; only the
            // touched slot range is re-zeroed below, so uniform blocks
            // (banded matrices: every row hashes to one slot) cost ~2n.
            if counts.len() < n + 1 {
                counts.resize(n + 1, 0);
            }
            keys.clear();
            keys.reserve(n);
            let mut min_k = usize::MAX;
            let mut max_k = 0usize;
            for &l in row_nnz {
                let k = h.slot(l);
                keys.push(k as u32);
                counts[k] += 1;
                min_k = min_k.min(k);
                max_k = max_k.max(k);
            }
            let mut acc = 0u32;
            for c in counts[min_k..=max_k].iter_mut() {
                let t = *c;
                *c = acc;
                acc += t;
            }
            // scatter writes every position of `out` exactly once
            // (slot counts sum to n), so skip the zero-init
            out.reserve(n);
            #[allow(clippy::uninit_vec)]
            unsafe {
                out.set_len(n);
            }
            for (r, &k) in keys.iter().enumerate() {
                let slot = &mut counts[k as usize];
                // SAFETY: *slot < n by the counting-sort invariant
                unsafe { *out.get_unchecked_mut(*slot as usize) = r as u32 };
                *slot += 1;
            }
            // restore the all-zero invariant
            for c in counts[min_k..=max_k].iter_mut() {
                *c = 0;
            }
        })
    }
    fn name(&self) -> &'static str {
        "hbp"
    }
}

/// sort2D baseline: stable sort of rows by nonzero count.
///
/// Produces the *optimal* grouping quality (monotone lengths => groups of
/// near-identical rows) at O(R log R) serial cost — the quality ceiling
/// the hash approximates, and the preprocessing cost HBP beats.
pub struct SortReorder;

impl Reorder for SortReorder {
    fn order_into(&self, out: &mut Vec<u32>, row_nnz: &[usize], _warp: usize) {
        out.clear();
        out.extend(0..row_nnz.len() as u32);
        out.sort_by_key(|&r| row_nnz[r as usize]);
    }
    fn name(&self) -> &'static str {
        "sort2d"
    }
}

/// DP2D baseline: the Regu2D-style dynamic-programming arrangement.
///
/// Regu2D sorts rows by length, then uses DP to partition the sorted
/// sequence into contiguous groups (each padded to its longest row) that
/// minimize total padded storage, subject to a maximum group extent of
/// `MAX_GROUPS_SPAN` warps. The DP runs *after* a full sort — which is
/// why the paper reports it even slower than sort2D alone.
pub struct DpReorder {
    /// Max group span in warps (Regu2D merges up to a few vector widths).
    pub max_span_warps: usize,
}

impl Default for DpReorder {
    fn default() -> Self {
        DpReorder { max_span_warps: 4 }
    }
}

impl Reorder for DpReorder {
    fn order_into(&self, out: &mut Vec<u32>, row_nnz: &[usize], warp: usize) {
        let n = row_nnz.len();
        out.clear();
        if n == 0 {
            return;
        }
        // 1) sort descending (dense rows execute together first)
        out.extend(0..n as u32);
        out.sort_by_key(|&r| std::cmp::Reverse(row_nnz[r as usize]));
        let idx = &out[..];

        // 2) DP over the sorted sequence: dp[i] = min padded cells for
        // suffix starting at i; group sizes are multiples of `warp`
        // up to max_span_warps*warp (the vectorization constraint).
        // dp/cut are the DP baseline's modeled cost, deliberately kept
        // per-call: this is what Fig. 7 charges Regu2D for.
        let warp = warp.max(1);
        let max_group = (self.max_span_warps * warp).max(warp);
        let mut dp = vec![u64::MAX; n + 1];
        let mut cut = vec![0usize; n + 1];
        dp[n] = 0;
        for i in (0..n).rev() {
            // descending => max of any group starting at i
            let longest = row_nnz[idx[i] as usize] as u64;
            let mut size = warp;
            while size <= max_group {
                let j = (i + size).min(n);
                if dp[j] != u64::MAX {
                    let cost = longest * (j - i) as u64 + dp[j];
                    if cost < dp[i] {
                        dp[i] = cost;
                        cut[i] = j;
                    }
                }
                if j == n {
                    break;
                }
                size += warp;
            }
            if dp[i] == u64::MAX {
                // fallback: single warp group
                let j = (i + warp).min(n);
                dp[i] = longest * (j - i) as u64 + dp[j];
                cut[i] = j;
            }
        }

        // 3) the DP's groups tile [0, n) contiguously in increasing
        // order, so emitting them concatenates consecutive ranges of
        // `idx` — the final order IS the sorted sequence (group
        // boundaries are implicit every `warp` slots downstream), and
        // `out` already holds it. Verify the tiling in debug builds.
        #[cfg(debug_assertions)]
        {
            let mut i = 0usize;
            while i < n {
                debug_assert!(cut[i] > i && cut[i] <= n, "bad DP cut at {i}");
                i = cut[i];
            }
        }
    }
    fn name(&self) -> &'static str {
        "dp2d"
    }
}

/// Check that a strategy's output is a permutation (shared test helper,
/// also used by the property suite).
pub fn is_permutation(order: &[u32]) -> bool {
    let n = order.len();
    let mut seen = vec![false; n];
    for &r in order {
        let r = r as usize;
        if r >= n || seen[r] {
            return false;
        }
        seen[r] = true;
    }
    true
}

/// Per-group standard deviations of row lengths under an ordering — the
/// Fig. 6 metric ("standard deviation of nonzero elements per warp of
/// rows within a matrix block").
pub fn group_stddevs(row_nnz: &[usize], order: &[u32], warp: usize) -> Vec<f64> {
    order
        .chunks(warp.max(1))
        .map(|chunk| {
            let lens: Vec<f64> = chunk.iter().map(|&r| row_nnz[r as usize] as f64).collect();
            crate::util::Stats::of(&lens).std
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_lens(n: usize, seed: u64) -> Vec<usize> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.power_law(2.0, 300)).collect()
    }

    #[test]
    fn all_strategies_produce_permutations() {
        let lens = random_lens(512, 3);
        let strategies: Vec<Box<dyn Reorder>> = vec![
            Box::new(IdentityReorder),
            Box::new(HashReorder::default()),
            Box::new(SortReorder),
            Box::new(DpReorder::default()),
        ];
        for s in &strategies {
            let o = s.order(&lens, 32);
            assert!(is_permutation(&o), "{} not a permutation", s.name());
        }
    }

    #[test]
    fn sort_is_monotone() {
        let lens = random_lens(128, 5);
        let o = SortReorder.order(&lens, 32);
        for w in o.windows(2) {
            assert!(lens[w[0] as usize] <= lens[w[1] as usize]);
        }
    }

    #[test]
    fn hash_reduces_group_stddev_vs_identity() {
        // the Fig. 6 claim, as a unit test
        let lens = random_lens(512, 11);
        let id = IdentityReorder.order(&lens, 32);
        let hash = HashReorder::default().order(&lens, 32);
        let before: f64 = group_stddevs(&lens, &id, 32).iter().sum();
        let after: f64 = group_stddevs(&lens, &hash, 32).iter().sum();
        assert!(
            after < before * 0.8,
            "hash should reduce total group stddev: before={before:.1} after={after:.1}"
        );
    }

    #[test]
    fn sort_is_the_quality_ceiling() {
        let lens = random_lens(512, 13);
        let hash = HashReorder::default().order(&lens, 32);
        let sort = SortReorder.order(&lens, 32);
        let h: f64 = group_stddevs(&lens, &hash, 32).iter().sum();
        let s: f64 = group_stddevs(&lens, &sort, 32).iter().sum();
        assert!(s <= h + 1e-9, "sort quality {s:.2} should lower-bound hash {h:.2}");
    }

    #[test]
    fn dp_groups_align_and_cover() {
        let lens = random_lens(200, 7);
        let o = DpReorder::default().order(&lens, 32);
        assert!(is_permutation(&o));
        // descending within the whole order except at group boundaries:
        // at least verify all rows present and heavy rows early
        let first_group_mean: f64 =
            o[..32].iter().map(|&r| lens[r as usize] as f64).sum::<f64>() / 32.0;
        let last_group_mean: f64 =
            o[o.len() - 32..].iter().map(|&r| lens[r as usize] as f64).sum::<f64>() / 32.0;
        assert!(first_group_mean >= last_group_mean);
    }

    #[test]
    fn order_into_matches_order_and_reuses_buffer() {
        let lens = random_lens(300, 17);
        let strategies: Vec<Box<dyn Reorder>> = vec![
            Box::new(IdentityReorder),
            Box::new(HashReorder::default()),
            Box::new(SortReorder),
            Box::new(DpReorder::default()),
        ];
        let mut out = Vec::new();
        for s in &strategies {
            s.order_into(&mut out, &lens, 32);
            assert_eq!(out, s.order(&lens, 32), "{} order_into != order", s.name());
            let cap = out.capacity();
            s.order_into(&mut out, &lens, 32);
            assert_eq!(cap, out.capacity(), "{} grew the scratch buffer", s.name());
            s.order_into(&mut out, &[], 32);
            assert!(out.is_empty(), "{} nonempty on empty input", s.name());
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        for s in [&HashReorder::default() as &dyn Reorder, &SortReorder, &DpReorder::default()] {
            assert!(s.order(&[], 32).is_empty());
            assert_eq!(s.order(&[5], 32), vec![0]);
        }
    }
}

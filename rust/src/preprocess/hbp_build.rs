//! The HBP (Hash-Based Partition) format and its construction
//! (paper §III-A/B, Fig. 2, Algorithm 2 + the format-conversion step).
//!
//! Per non-empty 2D block, rows are reordered by a [`Reorder`] strategy
//! into *slots*; consecutive `warp` slots form a *group* executed in
//! SIMT lockstep. Within a group, elements are stored **round-major**
//! ("column-major" in the paper's figure): round `k` holds the `k`-th
//! nonzero of every still-active row, consecutively in slot order. This
//! is the coalescing-friendly layout that Table II's memory-throughput
//! jump comes from.
//!
//! Arrays (Fig. 2):
//! - `col`, `data` — nonzeros in execution order; `col` stores
//!   **block-local** column indices (the paper's `vect[col[j] % N]`
//!   pre-applied), so engines index the block's vector segment directly.
//! - `add_sign[j]` — distance from element `j` to the same row's next
//!   element, `-1` if `j` is the row's last element.
//! - `zero_row[slot]` — `-1` if the slot's row has no nonzeros in this
//!   block, else the number of zero-rows before it *within its group*
//!   (so `lane - zero_row` = the lane's rank among active rows).
//! - `output_hash[slot]` — the original local row (where results go).
//! - `begin_ptr[group]` — offset of the group's first element.
//! - `begin_nnz[block]` — offset of the block's first element
//!   (CSR-ptr equivalent at block granularity).

use crate::formats::Csr;
use crate::partition::{block_views, BlockGrid, BlockView, PartitionConfig};
use crate::preprocess::reorder::{HashReorder, Reorder};

/// Per-block descriptor.
#[derive(Clone, Copy, Debug)]
pub struct HbpBlock {
    /// Row-block index.
    pub bi: u32,
    /// Column-block index.
    pub bj: u32,
    /// Start of this block's elements in `col`/`data`/`add_sign`
    /// (the paper's `begin_nnz`).
    pub nnz_start: usize,
    pub nnz: usize,
    /// Start of this block's slots in `zero_row`/`output_hash`.
    pub slot_start: usize,
    /// Rows (= slots) in this block; edge blocks may be short.
    pub nrows: usize,
    /// Start of this block's groups in `begin_ptr`.
    pub group_start: usize,
    pub ngroups: usize,
}

/// The HBP matrix.
#[derive(Clone, Debug)]
pub struct Hbp {
    pub rows: usize,
    pub cols: usize,
    pub grid: BlockGrid,
    /// Non-empty blocks, column-major (fixed-allocation order, §III-C).
    pub blocks: Vec<HbpBlock>,
    pub col: Vec<u32>,
    pub data: Vec<f64>,
    pub add_sign: Vec<i32>,
    pub zero_row: Vec<i32>,
    pub output_hash: Vec<u32>,
    pub begin_ptr: Vec<usize>,
}

impl Hbp {
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Approximate in-memory footprint (storage-cost ablation): fixed,
    /// unlike zero-padding formats — the paper's §III-A storage argument.
    pub fn storage_bytes(&self) -> usize {
        self.col.len() * 4
            + self.data.len() * 8
            + self.add_sign.len() * 4
            + self.zero_row.len() * 4
            + self.output_hash.len() * 4
            + self.begin_ptr.len() * 8
            + self.blocks.len() * std::mem::size_of::<HbpBlock>()
    }

    /// Structural invariants — exercised by the property suite.
    pub fn validate(&self) -> anyhow::Result<()> {
        let warp = self.grid.cfg.warp;
        anyhow::ensure!(self.col.len() == self.data.len());
        anyhow::ensure!(self.add_sign.len() == self.data.len());
        let mut nnz_cursor = 0usize;
        let mut slot_cursor = 0usize;
        let mut group_cursor = 0usize;
        for (i, b) in self.blocks.iter().enumerate() {
            anyhow::ensure!(b.nnz_start == nnz_cursor, "block {i} nnz_start");
            anyhow::ensure!(b.slot_start == slot_cursor, "block {i} slot_start");
            anyhow::ensure!(b.group_start == group_cursor, "block {i} group_start");
            anyhow::ensure!(b.nnz > 0, "block {i} empty");
            anyhow::ensure!(b.ngroups == b.nrows.div_ceil(warp), "block {i} ngroups");
            // output_hash is a permutation of local rows
            let oh = &self.output_hash[b.slot_start..b.slot_start + b.nrows];
            let mut seen = vec![false; b.nrows];
            for &r in oh {
                anyhow::ensure!(
                    (r as usize) < b.nrows && !seen[r as usize],
                    "block {i} output_hash not a permutation"
                );
                seen[r as usize] = true;
            }
            // add_sign chains cover exactly the block's element range
            let mut covered = vec![false; b.nnz];
            for g in 0..b.ngroups {
                let gslots = (g * warp)..(((g + 1) * warp).min(b.nrows));
                let gp = self.begin_ptr[b.group_start + g];
                let mut active_rank = 0usize;
                for s in gslots {
                    let z = self.zero_row[b.slot_start + s];
                    if z == -1 {
                        continue;
                    }
                    let mut j = gp + active_rank;
                    active_rank += 1;
                    loop {
                        let local = j - b.nnz_start;
                        anyhow::ensure!(local < b.nnz, "block {i} walk out of range");
                        anyhow::ensure!(!covered[local], "block {i} element {local} visited twice");
                        covered[local] = true;
                        match self.add_sign[j] {
                            -1 => break,
                            step if step > 0 => j += step as usize,
                            bad => anyhow::bail!("block {i} bad add_sign {bad}"),
                        }
                    }
                }
            }
            anyhow::ensure!(covered.iter().all(|&c| c), "block {i} uncovered elements");
            nnz_cursor += b.nnz;
            slot_cursor += b.nrows;
            group_cursor += b.ngroups;
        }
        anyhow::ensure!(nnz_cursor == self.nnz(), "total nnz mismatch");
        Ok(())
    }
}

/// Build HBP with the paper's hash reordering.
pub fn build_hbp(m: &Csr, cfg: PartitionConfig) -> Hbp {
    build_hbp_with(m, cfg, &HashReorder::default())
}

/// Build HBP with an arbitrary reorder strategy (sort2D / DP2D / identity
/// for the baselines — downstream engines are strategy-agnostic).
pub fn build_hbp_with(m: &Csr, cfg: PartitionConfig, reorder: &dyn Reorder) -> Hbp {
    cfg.validate().expect("invalid partition config");
    let grid = BlockGrid::new(m.rows, m.cols, cfg);
    let views = block_views(m, &grid);

    let mut hbp = Hbp {
        rows: m.rows,
        cols: m.cols,
        grid,
        blocks: Vec::with_capacity(views.len()),
        col: Vec::with_capacity(m.nnz()),
        data: Vec::with_capacity(m.nnz()),
        add_sign: Vec::with_capacity(m.nnz()),
        zero_row: vec![],
        output_hash: vec![],
        begin_ptr: vec![],
    };

    for view in &views {
        append_block(&mut hbp, m, view, reorder);
    }
    hbp
}

/// Build one block's arrays and append (shared with the parallel builder,
/// which builds per-block chunks independently then stitches).
pub(crate) fn append_block(hbp: &mut Hbp, m: &Csr, view: &BlockView, reorder: &dyn Reorder) {
    let cfg = hbp.grid.cfg;
    let warp = cfg.warp;
    let nrows = view.row_ranges.len();
    let row_nnz = view.row_nnz();
    let (col_start, _) = hbp.grid.col_range(view.bj);

    let order = reorder.order(&row_nnz, warp);
    debug_assert_eq!(order.len(), nrows);

    let block = HbpBlock {
        bi: view.bi as u32,
        bj: view.bj as u32,
        nnz_start: hbp.col.len(),
        nnz: view.nnz,
        slot_start: hbp.zero_row.len(),
        nrows,
        group_start: hbp.begin_ptr.len(),
        ngroups: nrows.div_ceil(warp),
    };

    // output_hash: slot -> original local row
    hbp.output_hash.extend_from_slice(&order);

    // per group: zero_row bookkeeping + round-major element emission
    let mut prev_pos: Vec<usize> = vec![usize::MAX; nrows]; // by local row
    for g in 0..block.ngroups {
        let slot_lo = g * warp;
        let slot_hi = ((g + 1) * warp).min(nrows);
        hbp.begin_ptr.push(hbp.col.len());

        // zero_row: -1 for inactive; else #zeros before it in the group
        let mut zeros_before = 0i32;
        let mut active: Vec<u32> = Vec::with_capacity(slot_hi - slot_lo);
        for s in slot_lo..slot_hi {
            let r = order[s];
            if row_nnz[r as usize] == 0 {
                hbp.zero_row.push(-1);
                zeros_before += 1;
            } else {
                hbp.zero_row.push(zeros_before);
                active.push(r);
            }
        }

        // round-major emission: round k emits the k-th nonzero of every
        // row still active; rows retire as they exhaust.
        let mut k = 0usize;
        let mut live = active;
        while !live.is_empty() {
            live.retain(|&r| {
                let (s, e) = view.row_ranges[r as usize];
                if s + k >= e {
                    return false;
                }
                let src = s + k;
                let pos = hbp.col.len();
                hbp.col.push(m.col[src] - col_start as u32);
                hbp.data.push(m.data[src]);
                hbp.add_sign.push(-1); // patched when the next round emits
                if prev_pos[r as usize] != usize::MAX {
                    let prev = prev_pos[r as usize];
                    hbp.add_sign[prev] = (pos - prev) as i32;
                }
                prev_pos[r as usize] = pos;
                true
            });
            k += 1;
        }
    }

    hbp.blocks.push(block);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::gen::random;
    use crate::preprocess::reorder::{IdentityReorder, SortReorder};

    fn small_cfg() -> PartitionConfig {
        PartitionConfig::test_small() // 16 rows, 32 cols, warp 4
    }

    #[test]
    fn single_block_structure() {
        // 4 rows, 8 cols, one block
        let mut coo = Coo::new(4, 8);
        coo.push(0, 1, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(1, 0, 3.0);
        coo.push(3, 2, 4.0);
        coo.push(3, 5, 5.0);
        coo.push(3, 7, 6.0);
        let m = coo.to_csr();
        let hbp = build_hbp_with(&m, small_cfg(), &IdentityReorder);
        assert_eq!(hbp.blocks.len(), 1);
        let b = hbp.blocks[0];
        assert_eq!(b.nnz, 6);
        assert_eq!(b.nrows, 4);
        assert_eq!(b.ngroups, 1);
        hbp.validate().unwrap();
        // identity order: slots = rows; row 2 is a zero row, so row 3 has
        // one zero-row before it within the group
        assert_eq!(hbp.zero_row, vec![0, 0, -1, 1]);
        // round-major: round0 = first elems of rows 0,1,3 -> cols 1,0,2
        assert_eq!(&hbp.col[0..3], &[1, 0, 2]);
        // add_sign of row0's first element: 3 active rows -> stride 3
        assert_eq!(hbp.add_sign[0], 3);
        // row1 has 1 elem -> -1 immediately
        assert_eq!(hbp.add_sign[1], -1);
    }

    #[test]
    fn local_column_indices() {
        // matrix wide enough for 2 col blocks (cols_per_block = 32)
        let mut coo = Coo::new(4, 64);
        coo.push(0, 33, 1.0);
        coo.push(2, 63, 2.0);
        let m = coo.to_csr();
        let hbp = build_hbp_with(&m, small_cfg(), &IdentityReorder);
        assert_eq!(hbp.blocks.len(), 1); // only col-block 1 nonempty
        assert_eq!(hbp.blocks[0].bj, 1);
        // local col = global - 32
        let mut cols = hbp.col.clone();
        cols.sort_unstable();
        assert_eq!(cols, vec![1, 31]);
    }

    #[test]
    fn validates_on_random_matrices_all_strategies() {
        for seed in 0..5 {
            let m = random::power_law_rows(100, 150, 2.0, 40, seed);
            for r in [
                &HashReorder::default() as &dyn Reorder,
                &IdentityReorder,
                &SortReorder,
            ] {
                let hbp = build_hbp_with(&m, small_cfg(), r);
                hbp.validate()
                    .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", r.name()));
                assert_eq!(hbp.nnz(), m.nnz());
            }
        }
    }

    #[test]
    fn storage_is_fixed_no_padding() {
        // HBP stores exactly nnz elements regardless of skew — the paper's
        // fixed-storage-cost claim vs zero padding.
        let skewed = random::with_row_lengths(&[1, 1, 1, 30], 32, 3);
        let hbp = build_hbp(&skewed, small_cfg());
        assert_eq!(hbp.col.len(), skewed.nnz());
        assert_eq!(hbp.data.len(), skewed.nnz());
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(8, 8);
        let hbp = build_hbp(&m, small_cfg());
        assert!(hbp.blocks.is_empty());
        hbp.validate().unwrap();
    }

    #[test]
    fn edge_partial_block_and_group() {
        // 18 rows with warp 4, rows_per_block 16 -> second block has 2 rows
        let m = random::uniform(18, 20, 0.3, 7);
        let hbp = build_hbp(&m, small_cfg());
        hbp.validate().unwrap();
        let total_rows: usize = hbp.blocks.iter().map(|b| b.nrows).sum();
        // all blocks are in col-block 0; row coverage = rows with nnz blocks
        assert!(total_rows <= 18 + 16);
    }

    #[test]
    fn begin_nnz_equivalent_monotone() {
        let m = random::uniform(64, 64, 0.1, 21);
        let hbp = build_hbp(&m, small_cfg());
        for w in hbp.blocks.windows(2) {
            assert_eq!(w[0].nnz_start + w[0].nnz, w[1].nnz_start);
        }
    }
}

//! The HBP (Hash-Based Partition) format and its construction
//! (paper §III-A/B, Fig. 2, Algorithm 2 + the format-conversion step).
//!
//! Per non-empty 2D block, rows are reordered by a [`Reorder`] strategy
//! into *slots*; consecutive `warp` slots form a *group* executed in
//! SIMT lockstep. Within a group, elements are stored **round-major**
//! ("column-major" in the paper's figure): round `k` holds the `k`-th
//! nonzero of every still-active row, consecutively in slot order. This
//! is the coalescing-friendly layout that Table II's memory-throughput
//! jump comes from.
//!
//! Arrays (Fig. 2):
//! - `col`, `data` — nonzeros in execution order; `col` stores
//!   **block-local** column indices (the paper's `vect[col[j] % N]`
//!   pre-applied), so engines index the block's vector segment directly.
//! - `add_sign[j]` — distance from element `j` to the same row's next
//!   element, `-1` if `j` is the row's last element.
//! - `zero_row[slot]` — `-1` if the slot's row has no nonzeros in this
//!   block, else the number of zero-rows before it *within its group*
//!   (so `lane - zero_row` = the lane's rank among active rows).
//! - `output_hash[slot]` — the original local row (where results go).
//! - `begin_ptr[group]` — offset of the group's first element.
//! - `begin_nnz[block]` — offset of the block's first element
//!   (CSR-ptr equivalent at block granularity).
//!
//! # Construction: plan → fill
//!
//! Building is a two-phase, zero-copy pipeline:
//!
//! 1. **Plan** ([`plan_hbp`]): one counting pass over the CSR produces
//!    the [`BlockMap`] (non-empty blocks + sparse row segments only),
//!    then per-block `nnz`/`nrows`/`ngroups` prefix-sum into the exact
//!    final `nnz_start`/`slot_start`/`group_start` offsets — the
//!    complete `blocks: Vec<HbpBlock>` — before any element moves.
//! 2. **Fill** (`fill_block` per block): every output array is
//!    allocated once at its exact final size, and each block writes its
//!    own **disjoint slices** (`nnz_start..`, `slot_start..`,
//!    `group_start..`). Because the slices are disjoint by the plan's
//!    prefix sums, serial and parallel fills produce bit-identical
//!    arrays by construction, and the parallel builder needs no stitch
//!    copy (see [`crate::preprocess::parallel`]).
//!
//! Per-worker [`FillScratch`] (densified row ranges, the reorder
//! permutation, `prev_pos` chain state, the live-row ring) is reused
//! across blocks, so the steady-state fill performs no allocation —
//! the hash, not the allocator, is the bottleneck, which is the whole
//! Fig. 7 preprocessing-speed story.

use crate::formats::Csr;
use crate::partition::{block_map, BlockGrid, BlockMap, PartitionConfig, RowSeg};
use crate::preprocess::reorder::{HashReorder, Reorder};

/// Per-block descriptor.
#[derive(Clone, Copy, Debug)]
pub struct HbpBlock {
    /// Row-block index.
    pub bi: u32,
    /// Column-block index.
    pub bj: u32,
    /// Start of this block's elements in `col`/`data`/`add_sign`
    /// (the paper's `begin_nnz`).
    pub nnz_start: usize,
    pub nnz: usize,
    /// Start of this block's slots in `zero_row`/`output_hash`.
    pub slot_start: usize,
    /// Rows (= slots) in this block; edge blocks may be short.
    pub nrows: usize,
    /// Start of this block's groups in `begin_ptr`.
    pub group_start: usize,
    pub ngroups: usize,
}

/// The HBP matrix.
#[derive(Clone, Debug)]
pub struct Hbp {
    pub rows: usize,
    pub cols: usize,
    pub grid: BlockGrid,
    /// Non-empty blocks, column-major (fixed-allocation order, §III-C).
    pub blocks: Vec<HbpBlock>,
    pub col: Vec<u32>,
    pub data: Vec<f64>,
    pub add_sign: Vec<i32>,
    pub zero_row: Vec<i32>,
    pub output_hash: Vec<u32>,
    pub begin_ptr: Vec<usize>,
}

impl Hbp {
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Approximate in-memory footprint (storage-cost ablation): fixed,
    /// unlike zero-padding formats — the paper's §III-A storage argument.
    pub fn storage_bytes(&self) -> usize {
        self.col.len() * 4
            + self.data.len() * 8
            + self.add_sign.len() * 4
            + self.zero_row.len() * 4
            + self.output_hash.len() * 4
            + self.begin_ptr.len() * 8
            + self.blocks.len() * std::mem::size_of::<HbpBlock>()
    }

    /// Structural invariants — exercised by the property suite.
    pub fn validate(&self) -> anyhow::Result<()> {
        let warp = self.grid.cfg.warp;
        anyhow::ensure!(self.col.len() == self.data.len());
        anyhow::ensure!(self.add_sign.len() == self.data.len());
        let mut nnz_cursor = 0usize;
        let mut slot_cursor = 0usize;
        let mut group_cursor = 0usize;
        for (i, b) in self.blocks.iter().enumerate() {
            anyhow::ensure!(b.nnz_start == nnz_cursor, "block {i} nnz_start");
            anyhow::ensure!(b.slot_start == slot_cursor, "block {i} slot_start");
            anyhow::ensure!(b.group_start == group_cursor, "block {i} group_start");
            anyhow::ensure!(b.nnz > 0, "block {i} empty");
            anyhow::ensure!(b.ngroups == b.nrows.div_ceil(warp), "block {i} ngroups");
            // output_hash is a permutation of local rows
            let oh = &self.output_hash[b.slot_start..b.slot_start + b.nrows];
            let mut seen = vec![false; b.nrows];
            for &r in oh {
                anyhow::ensure!(
                    (r as usize) < b.nrows && !seen[r as usize],
                    "block {i} output_hash not a permutation"
                );
                seen[r as usize] = true;
            }
            // add_sign chains cover exactly the block's element range
            let mut covered = vec![false; b.nnz];
            for g in 0..b.ngroups {
                let gslots = (g * warp)..(((g + 1) * warp).min(b.nrows));
                let gp = self.begin_ptr[b.group_start + g];
                let mut active_rank = 0usize;
                for s in gslots {
                    let z = self.zero_row[b.slot_start + s];
                    if z == -1 {
                        continue;
                    }
                    let mut j = gp + active_rank;
                    active_rank += 1;
                    loop {
                        let local = j - b.nnz_start;
                        anyhow::ensure!(local < b.nnz, "block {i} walk out of range");
                        anyhow::ensure!(!covered[local], "block {i} element {local} visited twice");
                        covered[local] = true;
                        match self.add_sign[j] {
                            -1 => break,
                            step if step > 0 => j += step as usize,
                            bad => anyhow::bail!("block {i} bad add_sign {bad}"),
                        }
                    }
                }
            }
            anyhow::ensure!(covered.iter().all(|&c| c), "block {i} uncovered elements");
            nnz_cursor += b.nnz;
            slot_cursor += b.nrows;
            group_cursor += b.ngroups;
        }
        anyhow::ensure!(nnz_cursor == self.nnz(), "total nnz mismatch");
        Ok(())
    }
}

/// Phase-1 output: the exact layout of every HBP array before a single
/// element is written. Shared by the serial and parallel fillers — there
/// is exactly one construction code path.
#[derive(Clone, Debug)]
pub struct HbpPlan {
    pub grid: BlockGrid,
    /// Sparse per-block row segments (the counting pass's output).
    pub map: BlockMap,
    /// Final block descriptors with exact prefix-summed offsets.
    pub blocks: Vec<HbpBlock>,
    pub total_nnz: usize,
    pub total_slots: usize,
    pub total_groups: usize,
}

/// Phase 1: count + prefix-sum. O(nnz) time, O(non-empty blocks +
/// row segments) memory — empty grid cells cost nothing.
pub fn plan_hbp(m: &Csr, cfg: PartitionConfig) -> HbpPlan {
    cfg.validate().expect("invalid partition config");
    let grid = BlockGrid::new(m.rows, m.cols, cfg);
    let map = block_map(m, &grid);
    let warp = cfg.warp;
    let mut blocks = Vec::with_capacity(map.blocks.len());
    let (mut nnz, mut slots, mut groups) = (0usize, 0usize, 0usize);
    for e in &map.blocks {
        let nrows = grid.rows_in(e.bi as usize);
        let ngroups = nrows.div_ceil(warp);
        blocks.push(HbpBlock {
            bi: e.bi,
            bj: e.bj,
            nnz_start: nnz,
            nnz: e.nnz,
            slot_start: slots,
            nrows,
            group_start: groups,
            ngroups,
        });
        nnz += e.nnz;
        slots += nrows;
        groups += ngroups;
    }
    HbpPlan { grid, map, blocks, total_nnz: nnz, total_slots: slots, total_groups: groups }
}

/// Allocate the output arrays at their exact final sizes (one allocation
/// per array — the "zero-copy" half of plan/fill).
///
/// `vec![0; n]` goes through `alloc_zeroed`, which for large arrays is
/// lazily-zeroed mmap pages — no eager memset, and first touch happens
/// in the worker that fills the page (the NUMA-friendly placement).
/// Don't "optimize" this into `set_len` over uninit memory.
pub(crate) fn alloc_from_plan(m: &Csr, plan: &HbpPlan) -> Hbp {
    Hbp {
        rows: m.rows,
        cols: m.cols,
        grid: plan.grid,
        blocks: plan.blocks.clone(),
        col: vec![0; plan.total_nnz],
        data: vec![0.0; plan.total_nnz],
        add_sign: vec![0; plan.total_nnz],
        zero_row: vec![0; plan.total_slots],
        output_hash: vec![0; plan.total_slots],
        begin_ptr: vec![0; plan.total_groups],
    }
}

/// Wall-time breakdown of one HBP construction — the served-path
/// counterpart of the paper's Fig. 7 preprocessing measurements.
///
/// `reorder_secs` is the time inside [`Reorder::order_into`] summed over
/// blocks; on the parallel fill it sums across workers, so it is
/// CPU-seconds and can exceed the `fill_secs` wall time.
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildProfile {
    /// Phase-1 counting + prefix-sum wall time ([`plan_hbp`]).
    pub plan_secs: f64,
    /// CPU-seconds inside the reorder strategy (subset of the fill).
    pub reorder_secs: f64,
    /// Phase-2 fill wall time (includes the reorder calls).
    pub fill_secs: f64,
    /// End-to-end build wall time.
    pub total_secs: f64,
}

/// Reusable per-worker scratch for `fill_block`: densified row ranges,
/// the reorder permutation, per-row chain positions and the live-row
/// list. Reused across blocks so steady-state fill allocates nothing.
#[derive(Default)]
pub struct FillScratch {
    row_nnz: Vec<usize>,
    row_start: Vec<usize>,
    order: Vec<u32>,
    prev_pos: Vec<usize>,
    live: Vec<u32>,
    // When set, fill_block times each order_into call into
    // reorder_secs. Off by default so the hot build path pays no
    // clock reads.
    profile: bool,
    reorder_secs: f64,
}

impl FillScratch {
    /// Scratch that accumulates reorder wall time (see [`BuildProfile`]).
    pub(crate) fn profiled() -> Self {
        FillScratch { profile: true, ..FillScratch::default() }
    }

    /// Accumulated seconds inside [`Reorder::order_into`].
    pub(crate) fn reorder_secs(&self) -> f64 {
        self.reorder_secs
    }
}

/// Phase 2, one block: write the block's elements into its exact slices
/// of the final arrays. The slices must be the block's own ranges
/// (`col`/`data`/`add_sign` at `nnz_start..nnz_start+nnz`,
/// `zero_row`/`output_hash` at `slot_start..slot_start+nrows`,
/// `begin_ptr` at `group_start..group_start+ngroups`). Distinct blocks
/// own disjoint ranges by the plan's prefix sums — that disjointness is
/// the entire parallel-safety argument (same as `spmv_partials`), and it
/// also makes parallel output bit-identical to serial by construction.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_block(
    m: &Csr,
    grid: &BlockGrid,
    b: &HbpBlock,
    segs: &[RowSeg],
    reorder: &dyn Reorder,
    scratch: &mut FillScratch,
    col: &mut [u32],
    data: &mut [f64],
    add_sign: &mut [i32],
    zero_row: &mut [i32],
    output_hash: &mut [u32],
    begin_ptr: &mut [usize],
) {
    let warp = grid.cfg.warp;
    let nrows = b.nrows;
    let (col_start, _) = grid.col_range(b.bj as usize);
    let FillScratch { row_nnz, row_start, order, prev_pos, live, profile, reorder_secs } = scratch;

    // densify the block's sparse row segments (scratch, O(nrows))
    row_nnz.clear();
    row_nnz.resize(nrows, 0);
    row_start.clear();
    row_start.resize(nrows, 0);
    for s in segs {
        row_start[s.local_row as usize] = s.start;
        row_nnz[s.local_row as usize] = s.end - s.start;
    }

    // output_hash: slot -> original local row
    if *profile {
        let t = crate::util::Timer::start();
        reorder.order_into(order, row_nnz, warp);
        *reorder_secs += t.elapsed_secs();
    } else {
        reorder.order_into(order, row_nnz, warp);
    }
    debug_assert_eq!(order.len(), nrows);
    output_hash.copy_from_slice(order);

    // per group: zero_row bookkeeping + round-major element emission
    prev_pos.clear();
    prev_pos.resize(nrows, usize::MAX);
    let mut cursor = 0usize; // block-local element cursor
    for g in 0..b.ngroups {
        let slot_lo = g * warp;
        let slot_hi = ((g + 1) * warp).min(nrows);
        begin_ptr[g] = b.nnz_start + cursor;

        // zero_row: -1 for inactive; else #zeros before it in the group
        let mut zeros_before = 0i32;
        live.clear();
        for s in slot_lo..slot_hi {
            let r = order[s];
            if row_nnz[r as usize] == 0 {
                zero_row[s] = -1;
                zeros_before += 1;
            } else {
                zero_row[s] = zeros_before;
                live.push(r);
            }
        }

        // round-major emission: round k emits the k-th nonzero of every
        // row still active; rows retire as they exhaust.
        let mut k = 0usize;
        while !live.is_empty() {
            live.retain(|&r| {
                let r = r as usize;
                if k >= row_nnz[r] {
                    return false;
                }
                let src = row_start[r] + k;
                let pos = cursor;
                col[pos] = m.col[src] - col_start as u32;
                data[pos] = m.data[src];
                add_sign[pos] = -1; // patched when the next round emits
                if prev_pos[r] != usize::MAX {
                    add_sign[prev_pos[r]] = (pos - prev_pos[r]) as i32;
                }
                prev_pos[r] = pos;
                cursor += 1;
                true
            });
            k += 1;
        }
    }
    debug_assert_eq!(cursor, b.nnz);
}

/// Serial fill over a plan (also the parallel builder's 1-thread and
/// empty-matrix path — one construction code path).
pub(crate) fn fill_hbp_serial(m: &Csr, plan: &HbpPlan, reorder: &dyn Reorder) -> Hbp {
    fill_hbp_serial_with(m, plan, reorder, &mut FillScratch::default())
}

/// Serial fill into a caller-supplied scratch — the profiled path reads
/// the scratch's accumulated reorder time back out afterwards.
pub(crate) fn fill_hbp_serial_with(
    m: &Csr,
    plan: &HbpPlan,
    reorder: &dyn Reorder,
    scratch: &mut FillScratch,
) -> Hbp {
    let mut hbp = alloc_from_plan(m, plan);
    for (b, e) in plan.blocks.iter().zip(&plan.map.blocks) {
        fill_block(
            m,
            &plan.grid,
            b,
            &plan.map.segs[e.seg_start..e.seg_end],
            reorder,
            scratch,
            &mut hbp.col[b.nnz_start..b.nnz_start + b.nnz],
            &mut hbp.data[b.nnz_start..b.nnz_start + b.nnz],
            &mut hbp.add_sign[b.nnz_start..b.nnz_start + b.nnz],
            &mut hbp.zero_row[b.slot_start..b.slot_start + b.nrows],
            &mut hbp.output_hash[b.slot_start..b.slot_start + b.nrows],
            &mut hbp.begin_ptr[b.group_start..b.group_start + b.ngroups],
        );
    }
    hbp
}

/// Build HBP with the paper's hash reordering.
pub fn build_hbp(m: &Csr, cfg: PartitionConfig) -> Hbp {
    build_hbp_with(m, cfg, &HashReorder::default())
}

/// Build HBP with an arbitrary reorder strategy (sort2D / DP2D / identity
/// for the baselines — downstream engines are strategy-agnostic).
pub fn build_hbp_with(m: &Csr, cfg: PartitionConfig, reorder: &dyn Reorder) -> Hbp {
    let plan = plan_hbp(m, cfg);
    fill_hbp_serial(m, &plan, reorder)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::gen::random;
    use crate::preprocess::reorder::{IdentityReorder, SortReorder};

    fn small_cfg() -> PartitionConfig {
        PartitionConfig::test_small() // 16 rows, 32 cols, warp 4
    }

    #[test]
    fn single_block_structure() {
        // 4 rows, 8 cols, one block
        let mut coo = Coo::new(4, 8);
        coo.push(0, 1, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(1, 0, 3.0);
        coo.push(3, 2, 4.0);
        coo.push(3, 5, 5.0);
        coo.push(3, 7, 6.0);
        let m = coo.to_csr();
        let hbp = build_hbp_with(&m, small_cfg(), &IdentityReorder);
        assert_eq!(hbp.blocks.len(), 1);
        let b = hbp.blocks[0];
        assert_eq!(b.nnz, 6);
        assert_eq!(b.nrows, 4);
        assert_eq!(b.ngroups, 1);
        hbp.validate().unwrap();
        // identity order: slots = rows; row 2 is a zero row, so row 3 has
        // one zero-row before it within the group
        assert_eq!(hbp.zero_row, vec![0, 0, -1, 1]);
        // round-major: round0 = first elems of rows 0,1,3 -> cols 1,0,2
        assert_eq!(&hbp.col[0..3], &[1, 0, 2]);
        // add_sign of row0's first element: 3 active rows -> stride 3
        assert_eq!(hbp.add_sign[0], 3);
        // row1 has 1 elem -> -1 immediately
        assert_eq!(hbp.add_sign[1], -1);
    }

    #[test]
    fn local_column_indices() {
        // matrix wide enough for 2 col blocks (cols_per_block = 32)
        let mut coo = Coo::new(4, 64);
        coo.push(0, 33, 1.0);
        coo.push(2, 63, 2.0);
        let m = coo.to_csr();
        let hbp = build_hbp_with(&m, small_cfg(), &IdentityReorder);
        assert_eq!(hbp.blocks.len(), 1); // only col-block 1 nonempty
        assert_eq!(hbp.blocks[0].bj, 1);
        // local col = global - 32
        let mut cols = hbp.col.clone();
        cols.sort_unstable();
        assert_eq!(cols, vec![1, 31]);
    }

    #[test]
    fn validates_on_random_matrices_all_strategies() {
        for seed in 0..5 {
            let m = random::power_law_rows(100, 150, 2.0, 40, seed);
            for r in [
                &HashReorder::default() as &dyn Reorder,
                &IdentityReorder,
                &SortReorder,
            ] {
                let hbp = build_hbp_with(&m, small_cfg(), r);
                hbp.validate()
                    .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", r.name()));
                assert_eq!(hbp.nnz(), m.nnz());
            }
        }
    }

    #[test]
    fn storage_is_fixed_no_padding() {
        // HBP stores exactly nnz elements regardless of skew — the paper's
        // fixed-storage-cost claim vs zero padding.
        let skewed = random::with_row_lengths(&[1, 1, 1, 30], 32, 3);
        let hbp = build_hbp(&skewed, small_cfg());
        assert_eq!(hbp.col.len(), skewed.nnz());
        assert_eq!(hbp.data.len(), skewed.nnz());
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(8, 8);
        let hbp = build_hbp(&m, small_cfg());
        assert!(hbp.blocks.is_empty());
        hbp.validate().unwrap();
    }

    #[test]
    fn edge_partial_block_and_group() {
        // 18 rows with warp 4, rows_per_block 16 -> second block has 2 rows
        let m = random::uniform(18, 20, 0.3, 7);
        let hbp = build_hbp(&m, small_cfg());
        hbp.validate().unwrap();
        let total_rows: usize = hbp.blocks.iter().map(|b| b.nrows).sum();
        // all blocks are in col-block 0; row coverage = rows with nnz blocks
        assert!(total_rows <= 18 + 16);
    }

    #[test]
    fn begin_nnz_equivalent_monotone() {
        let m = random::uniform(64, 64, 0.1, 21);
        let hbp = build_hbp(&m, small_cfg());
        for w in hbp.blocks.windows(2) {
            assert_eq!(w[0].nnz_start + w[0].nnz, w[1].nnz_start);
        }
    }

    #[test]
    fn plan_offsets_are_exact() {
        // the planner's prefix sums must equal what the fill emits
        let m = random::power_law_rows(120, 180, 2.0, 45, 33);
        let plan = plan_hbp(&m, small_cfg());
        assert_eq!(plan.total_nnz, m.nnz());
        let hbp = build_hbp(&m, small_cfg());
        assert_eq!(hbp.col.len(), plan.total_nnz);
        assert_eq!(hbp.zero_row.len(), plan.total_slots);
        assert_eq!(hbp.begin_ptr.len(), plan.total_groups);
        assert_eq!(hbp.blocks.len(), plan.blocks.len());
        for (a, b) in hbp.blocks.iter().zip(&plan.blocks) {
            assert_eq!(a.nnz_start, b.nnz_start);
            assert_eq!(a.slot_start, b.slot_start);
            assert_eq!(a.group_start, b.group_start);
        }
    }
}

//! Group-ELL export: the TPU/PJRT tensor layout of an HBP block.
//!
//! DESIGN.md §3 (Hardware adaptation): a CUDA warp walking `add_sign`
//! chains becomes, on TPU, a dense `(L, ω)` tile per group — row `k` of
//! the tile holds the `k`-th nonzero of each lane's row (exactly HBP's
//! round-major order), padded with zeros up to the group's max length.
//! The nonlinear hash keeps lanes of a group near-equal length, so the
//! padding (and hence VMEM traffic + FLOPs) stays small: HBP's Fig. 6
//! metric directly bounds the tile waste measured here.
//!
//! Shapes are bucketed to powers of two so the AOT-compiled PJRT
//! executables (one per `(G, ω, L)` bucket) can be reused across blocks —
//! the serving-style fixed-shape discipline of the L3 runtime.

use super::hbp_build::{Hbp, HbpBlock};

/// Padding marker for inactive lanes in `slot_rows`.
pub const PAD_ROW: u32 = u32::MAX;

/// Dense group-ELL tensors for one HBP block.
#[derive(Clone, Debug)]
pub struct GroupEllBlock {
    pub bi: u32,
    pub bj: u32,
    /// Groups in the block (G).
    pub ngroups: usize,
    /// Lanes per group (ω).
    pub warp: usize,
    /// Padded per-lane length (the shape bucket L; >= true max length).
    pub lmax: usize,
    /// `[G, L, ω]` block-local column indices (0 where padded).
    pub cols: Vec<i32>,
    /// `[G, L, ω]` values (0.0 where padded) — f32 for the TPU path; the
    /// precision substitution is recorded in DESIGN.md.
    pub vals: Vec<f32>,
    /// `[G * ω]` slot -> original local row; `PAD_ROW` for lanes past the
    /// block edge. Applied by the *rust* combine step (the kernel output
    /// stays dense `[G, ω]`).
    pub slot_rows: Vec<u32>,
}

impl GroupEllBlock {
    /// Fraction of `(L, ω)` tile slots that are padding.
    pub fn padding_ratio(&self) -> f64 {
        let slots = self.ngroups * self.lmax * self.warp;
        if slots == 0 {
            return 0.0;
        }
        let nnz = self.vals.iter().filter(|&&v| v != 0.0).count();
        // counts explicit zero values as padding too — acceptable for the
        // waste metric (explicit zeros are rare in our generators)
        1.0 - nnz as f64 / slots as f64
    }

    #[inline]
    fn idx(&self, g: usize, k: usize, w: usize) -> usize {
        (g * self.lmax + k) * self.warp + w
    }
}

/// Shape buckets for the padded length L.
pub const L_BUCKETS: [usize; 11] = [4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096];

/// Smallest bucket >= `l` (saturates at the largest bucket; longer rows
/// are handled by the pure-rust engine fallback, reported by the runtime).
pub fn l_bucket(l: usize) -> usize {
    for &b in &L_BUCKETS {
        if l <= b {
            return b;
        }
    }
    *L_BUCKETS.last().unwrap()
}

/// Export one HBP block to group-ELL tensors.
///
/// Walks the block's `add_sign` chains (the authoritative layout) so the
/// export doubles as a consistency check of the HBP structure.
pub fn export_block(hbp: &Hbp, b: &HbpBlock) -> GroupEllBlock {
    let warp = hbp.grid.cfg.warp;

    // true max lane length in this block
    let mut true_lmax = 0usize;
    let mut lane_elems: Vec<Vec<(i32, f32)>> = vec![vec![]; b.nrows];
    for g in 0..b.ngroups {
        let slot_lo = g * warp;
        let slot_hi = ((g + 1) * warp).min(b.nrows);
        let gp = hbp.begin_ptr[b.group_start + g];
        let mut active_rank = 0usize;
        for s in slot_lo..slot_hi {
            if hbp.zero_row[b.slot_start + s] == -1 {
                continue;
            }
            let mut j = gp + active_rank;
            active_rank += 1;
            loop {
                lane_elems[s].push((hbp.col[j] as i32, hbp.data[j] as f32));
                match hbp.add_sign[j] {
                    -1 => break,
                    step => j += step as usize,
                }
            }
            true_lmax = true_lmax.max(lane_elems[s].len());
        }
    }

    let lmax = l_bucket(true_lmax.max(1));
    let g_total = b.ngroups;
    let mut out = GroupEllBlock {
        bi: b.bi,
        bj: b.bj,
        ngroups: g_total,
        warp,
        lmax,
        cols: vec![0; g_total * lmax * warp],
        vals: vec![0.0; g_total * lmax * warp],
        slot_rows: vec![PAD_ROW; g_total * warp],
    };

    for g in 0..g_total {
        let slot_lo = g * warp;
        let slot_hi = ((g + 1) * warp).min(b.nrows);
        for s in slot_lo..slot_hi {
            let w = s - slot_lo;
            out.slot_rows[g * warp + w] = hbp.output_hash[b.slot_start + s];
            for (k, &(c, v)) in lane_elems[s].iter().enumerate() {
                let i = out.idx(g, k, w);
                out.cols[i] = c;
                out.vals[i] = v;
            }
        }
    }
    out
}

/// Export every block of an HBP matrix.
pub fn export_all(hbp: &Hbp) -> Vec<GroupEllBlock> {
    hbp.blocks.iter().map(|b| export_block(hbp, b)).collect()
}

/// Reference SpMV over an exported block (f32, same association order as
/// the Pallas kernel's reduction): returns dense `[G * ω]` slot sums.
/// Used to cross-check rust engines against the kernel path.
pub fn block_spmv_ref(blk: &GroupEllBlock, x_seg: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; blk.ngroups * blk.warp];
    for g in 0..blk.ngroups {
        for w in 0..blk.warp {
            let mut acc = 0.0f32;
            for k in 0..blk.lmax {
                let i = blk.idx(g, k, w);
                acc += blk.vals[i] * x_seg[blk.cols[i] as usize];
            }
            out[g * blk.warp + w] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;
    use crate::partition::PartitionConfig;
    use crate::preprocess::build_hbp;

    #[test]
    fn bucket_selection() {
        assert_eq!(l_bucket(1), 4);
        assert_eq!(l_bucket(4), 4);
        assert_eq!(l_bucket(5), 8);
        assert_eq!(l_bucket(4096), 4096);
        assert_eq!(l_bucket(100_000), 4096); // saturates
    }

    #[test]
    fn export_reconstructs_block_spmv() {
        let m = random::power_law_rows(64, 64, 2.0, 20, 9);
        let cfg = PartitionConfig::test_small();
        let hbp = build_hbp(&m, cfg);
        let x: Vec<f64> = (0..64).map(|i| (i as f64 * 0.37).sin()).collect();

        // full SpMV via exported blocks + slot mapping
        let mut y = vec![0.0f64; 64];
        for (blk, hb) in export_all(&hbp).iter().zip(&hbp.blocks) {
            let (cs, ce) = hbp.grid.col_range(blk.bj as usize);
            let xseg: Vec<f32> = x[cs..ce].iter().map(|&v| v as f32).collect();
            let slot_sums = block_spmv_ref(blk, &xseg);
            let (rs, _) = hbp.grid.row_range(hb.bi as usize);
            for (slot, &orig) in blk.slot_rows.iter().enumerate() {
                if orig != PAD_ROW {
                    y[rs + orig as usize] += slot_sums[slot] as f64;
                }
            }
        }

        let mut expect = vec![0.0f64; 64];
        m.spmv(&x, &mut expect);
        assert!(
            allclose(&y, &expect, 1e-4, 1e-4),
            "group-ELL path diverged from CSR"
        );
    }

    #[test]
    fn padding_ratio_small_after_hash() {
        // heavily skewed rows: identity grouping would pad enormously;
        // hash grouping should keep tile waste modest
        let m = random::power_law_rows(256, 64, 2.0, 48, 21);
        let cfg = PartitionConfig { rows_per_block: 64, cols_per_block: 64, warp: 8 };
        let hash = build_hbp(&m, cfg);
        let id = crate::preprocess::build_hbp_with(&m, cfg, &crate::preprocess::IdentityReorder);
        let waste = |hbp: &crate::preprocess::Hbp| -> f64 {
            let blocks = export_all(hbp);
            let total: usize = blocks.iter().map(|b| b.ngroups * b.lmax * b.warp).sum();
            let nnz: usize = hbp.nnz();
            1.0 - nnz as f64 / total as f64
        };
        let w_hash = waste(&hash);
        let w_id = waste(&id);
        assert!(
            w_hash <= w_id,
            "hash should not pad more than identity: {w_hash:.3} vs {w_id:.3}"
        );
    }

    #[test]
    fn slot_rows_cover_all_rows() {
        let m = random::uniform(50, 40, 0.2, 33);
        let hbp = build_hbp(&m, PartitionConfig::test_small());
        for (blk, hb) in export_all(&hbp).iter().zip(&hbp.blocks) {
            let mut seen = vec![false; hb.nrows];
            for &r in blk.slot_rows.iter().filter(|&&r| r != PAD_ROW) {
                assert!(!seen[r as usize], "row {r} twice");
                seen[r as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "missing rows in slot_rows");
        }
    }
}

//! Incremental HBP repair on matrix updates (the serving-path
//! counterpart of the plan/fill build).
//!
//! A serving system whose matrices drift between requests should not pay
//! even the paper's cheap preprocessing per update. The plan's exact
//! per-block offsets make a cheaper contract possible: a value-level
//! delta localizes to the touched rows' row-blocks, and only the blocks
//! that actually hold those rows' nonzeros need their **disjoint slices**
//! re-filled — O(touched-block nnz), not O(nnz).
//!
//! # Delta kinds ([`DeltaOp`])
//!
//! - `Set` — overwrite the value of one *existing* nonzero (an absent
//!   coordinate is an error, not a fill-in).
//! - `ScaleRow` / `ZeroRow` — multiply / zero every value in a row.
//!   Zeroing stores explicit zeros: the sparsity pattern (and with it
//!   every structural array) is untouched.
//! - `ReplaceRow` — new columns + values for a row **within the existing
//!   row extent** (same nonzero count, so the CSR `ptr` array never
//!   changes). Same columns → value-only, pattern preserved; different
//!   columns → the pattern (and possibly block occupancy) changed.
//!
//! # Fallback rule
//!
//! Pattern-preserving deltas re-fill only the touched blocks' slices
//! (reusing [`FillScratch`], in parallel on `util::pool::shared_pool`
//! workers when the touched set is large). A pattern-changing delta
//! invalidates the plan itself — per-block nnz, row segments, even which
//! blocks exist — so [`Hbp::apply_delta`] falls back to a full
//! [`plan_hbp`] rebuild and reports `full_rebuild = true` (the caller
//! must refresh any cached [`BlockMap`], see
//! [`crate::exec::HbpEngine::update`]).
//!
//! # Parity argument
//!
//! For a pattern-preserving delta, the plan of the mutated matrix is
//! *identical* to the current plan (it depends only on the pattern), and
//! per-row nonzero counts are unchanged, so every reorder strategy
//! reproduces the permutation already stored in `output_hash`. The
//! partial path therefore replays the stored per-block permutation
//! (`ReplayOrder` — no hash work at all) and re-runs `fill_block` on
//! the touched blocks; untouched blocks hold values that did not change.
//! The result is **bit-identical** to a from-scratch build of the
//! mutated matrix — asserted across strategies × thread counts by the
//! property suite.

use super::hbp_build::{fill_block, plan_hbp, BuildProfile, FillScratch, Hbp, HbpBlock};
use super::parallel::{
    build_hbp_parallel, fill_hbp_parallel, fill_hbp_parallel_profiled, nnz_chunks, pool_thread_cap,
};
use super::reorder::Reorder;
use crate::formats::Csr;
use crate::partition::BlockMap;
use crate::util::pool::shared_pool;
use crate::util::sync::SharedMut;
use anyhow::{ensure, Result};

/// One matrix mutation. See the module docs for semantics.
#[derive(Clone, Debug, PartialEq)]
pub enum DeltaOp {
    /// Overwrite the value of the existing nonzero at `(row, col)`.
    Set { row: usize, col: usize, value: f64 },
    /// Multiply every value in `row` by `factor`.
    ScaleRow { row: usize, factor: f64 },
    /// Set every value in `row` to zero (explicit zeros; pattern kept).
    ZeroRow { row: usize },
    /// Replace `row`'s columns and values within its existing extent:
    /// `cols` strictly ascending, in range, `cols.len()` = the row's
    /// current nonzero count. Different columns change the pattern.
    ReplaceRow { row: usize, cols: Vec<u32>, values: Vec<f64> },
}

impl DeltaOp {
    fn row(&self) -> usize {
        match self {
            DeltaOp::Set { row, .. }
            | DeltaOp::ScaleRow { row, .. }
            | DeltaOp::ZeroRow { row }
            | DeltaOp::ReplaceRow { row, .. } => *row,
        }
    }
}

/// An ordered batch of [`DeltaOp`]s, applied atomically: validation runs
/// against the pre-delta matrix before any value moves, so a rejected
/// delta leaves the matrix untouched.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MatrixDelta {
    pub ops: Vec<DeltaOp>,
}

impl MatrixDelta {
    pub fn new() -> Self {
        MatrixDelta::default()
    }

    /// Builder: overwrite one existing nonzero.
    pub fn set(mut self, row: usize, col: usize, value: f64) -> Self {
        self.ops.push(DeltaOp::Set { row, col, value });
        self
    }

    /// Builder: scale a row's values.
    pub fn scale_row(mut self, row: usize, factor: f64) -> Self {
        self.ops.push(DeltaOp::ScaleRow { row, factor });
        self
    }

    /// Builder: zero a row's values (pattern kept).
    pub fn zero_row(mut self, row: usize) -> Self {
        self.ops.push(DeltaOp::ZeroRow { row });
        self
    }

    /// Builder: replace a row within its existing extent.
    pub fn replace_row(mut self, row: usize, cols: Vec<u32>, values: Vec<f64>) -> Self {
        self.ops.push(DeltaOp::ReplaceRow { row, cols, values });
        self
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// What [`apply_to_csr`] did: which rows changed values (sorted,
/// deduped, zero-nnz rows excluded — they hold nothing to change) and
/// whether the sparsity pattern changed.
#[derive(Clone, Debug, Default)]
pub struct CsrChange {
    pub touched_rows: Vec<usize>,
    pub pattern_changed: bool,
}

/// Outcome summary of one delta application (the coordinator's
/// blocks-touched vs blocks-total metric source).
///
/// `blocks_touched <= blocks_total` always: both counts describe the
/// post-update HBP (which on the partial path has exactly the
/// pre-update structure; on a full rebuild every block of the new plan
/// was written, so touched == total).
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateReport {
    /// Rows whose values changed.
    pub rows_touched: usize,
    /// Blocks re-filled (on a full rebuild: every block of the new HBP).
    pub blocks_touched: usize,
    /// Non-empty blocks of the post-update HBP.
    pub blocks_total: usize,
    /// True when the delta changed the pattern and the whole HBP was
    /// rebuilt from a fresh plan.
    pub full_rebuild: bool,
}

/// Apply a delta to a CSR matrix in place.
///
/// Two passes: a read-only validation pass over every op (tracking
/// per-row column replacements so later `Set`s are checked against the
/// pattern they will actually see), then the sequential application.
/// On `Err` the matrix is unmodified.
pub fn apply_to_csr(m: &mut Csr, delta: &MatrixDelta) -> Result<CsrChange> {
    use std::collections::BTreeMap;

    // --- validation pass (no mutation) ---
    // row → cols as most recently replaced within this delta
    let mut replaced: BTreeMap<usize, &[u32]> = BTreeMap::new();
    for (i, op) in delta.ops.iter().enumerate() {
        let row = op.row();
        ensure!(row < m.rows, "op {i}: row {row} out of range ({} rows)", m.rows);
        match op {
            DeltaOp::Set { col, .. } => {
                ensure!(*col < m.cols, "op {i}: col {col} out of range ({} cols)", m.cols);
                let cols: &[u32] =
                    replaced.get(&row).copied().unwrap_or_else(|| m.row(row).0);
                ensure!(
                    cols.binary_search(&(*col as u32)).is_ok(),
                    "op {i}: ({row}, {col}) is not in the sparsity pattern \
                     (Set only overwrites existing nonzeros)"
                );
            }
            DeltaOp::ScaleRow { .. } | DeltaOp::ZeroRow { .. } => {}
            DeltaOp::ReplaceRow { cols, values, .. } => {
                ensure!(
                    cols.len() == values.len(),
                    "op {i}: {} cols but {} values",
                    cols.len(),
                    values.len()
                );
                ensure!(
                    cols.len() == m.row_nnz(row),
                    "op {i}: replacement has {} nonzeros but row {row} holds {} \
                     (ReplaceRow must stay within the row's extent)",
                    cols.len(),
                    m.row_nnz(row)
                );
                for w in cols.windows(2) {
                    ensure!(w[0] < w[1], "op {i}: replacement columns not strictly ascending");
                }
                if let Some(&c) = cols.last() {
                    ensure!(
                        (c as usize) < m.cols,
                        "op {i}: replacement col {c} out of range ({} cols)",
                        m.cols
                    );
                }
                replaced.insert(row, cols);
            }
        }
    }

    // --- application pass ---
    let mut touched: Vec<usize> = Vec::new();
    let mut pattern_changed = false;
    for op in &delta.ops {
        let row = op.row();
        if m.row_nnz(row) > 0 {
            touched.push(row);
        }
        let range = m.ptr[row]..m.ptr[row + 1];
        match op {
            DeltaOp::Set { col, value, .. } => {
                // validated above; the search is against the current
                // (possibly already-replaced) columns
                let k = m.col[range.clone()]
                    .binary_search(&(*col as u32))
                    .expect("validated Set target vanished");
                m.data[range.start + k] = *value;
            }
            DeltaOp::ScaleRow { factor, .. } => {
                for v in &mut m.data[range] {
                    *v *= factor;
                }
            }
            DeltaOp::ZeroRow { .. } => {
                for v in &mut m.data[range] {
                    *v = 0.0;
                }
            }
            DeltaOp::ReplaceRow { cols, values, .. } => {
                if m.col[range.clone()] != cols[..] {
                    pattern_changed = true;
                    m.col[range.clone()].copy_from_slice(cols);
                }
                m.data[range].copy_from_slice(values);
            }
        }
    }
    touched.sort_unstable();
    touched.dedup();
    Ok(CsrChange { touched_rows: touched, pattern_changed })
}

/// Replays a block's previously computed permutation (its stored
/// `output_hash` slice) instead of re-running a reorder strategy — valid
/// on the partial path because an unchanged pattern means unchanged
/// per-row counts, and every strategy is a deterministic function of
/// those counts.
struct ReplayOrder<'a>(&'a [u32]);

impl Reorder for ReplayOrder<'_> {
    fn order_into(&self, out: &mut Vec<u32>, row_nnz: &[usize], _warp: usize) {
        debug_assert_eq!(self.0.len(), row_nnz.len());
        out.clear();
        out.extend_from_slice(self.0);
    }
    fn name(&self) -> &'static str {
        "replay"
    }
}

/// Touched-block threshold below which the partial re-fill stays serial:
/// the common serving delta touches one row → a handful of blocks, where
/// a pool generation costs more than the fill itself.
const PARALLEL_MIN_BLOCKS: usize = 4;

impl Hbp {
    /// Apply `delta` to the matrix/HBP pair in place: mutate `m` (the
    /// source CSR this HBP was built from), then repair `self`.
    ///
    /// `map` must be the [`BlockMap`] of the plan that built `self`
    /// (`plan_hbp(m, cfg).map` before mutation). Pattern-preserving
    /// deltas re-fill only the blocks holding the touched rows' nonzeros
    /// — each block's disjoint slices, serial or on the shared pool —
    /// and are bit-identical to a from-scratch rebuild of the mutated
    /// matrix. Pattern-changing deltas rebuild everything with `reorder`
    /// (`full_rebuild = true` in the report), after which `map` is stale
    /// and must be refreshed by the caller via
    /// [`crate::partition::block_map`].
    ///
    /// On `Err` neither `m` nor `self` is modified.
    pub fn apply_delta(
        &mut self,
        m: &mut Csr,
        map: &BlockMap,
        delta: &MatrixDelta,
        reorder: &(dyn Reorder + Sync),
        threads: usize,
    ) -> Result<UpdateReport> {
        debug_assert_eq!(self.blocks.len(), map.blocks.len(), "map does not match this HBP");
        let change = apply_to_csr(m, delta)?;

        if change.pattern_changed {
            *self = build_hbp_parallel(m, self.grid.cfg, reorder, threads);
            // both counts describe the new plan: every block was written
            return Ok(UpdateReport {
                rows_touched: change.touched_rows.len(),
                blocks_touched: self.blocks.len(),
                blocks_total: self.blocks.len(),
                full_rebuild: true,
            });
        }

        let touched = map.blocks_for_rows(&self.grid, &change.touched_rows);
        self.refill_blocks(m, map, &touched, threads);
        Ok(UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: touched.len(),
            blocks_total: self.blocks.len(),
            full_rebuild: false,
        })
    }

    /// Re-run `fill_block` on the given block indices' disjoint slices.
    /// The disjointness argument of the parallel builder applies
    /// unchanged: distinct blocks own disjoint ranges by the plan's
    /// prefix sums, and each touched block is visited exactly once.
    fn refill_blocks(&mut self, m: &Csr, map: &BlockMap, touched: &[usize], threads: usize) {
        let grid = self.grid;
        let threads = threads.min(pool_thread_cap());
        if threads <= 1 || touched.len() < PARALLEL_MIN_BLOCKS {
            let mut scratch = FillScratch::default();
            let mut replay = Vec::new();
            for &i in touched {
                let b = self.blocks[i];
                let e = &map.blocks[i];
                replay.clear();
                replay.extend_from_slice(&self.output_hash[b.slot_start..b.slot_start + b.nrows]);
                fill_block(
                    m,
                    &grid,
                    &b,
                    &map.segs[e.seg_start..e.seg_end],
                    &ReplayOrder(&replay),
                    &mut scratch,
                    &mut self.col[b.nnz_start..b.nnz_start + b.nnz],
                    &mut self.data[b.nnz_start..b.nnz_start + b.nnz],
                    &mut self.add_sign[b.nnz_start..b.nnz_start + b.nnz],
                    &mut self.zero_row[b.slot_start..b.slot_start + b.nrows],
                    &mut self.output_hash[b.slot_start..b.slot_start + b.nrows],
                    &mut self.begin_ptr[b.group_start..b.group_start + b.ngroups],
                );
            }
            return;
        }

        // large touched set: nnz-balanced chunks of the gathered touched
        // blocks on the shared pool, same SharedMut contract as the
        // full parallel build
        let gathered: Vec<HbpBlock> = touched.iter().map(|&i| self.blocks[i]).collect();
        let pool = shared_pool(threads);
        let chunks = nnz_chunks(&gathered, pool.workers.min(gathered.len()).max(1));
        let col = SharedMut::new(&mut self.col[..]);
        let data = SharedMut::new(&mut self.data[..]);
        let add_sign = SharedMut::new(&mut self.add_sign[..]);
        let zero_row = SharedMut::new(&mut self.zero_row[..]);
        let output_hash = SharedMut::new(&mut self.output_hash[..]);
        let begin_ptr = SharedMut::new(&mut self.begin_ptr[..]);
        let (chunks, gathered, touched) = (&chunks, &gathered, &touched);
        pool.run_generation(|w, _| {
            let Some(&(lo, hi)) = chunks.get(w) else { return };
            let mut scratch = FillScratch::default();
            let mut replay = Vec::new();
            for (b, &i) in gathered[lo..hi].iter().zip(&touched[lo..hi]) {
                let e = &map.blocks[i];
                // SAFETY: per-block ranges are disjoint by the plan's
                // prefix sums, chunks partition the touched list, and
                // each chunk is visited by exactly one worker.
                let (c, d, a, z, o, p) = unsafe {
                    (
                        col.slice_mut(b.nnz_start, b.nnz),
                        data.slice_mut(b.nnz_start, b.nnz),
                        add_sign.slice_mut(b.nnz_start, b.nnz),
                        zero_row.slice_mut(b.slot_start, b.nrows),
                        output_hash.slice_mut(b.slot_start, b.nrows),
                        begin_ptr.slice_mut(b.group_start, b.ngroups),
                    )
                };
                replay.clear();
                replay.extend_from_slice(o);
                let segs = &map.segs[e.seg_start..e.seg_end];
                let replay = ReplayOrder(&replay);
                fill_block(m, &grid, b, segs, &replay, &mut scratch, c, d, a, z, o, p);
            }
        });
    }
}

/// Plan + fill + retained [`BlockMap`] in one call — the resident triple
/// the update path needs (avoids planning twice). Returns the HBP and
/// the map it was planned from.
pub fn build_hbp_updatable(
    m: &Csr,
    cfg: crate::partition::PartitionConfig,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> (Hbp, BlockMap) {
    let plan = plan_hbp(m, cfg);
    let hbp = fill_hbp_parallel(m, &plan, reorder, threads);
    (hbp, plan.map)
}

/// [`build_hbp_updatable`] plus the construction's [`BuildProfile`] —
/// what the serving coordinator records and reports at register time.
pub fn build_hbp_updatable_profiled(
    m: &Csr,
    cfg: crate::partition::PartitionConfig,
    reorder: &(dyn Reorder + Sync),
    threads: usize,
) -> (Hbp, BlockMap, BuildProfile) {
    let total = crate::util::Timer::start();
    let (plan, plan_secs) = crate::util::timer::time(|| plan_hbp(m, cfg));
    let fill_t = crate::util::Timer::start();
    let (hbp, reorder_secs) = fill_hbp_parallel_profiled(m, &plan, reorder, threads);
    let fill_secs = fill_t.elapsed_secs();
    let profile =
        BuildProfile { plan_secs, reorder_secs, fill_secs, total_secs: total.elapsed_secs() };
    (hbp, plan.map, profile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::partition::{block_map, PartitionConfig};
    use crate::preprocess::{build_hbp_with, HashReorder};

    fn cfg() -> PartitionConfig {
        PartitionConfig::test_small()
    }

    fn assert_hbp_eq(a: &Hbp, b: &Hbp, ctx: &str) {
        assert_eq!(a.col, b.col, "{ctx}: col");
        assert_eq!(a.data, b.data, "{ctx}: data");
        assert_eq!(a.add_sign, b.add_sign, "{ctx}: add_sign");
        assert_eq!(a.zero_row, b.zero_row, "{ctx}: zero_row");
        assert_eq!(a.output_hash, b.output_hash, "{ctx}: output_hash");
        assert_eq!(a.begin_ptr, b.begin_ptr, "{ctx}: begin_ptr");
        assert_eq!(a.blocks.len(), b.blocks.len(), "{ctx}: blocks");
    }

    #[test]
    fn set_scale_zero_apply_in_place() {
        let mut m = random::power_law_rows(50, 60, 2.0, 20, 3);
        let before = m.clone();
        let (r, c) = {
            let row = (0..50).find(|&r| m.row_nnz(r) >= 2).unwrap();
            (row, m.row(row).0[1] as usize)
        };
        let delta = MatrixDelta::new().set(r, c, 42.5).scale_row(r, 2.0).zero_row(49);
        let change = apply_to_csr(&mut m, &delta).unwrap();
        assert!(!change.pattern_changed);
        assert_eq!(m.get(r, c), 85.0); // set then scaled
        for &v in m.row(49).1 {
            assert_eq!(v, 0.0);
        }
        // pattern untouched
        assert_eq!(m.ptr, before.ptr);
        assert_eq!(m.col, before.col);
    }

    #[test]
    fn invalid_delta_leaves_matrix_untouched() {
        let mut m = random::power_law_rows(30, 30, 2.0, 10, 7);
        let before = m.clone();
        // second op is invalid: Set outside the pattern
        let missing = (0..30u32).find(|c| !m.row(0).0.contains(c)).unwrap() as usize;
        let delta = MatrixDelta::new().scale_row(0, 3.0).set(0, missing, 1.0);
        assert!(apply_to_csr(&mut m, &delta).is_err());
        assert_eq!(m, before, "failed delta must not mutate");
        // row out of range
        assert!(apply_to_csr(&mut m, &MatrixDelta::new().zero_row(30)).is_err());
        // replace with wrong extent
        let delta = MatrixDelta::new().replace_row(0, vec![0], vec![1.0]);
        if m.row_nnz(0) != 1 {
            assert!(apply_to_csr(&mut m, &delta).is_err());
        }
        assert_eq!(m, before);
    }

    #[test]
    fn replace_row_same_cols_preserves_pattern() {
        let mut m = random::power_law_rows(40, 50, 2.0, 15, 9);
        let row = (0..40).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let cols = m.row(row).0.to_vec();
        let vals: Vec<f64> = (0..cols.len()).map(|i| i as f64 + 0.5).collect();
        let change = apply_to_csr(
            &mut m,
            &MatrixDelta::new().replace_row(row, cols.clone(), vals.clone()),
        )
        .unwrap();
        assert!(!change.pattern_changed);
        assert_eq!(change.touched_rows, vec![row]);
        assert_eq!(m.row(row).1, &vals[..]);
        m.validate().unwrap();
    }

    #[test]
    fn replace_row_new_cols_flags_pattern_change() {
        let mut m = random::with_row_lengths(&[3, 2, 4], 40, 5);
        let old: Vec<u32> = m.row(1).0.to_vec();
        let new: Vec<u32> = (0..40u32).filter(|c| !old.contains(c)).take(2).collect();
        let change = apply_to_csr(
            &mut m,
            &MatrixDelta::new().replace_row(1, new.clone(), vec![1.0, 2.0]),
        )
        .unwrap();
        assert!(change.pattern_changed);
        assert_eq!(m.row(1).0, &new[..]);
        m.validate().unwrap();
    }

    #[test]
    fn set_after_replace_sees_the_new_pattern() {
        let mut m = random::with_row_lengths(&[2], 20, 1);
        let old = m.row(0).0.to_vec();
        let new: Vec<u32> = (0..20u32).filter(|c| !old.contains(c)).take(2).collect();
        // Set on a NEW column after the replace must validate…
        let delta = MatrixDelta::new()
            .replace_row(0, new.clone(), vec![1.0, 2.0])
            .set(0, new[1] as usize, 9.0);
        apply_to_csr(&mut m.clone(), &delta).unwrap();
        // …and Set on a column the replace removed must fail
        let delta = MatrixDelta::new()
            .replace_row(0, new, vec![1.0, 2.0])
            .set(0, old[0] as usize, 9.0);
        assert!(apply_to_csr(&mut m, &delta).is_err());
    }

    #[test]
    fn partial_repair_is_bit_identical_and_localized() {
        let m0 = random::power_law_rows(200, 260, 2.0, 60, 29);
        let r = HashReorder::default();
        let (mut hbp, map) = build_hbp_updatable(&m0, cfg(), &r, 1);
        let mut m = m0.clone();
        let row = (0..200).find(|&r| m.row_nnz(r) >= 2).unwrap();
        let report = hbp
            .apply_delta(&mut m, &map, &MatrixDelta::new().scale_row(row, 3.0), &r, 1)
            .unwrap();
        assert!(!report.full_rebuild);
        assert!(report.blocks_touched >= 1);
        assert!(
            report.blocks_touched < report.blocks_total,
            "single-row delta must not touch all {} blocks",
            report.blocks_total
        );
        hbp.validate().unwrap();
        assert_hbp_eq(&hbp, &build_hbp_with(&m, cfg(), &r), "scale_row repair");
    }

    #[test]
    fn pattern_breaking_delta_falls_back_to_rebuild() {
        let m0 = random::power_law_rows(120, 200, 2.0, 50, 31);
        let r = HashReorder::default();
        let (mut hbp, map) = build_hbp_updatable(&m0, cfg(), &r, 2);
        let mut m = m0.clone();
        let row = (0..120).find(|&r| m.row_nnz(r) >= 2).unwrap();
        // move the row's nonzeros to fresh columns (likely crossing
        // column blocks): pattern-breaking
        let n = m.row_nnz(row);
        let old = m.row(row).0.to_vec();
        let new: Vec<u32> = (0..200u32).filter(|c| !old.contains(c)).take(n).collect();
        let vals: Vec<f64> = (0..n).map(|i| -(i as f64) - 1.0).collect();
        let report = hbp
            .apply_delta(&mut m, &map, &MatrixDelta::new().replace_row(row, new, vals), &r, 2)
            .unwrap();
        assert!(report.full_rebuild);
        // on the fallback both counts are the NEW plan's: ratio stays <= 1
        assert_eq!(report.blocks_touched, report.blocks_total);
        assert_eq!(report.blocks_total, hbp.blocks.len());
        hbp.validate().unwrap();
        assert_hbp_eq(&hbp, &build_hbp_with(&m, cfg(), &r), "fallback rebuild");
    }

    #[test]
    fn large_touched_set_takes_the_pooled_path() {
        // touch every row → touched blocks = all blocks ≥ the parallel
        // threshold; output must still be bit-identical
        let m0 = random::power_law_rows(300, 300, 2.0, 60, 37);
        let r = HashReorder::default();
        let (mut hbp, map) = build_hbp_updatable(&m0, cfg(), &r, 4);
        assert!(hbp.blocks.len() >= PARALLEL_MIN_BLOCKS, "test needs many blocks");
        let mut m = m0.clone();
        let mut delta = MatrixDelta::new();
        for row in 0..300 {
            delta = delta.scale_row(row, 0.5);
        }
        let report = hbp.apply_delta(&mut m, &map, &delta, &r, 4).unwrap();
        assert_eq!(report.blocks_touched, report.blocks_total);
        assert_hbp_eq(&hbp, &build_hbp_with(&m, cfg(), &r), "pooled repair");
    }

    #[test]
    fn empty_delta_and_empty_matrix() {
        let m0 = Csr::empty(16, 16);
        let r = HashReorder::default();
        let (mut hbp, map) = build_hbp_updatable(&m0, cfg(), &r, 2);
        let mut m = m0.clone();
        let report = hbp.apply_delta(&mut m, &map, &MatrixDelta::new(), &r, 2).unwrap();
        assert_eq!(report.blocks_touched, 0);
        assert!(!report.full_rebuild);
        // zero-nnz row ops are value no-ops
        let report = hbp
            .apply_delta(&mut m, &map, &MatrixDelta::new().zero_row(3).scale_row(5, 2.0), &r, 2)
            .unwrap();
        assert_eq!(report.rows_touched, 0);
        assert_eq!(report.blocks_touched, 0);
    }

    #[test]
    fn updated_hbp_serves_correct_spmv() {
        let m0 = random::power_law_rows(150, 120, 2.0, 40, 43);
        let r = HashReorder::default();
        let (mut hbp, map) = build_hbp_updatable(&m0, cfg(), &r, 2);
        let mut m = m0.clone();
        let row = (0..150).find(|&r| m.row_nnz(r) >= 1).unwrap();
        hbp.apply_delta(&mut m, &map, &MatrixDelta::new().scale_row(row, -2.5), &r, 2).unwrap();
        let x = random::vector(120, 11);
        let eng = crate::exec::HbpEngine::new(hbp, 2, 0.25);
        use crate::exec::SpmvEngine;
        let mut y = vec![0.0; 150];
        eng.spmv(&x, &mut y);
        let mut expect = vec![0.0; 150];
        m.spmv(&x, &mut expect);
        assert!(crate::formats::dense::allclose(&y, &expect, 1e-10, 1e-12));
    }

    #[test]
    fn map_refresh_after_fallback_matches_fresh_plan() {
        let m0 = random::power_law_rows(100, 150, 2.0, 40, 47);
        let r = HashReorder::default();
        let (mut hbp, map) = build_hbp_updatable(&m0, cfg(), &r, 1);
        let mut m = m0.clone();
        let row = (0..100).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let n = m.row_nnz(row);
        let new: Vec<u32> = (100..150u32).take(n).collect();
        let vals = vec![1.0; n];
        let report = hbp
            .apply_delta(&mut m, &map, &MatrixDelta::new().replace_row(row, new, vals), &r, 1)
            .unwrap();
        if report.full_rebuild {
            let fresh = block_map(&m, &hbp.grid);
            assert_eq!(fresh.blocks.len(), hbp.blocks.len());
            // a follow-up pattern-preserving delta through the refreshed
            // map must again match a from-scratch build
            let report2 = hbp
                .apply_delta(&mut m, &fresh, &MatrixDelta::new().scale_row(row, 2.0), &r, 1)
                .unwrap();
            assert!(!report2.full_rebuild);
            assert_hbp_eq(&hbp, &build_hbp_with(&m, cfg(), &r), "post-fallback repair");
        }
    }
}

//! Preprocessing: reordering strategies + HBP format construction.
//!
//! This is the paper's benchmarked preprocessing step (Fig. 7):
//! - [`reorder`] — the row-reordering strategies: the paper's nonlinear
//!   **hash** (HBP), the **sort2D** baseline, the **DP2D** dynamic-
//!   programming baseline (Regu2D's method), and identity (plain 2D).
//! - [`hbp_build`] — Algorithm 2 + format conversion: build the full HBP
//!   structure (`col`, `data`, `add_sign`, `zero_row`, `begin_nnz`/
//!   `begin_ptr`, `output_hash`) from CSR.
//! - [`parallel`] — the multithreaded build; the hash's atomicity is what
//!   makes per-row/per-block parallelism possible (the paper's argument
//!   for why zero-padding formats can't parallelize their conversion).
//! - [`group_ell`] — export to the dense group-ELL tensors consumed by
//!   the L1 Pallas kernel through PJRT.

pub mod reorder;
pub mod hbp_build;
pub mod parallel;
pub mod group_ell;

pub use hbp_build::{build_hbp, build_hbp_with, Hbp, HbpBlock};
pub use parallel::build_hbp_parallel;
pub use reorder::{DpReorder, HashReorder, IdentityReorder, Reorder, SortReorder};

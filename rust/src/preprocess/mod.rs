//! Preprocessing: reordering strategies + HBP format construction.
//!
//! This is the paper's benchmarked preprocessing step (Fig. 7):
//! - [`reorder`] — the row-reordering strategies: the paper's nonlinear
//!   **hash** (HBP), the **sort2D** baseline, the **DP2D** dynamic-
//!   programming baseline (Regu2D's method), and identity (plain 2D).
//! - [`hbp_build`] — Algorithm 2 + format conversion as a two-phase
//!   **plan → fill** pipeline: a counting pass prefix-sums every block's
//!   exact array offsets, then each block fills its disjoint slices of
//!   single-allocation output arrays (`col`, `data`, `add_sign`,
//!   `zero_row`, `begin_nnz`/`begin_ptr`, `output_hash`).
//! - [`parallel`] — the multithreaded fill on the persistent worker
//!   pools; the hash's atomicity is what makes per-row/per-block
//!   parallelism possible (the paper's argument for why zero-padding
//!   formats can't parallelize their conversion), and the plan's
//!   disjoint slices make parallel output bit-identical to serial.
//! - [`group_ell`] — export to the dense group-ELL tensors consumed by
//!   the L1 Pallas kernel through PJRT.
//! - [`update`] — incremental repair on matrix updates: value-level
//!   deltas re-fill only the touched blocks' disjoint slices, falling
//!   back to a full rebuild when the sparsity pattern changes.

pub mod reorder;
pub mod hbp_build;
pub mod parallel;
pub mod group_ell;
pub mod update;

pub use hbp_build::{build_hbp, build_hbp_with, plan_hbp, BuildProfile, Hbp, HbpBlock, HbpPlan};
pub use parallel::{
    build_hbp_parallel, build_hbp_pooled, build_hbp_profiled, fill_hbp_parallel,
    fill_hbp_parallel_profiled,
};
pub use reorder::{DpReorder, HashReorder, IdentityReorder, Reorder, SortReorder};
pub use update::{
    apply_to_csr, build_hbp_updatable, build_hbp_updatable_profiled, CsrChange, DeltaOp,
    MatrixDelta, UpdateReport,
};

//! Persistent tuning cache keyed by matrix content hash.
//!
//! A tuned decision is a property of the matrix *content* (not the
//! registration name) plus the tuning context it was measured in — so
//! the cache key starts from a 64-bit FNV-1a hash over the CSR's
//! dimensions, `ptr`, `col`, and `data` bit patterns ([`content_hash`],
//! O(nnz), deterministic across platforms), which the tuner then mixes
//! with its thread count and base partition config
//! ([`crate::tune::Tuner::cache_key`]). A re-registered or
//! server-restarted matrix hashes to the same key and skips straight to
//! its tuned decision with no second trial run; a different context
//! misses and re-tunes.
//!
//! The on-disk format follows the `io::binfmt` framing convention
//! (little-endian u64 fields behind a magic number):
//!
//! ```text
//! magic   u64 = 0x4842_5054_554e_4531  ("HBPTUNE1")
//! count   u64
//! entry*  key u64, kind u64, rows_per_block u64, cols_per_block u64,
//!         warp u64, trial_secs f64-bits
//! ```
//!
//! Reads validate the magic, the engine-kind code, and every decision's
//! [`PartitionConfig`] invariants; any violation is a hard error the
//! caller downgrades to an empty cache (a corrupt file must never
//! poison decisions — it costs one re-tune and is overwritten by the
//! next save).

use super::Decision;
use crate::coordinator::EngineKind;
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: u64 = 0x4842_5054_554e_4531; // "HBPTUNE1"

/// FNV-1a over the CSR's structure and values, folded 64 bits at a
/// time. Any change to shape, pattern, or values changes the key.
pub fn content_hash(m: &Csr) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut mix = |v: u64| h = (h ^ v).wrapping_mul(FNV_PRIME);
    mix(m.rows as u64);
    mix(m.cols as u64);
    for &p in &m.ptr {
        mix(p as u64);
    }
    for &c in &m.col {
        mix(c as u64);
    }
    for &d in &m.data {
        mix(d.to_bits());
    }
    h
}

fn kind_code(kind: EngineKind) -> u64 {
    match kind {
        EngineKind::Hbp => 0,
        EngineKind::Csr => 1,
        EngineKind::Plain2d => 2,
        EngineKind::Flat => 3,
        EngineKind::LineEnhance => 4,
        EngineKind::Auto => unreachable!("Auto decisions are never cached"),
    }
}

fn kind_from_code(code: u64) -> Result<EngineKind> {
    match code {
        0 => Ok(EngineKind::Hbp),
        1 => Ok(EngineKind::Csr),
        2 => Ok(EngineKind::Plain2d),
        3 => Ok(EngineKind::Flat),
        4 => Ok(EngineKind::LineEnhance),
        other => bail!("tuning cache: unknown engine code {other}"),
    }
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// In-memory map of content hash → tuned decision, with binary
/// load/save.
///
/// # Example
///
/// ```
/// use hbp_spmv::coordinator::EngineKind;
/// use hbp_spmv::partition::PartitionConfig;
/// use hbp_spmv::tune::{Decision, TuneCache};
///
/// let mut cache = TuneCache::new();
/// let decision =
///     Decision { kind: EngineKind::Csr, cfg: PartitionConfig::test_small(), trial_secs: 1e-6 };
/// cache.put(42, decision);
/// assert_eq!(cache.get(42).map(|d| d.kind), Some(EngineKind::Csr));
/// assert_eq!(cache.get(7), None, "unknown key is a miss");
/// // `save`/`load` round-trip this map through the HBPTUNE1 binary format
/// ```
#[derive(Clone, Debug, Default)]
pub struct TuneCache {
    entries: BTreeMap<u64, Decision>,
}

impl TuneCache {
    /// An empty cache.
    pub fn new() -> TuneCache {
        TuneCache::default()
    }

    /// Load a cache file. A missing file is an empty cache (the normal
    /// first-run state); a malformed one is an error — callers decide
    /// whether to downgrade it (the [`crate::tune::Tuner`] does).
    pub fn load(path: impl AsRef<Path>) -> Result<TuneCache> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(TuneCache::new());
        }
        let mut r = BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        if read_u64(&mut r)? != MAGIC {
            bail!("bad magic in tuning cache {path:?}");
        }
        let count = read_u64(&mut r)?;
        let mut entries = BTreeMap::new();
        for i in 0..count {
            let key = read_u64(&mut r).with_context(|| format!("cache entry {i}"))?;
            let kind = kind_from_code(read_u64(&mut r)?)?;
            let cfg = PartitionConfig {
                rows_per_block: read_u64(&mut r)? as usize,
                cols_per_block: read_u64(&mut r)? as usize,
                warp: read_u64(&mut r)? as usize,
            };
            cfg.validate().with_context(|| format!("cache entry {i} config"))?;
            let trial_secs = f64::from_bits(read_u64(&mut r)?);
            entries.insert(key, Decision { kind, cfg, trial_secs });
        }
        Ok(TuneCache { entries })
    }

    /// Write the cache atomically (temp file + rename), so a crash
    /// mid-save never leaves a truncated file behind.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        {
            let mut w = BufWriter::new(
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?,
            );
            write_u64(&mut w, MAGIC)?;
            write_u64(&mut w, self.entries.len() as u64)?;
            for (&key, d) in &self.entries {
                write_u64(&mut w, key)?;
                write_u64(&mut w, kind_code(d.kind))?;
                write_u64(&mut w, d.cfg.rows_per_block as u64)?;
                write_u64(&mut w, d.cfg.cols_per_block as u64)?;
                write_u64(&mut w, d.cfg.warp as u64)?;
                write_u64(&mut w, d.trial_secs.to_bits())?;
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        Ok(())
    }

    /// The decision stored under `key`, if any.
    pub fn get(&self, key: u64) -> Option<Decision> {
        self.entries.get(&key).copied()
    }

    /// Store (or overwrite) a decision under `key`.
    pub fn put(&mut self, key: u64, decision: Decision) {
        assert_ne!(decision.kind, EngineKind::Auto, "Auto decisions are never cached");
        self.entries.insert(key, decision);
    }

    /// Number of cached decisions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no decisions are cached.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("hbp_tune_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("tune.cache")
    }

    fn decision() -> Decision {
        Decision {
            kind: EngineKind::Hbp,
            cfg: PartitionConfig::default(),
            trial_secs: 1.25e-3,
        }
    }

    #[test]
    fn missing_file_is_an_empty_cache() {
        let cache = TuneCache::load("/nonexistent/dir/tune.cache").unwrap();
        assert!(cache.is_empty());
    }

    #[test]
    fn roundtrip_preserves_decisions() {
        let path = tmp("roundtrip");
        let mut cache = TuneCache::new();
        cache.put(42, decision());
        cache.put(
            7,
            Decision {
                kind: EngineKind::Csr,
                cfg: PartitionConfig::test_small(),
                trial_secs: 9.5e-6,
            },
        );
        cache.put(
            11,
            Decision {
                kind: EngineKind::Flat,
                cfg: PartitionConfig::test_small(),
                trial_secs: 3.0e-6,
            },
        );
        cache.put(
            12,
            Decision {
                kind: EngineKind::LineEnhance,
                cfg: PartitionConfig::test_small(),
                trial_secs: 4.0e-6,
            },
        );
        cache.save(&path).unwrap();
        let back = TuneCache::load(&path).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back.get(42), Some(decision()));
        assert_eq!(back.get(7).unwrap().kind, EngineKind::Csr);
        assert_eq!(back.get(11).unwrap().kind, EngineKind::Flat);
        assert_eq!(back.get(12).unwrap().kind, EngineKind::LineEnhance);
        assert_eq!(back.get(99), None, "unknown key is a miss");
    }

    #[test]
    fn corrupt_file_is_an_error_not_a_decision() {
        let path = tmp("corrupt");
        std::fs::write(&path, b"garbage that is definitely not a cache").unwrap();
        assert!(TuneCache::load(&path).is_err());
        // a valid header with an invalid engine code is also corrupt
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&5u64.to_le_bytes()); // key
        bytes.extend_from_slice(&77u64.to_le_bytes()); // bad kind code
        bytes.extend_from_slice(&[0u8; 32]);
        std::fs::write(&path, bytes).unwrap();
        assert!(TuneCache::load(&path).is_err());
        // truncated entry list
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC.to_le_bytes());
        bytes.extend_from_slice(&3u64.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        assert!(TuneCache::load(&path).is_err());
    }

    #[test]
    fn content_hash_tracks_values_pattern_and_shape() {
        let m = random::power_law_rows(50, 60, 2.0, 15, 3);
        let base = content_hash(&m);
        assert_eq!(base, content_hash(&m.clone()), "hash is deterministic");

        let mut value_changed = m.clone();
        let k = value_changed.data.len() / 2;
        value_changed.data[k] += 1.0;
        assert_ne!(base, content_hash(&value_changed), "value change must re-key");

        let mut pattern_changed = m.clone();
        let row = (0..50).find(|&r| m.row_nnz(r) >= 1).unwrap();
        let j = pattern_changed.ptr[row];
        pattern_changed.col[j] = if pattern_changed.col[j] == 0 { 1 } else { 0 };
        assert_ne!(base, content_hash(&pattern_changed), "pattern change must re-key");

        let other = random::power_law_rows(50, 61, 2.0, 15, 3);
        assert_ne!(base, content_hash(&other), "shape change must re-key");
    }

    #[test]
    fn save_overwrites_a_corrupt_file() {
        let path = tmp("repair");
        std::fs::write(&path, b"junk").unwrap();
        assert!(TuneCache::load(&path).is_err());
        let mut cache = TuneCache::new();
        cache.put(1, decision());
        cache.save(&path).unwrap();
        assert_eq!(TuneCache::load(&path).unwrap().len(), 1);
    }
}

//! Transparent rule-based cost model: features → ranked engine/grid
//! candidates.
//!
//! The model is deliberately *not* a learned black box: it is a fixed
//! list of named, unit-testable rules, each mapping a feature pattern to
//! a score contribution for a specific candidate shape, with the reason
//! recorded alongside the score. Scores only ever *rank* candidates —
//! the final winner is crowned by the competitive trials of
//! [`crate::tune::trial`] (the paper's measure-don't-model method), so a
//! wrong rule costs at most a wasted trial slot, never a wrong decision.

use super::features::MatrixFeatures;
use crate::coordinator::EngineKind;
use crate::partition::PartitionConfig;

/// One engine/grid configuration the model can propose. `cfg` is only
/// meaningful for the blocked engines; the CSR baseline carries the base
/// config untouched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// Engine the candidate runs on (never `Auto`).
    pub kind: EngineKind,
    /// Partition grid the candidate is built with.
    pub cfg: PartitionConfig,
}

/// A candidate with its model score and the rules that fired.
#[derive(Clone, Debug)]
pub struct ScoredCandidate {
    /// The engine/grid configuration that was scored.
    pub candidate: Candidate,
    /// Sum of every firing rule's contribution.
    pub score: f64,
    /// Why each firing rule contributed.
    pub reasons: Vec<&'static str>,
}

/// A scoring rule: `Some((score_delta, why))` when it applies to the
/// candidate under these features.
pub type Rule = fn(&MatrixFeatures, &Candidate) -> Option<(f64, &'static str)>;

/// Below this nnz the blocked engines' partial/combine overhead is
/// larger than any layout gain.
pub const TINY_NNZ: usize = 4096;

/// Row-length CV below which reordering cannot improve warp grouping.
pub const UNIFORM_CV: f64 = 0.25;

/// Row-length CV above which hash grouping clearly pays.
pub const SKEWED_CV: f64 = 0.5;

/// Mean row length at or below which the flat engine's contiguous nnz
/// chunks cost almost no cut-row fix-up (cut rows are short).
pub const SHORT_ROW_MEAN: f64 = 16.0;

/// Tiny matrices: stream them as CSR.
pub fn rule_tiny_matrix(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    (f.nnz < TINY_NNZ && c.kind == EngineKind::Csr)
        .then_some((2.0, "tiny matrix: blocked partial/combine overhead dominates"))
}

/// Uniform row lengths: the hash has nothing to balance.
pub fn rule_uniform_rows(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    if f.row_cv >= UNIFORM_CV {
        return None;
    }
    match c.kind {
        EngineKind::Csr => Some((1.0, "uniform row lengths: reordering cannot improve grouping")),
        EngineKind::Plain2d => {
            Some((0.5, "uniform row lengths: plain 2D already gets even groups"))
        }
        _ => None,
    }
}

/// Skewed row lengths: hash grouping balances warps (the paper's case).
pub fn rule_skewed_rows(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    if c.kind != EngineKind::Hbp || f.row_cv < SKEWED_CV {
        return None;
    }
    if f.row_cv >= 1.0 {
        Some((2.0, "highly skewed row lengths: hash grouping balances warps"))
    } else {
        Some((1.0, "moderately skewed row lengths: hash grouping helps"))
    }
}

/// A heavy tail of ultra-dense rows (power/ground nets, kron hubs).
pub fn rule_heavy_tail(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    (c.kind == EngineKind::Hbp && f.row_mean > 0.0 && f.row_max as f64 > 8.0 * f.row_mean)
        .then_some((0.75, "heavy-tail rows: grouping + competitive schedule absorb hot rows"))
}

/// Vector wider than one column segment: 2D tiling keeps segments
/// cache-resident.
pub fn rule_wide_vector(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    (matches!(c.kind, EngineKind::Hbp | EngineKind::Plain2d) && f.cols > c.cfg.cols_per_block)
        .then_some((0.5, "vector wider than one segment: 2D tiling localizes x"))
}

/// Near-diagonal band: row-streaming CSR is already cache-friendly.
pub fn rule_near_diagonal(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    (c.kind == EngineKind::Csr && f.diag_frac > 0.0 && f.bandwidth_frac < 0.02)
        .then_some((0.75, "near-diagonal band: streaming CSR is cache-friendly"))
}

/// Enough blocks under this grid to load-balance across workers.
pub fn rule_grid_occupancy(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    if c.kind != EngineKind::Hbp {
        return None;
    }
    let blocks =
        f.rows.div_ceil(c.cfg.rows_per_block).max(1) * f.cols.div_ceil(c.cfg.cols_per_block).max(1);
    (blocks >= 8).then_some((0.5, "grid yields enough blocks to load-balance"))
}

/// Uniform short rows: flat's equal-nnz chunks are perfectly balanced
/// by construction, with zero format-conversion cost — exactly where
/// reordering's preprocessing never pays for itself.
pub fn rule_uniform_short_rows(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    (c.kind == EngineKind::Flat
        && f.row_cv < UNIFORM_CV
        && f.row_mean > 0.0
        && f.row_mean <= SHORT_ROW_MEAN)
        .then_some((1.5, "uniform short rows: flat nnz chunks balance with zero conversion cost"))
}

/// Mixed skew — a short-row body plus a long-row tail: line-enhance
/// row-splits the body and gives each tail row a dedicated owner,
/// again with zero conversion cost.
pub fn rule_mixed_skew(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    (c.kind == EngineKind::LineEnhance
        && f.row_cv >= SKEWED_CV
        && f.row_mean > 0.0
        && f.row_max as f64 > 4.0 * f.row_mean)
        .then_some((1.25, "mixed row skew: row-split short bands, nnz-split the long tail"))
}

/// Mostly-dense blocks: plain 2D row-major streaming suffices.
pub fn rule_dense_blocks(f: &MatrixFeatures, c: &Candidate) -> Option<(f64, &'static str)> {
    let dense_frac: f64 = f.block_fill_hist[4] + f.block_fill_hist[5];
    (c.kind == EngineKind::Plain2d && dense_frac > 0.5)
        .then_some((0.5, "mostly dense blocks: row-major 2D streaming suffices"))
}

/// The model's fixed rule list, applied in order.
pub const RULES: [Rule; 10] = [
    rule_tiny_matrix,
    rule_uniform_rows,
    rule_skewed_rows,
    rule_heavy_tail,
    rule_wide_vector,
    rule_near_diagonal,
    rule_grid_occupancy,
    rule_uniform_short_rows,
    rule_mixed_skew,
    rule_dense_blocks,
];

/// Score one candidate: sum of every firing rule, with reasons.
pub fn score(f: &MatrixFeatures, c: &Candidate) -> (f64, Vec<&'static str>) {
    let mut total = 0.0;
    let mut reasons = Vec::new();
    for rule in RULES {
        if let Some((delta, why)) = rule(f, c) {
            total += delta;
            reasons.push(why);
        }
    }
    (total, reasons)
}

/// Candidate set: the five engines at the base config (the CSR-native
/// flat/line-enhance kinds ignore the grid), plus HBP grid variants
/// (halved/doubled rows and columns per block, where valid) — the knob
/// the paper itself ablates (`ablation_block_size`).
pub fn candidates(base: PartitionConfig) -> Vec<Candidate> {
    let mut out = vec![
        Candidate { kind: EngineKind::Hbp, cfg: base },
        Candidate { kind: EngineKind::Csr, cfg: base },
        Candidate { kind: EngineKind::Plain2d, cfg: base },
        Candidate { kind: EngineKind::Flat, cfg: base },
        Candidate { kind: EngineKind::LineEnhance, cfg: base },
    ];
    for rows_per_block in [base.rows_per_block / 2, base.rows_per_block * 2] {
        let cfg = PartitionConfig { rows_per_block, ..base };
        if rows_per_block != base.rows_per_block && cfg.validate().is_ok() {
            out.push(Candidate { kind: EngineKind::Hbp, cfg });
        }
    }
    for cols_per_block in [base.cols_per_block / 2, base.cols_per_block * 2] {
        let cfg = PartitionConfig { cols_per_block, ..base };
        if cols_per_block != base.cols_per_block && cfg.validate().is_ok() {
            out.push(Candidate { kind: EngineKind::Hbp, cfg });
        }
    }
    out
}

/// Rank the candidate set by model score, descending. The sort is
/// stable, so ties keep the fixed candidate order — ranking is fully
/// deterministic for a given feature vector.
pub fn rank(f: &MatrixFeatures, base: PartitionConfig) -> Vec<ScoredCandidate> {
    let mut scored: Vec<ScoredCandidate> = candidates(base)
        .into_iter()
        .map(|candidate| {
            let (score, reasons) = score(f, &candidate);
            ScoredCandidate { candidate, score, reasons }
        })
        .collect();
    scored.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal));
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::tune::features::FILL_BUCKETS;

    /// Feature vector with neutral defaults; tests override the signal
    /// under test.
    fn base_features() -> MatrixFeatures {
        MatrixFeatures {
            rows: 10_000,
            cols: 10_000,
            nnz: 100_000,
            row_mean: 10.0,
            row_std: 3.0,
            row_max: 30,
            row_cv: 0.3,
            zero_row_frac: 0.0,
            diag_frac: 0.01,
            bandwidth_mean: 3000.0,
            bandwidth_frac: 0.3,
            nonempty_blocks: 40,
            block_nnz_cv: 0.5,
            block_fill_hist: [0.0; FILL_BUCKETS],
        }
    }

    fn cand(kind: EngineKind) -> Candidate {
        Candidate { kind, cfg: PartitionConfig::default() }
    }

    #[test]
    fn tiny_matrix_rule_prefers_csr() {
        let mut f = base_features();
        f.nnz = 100;
        assert!(rule_tiny_matrix(&f, &cand(EngineKind::Csr)).is_some());
        assert!(rule_tiny_matrix(&f, &cand(EngineKind::Hbp)).is_none());
        f.nnz = TINY_NNZ;
        assert!(rule_tiny_matrix(&f, &cand(EngineKind::Csr)).is_none());
    }

    #[test]
    fn uniformity_rules_split_on_cv() {
        let mut f = base_features();
        f.row_cv = 0.1;
        assert!(rule_uniform_rows(&f, &cand(EngineKind::Csr)).is_some());
        assert!(rule_skewed_rows(&f, &cand(EngineKind::Hbp)).is_none());
        f.row_cv = 1.5;
        assert!(rule_uniform_rows(&f, &cand(EngineKind::Csr)).is_none());
        let (s, _) = rule_skewed_rows(&f, &cand(EngineKind::Hbp)).unwrap();
        assert_eq!(s, 2.0);
        f.row_cv = 0.7;
        let (s, _) = rule_skewed_rows(&f, &cand(EngineKind::Hbp)).unwrap();
        assert_eq!(s, 1.0);
    }

    #[test]
    fn heavy_tail_rule_needs_hot_rows() {
        let mut f = base_features();
        f.row_max = 500; // 50x the mean
        assert!(rule_heavy_tail(&f, &cand(EngineKind::Hbp)).is_some());
        f.row_max = 20;
        assert!(rule_heavy_tail(&f, &cand(EngineKind::Hbp)).is_none());
    }

    #[test]
    fn near_diagonal_rule_reads_bandwidth() {
        let mut f = base_features();
        f.bandwidth_frac = 0.001;
        f.diag_frac = 0.2;
        assert!(rule_near_diagonal(&f, &cand(EngineKind::Csr)).is_some());
        f.bandwidth_frac = 0.3;
        assert!(rule_near_diagonal(&f, &cand(EngineKind::Csr)).is_none());
    }

    #[test]
    fn wide_vector_rule_compares_against_candidate_segment() {
        let mut f = base_features();
        f.cols = 100_000;
        assert!(rule_wide_vector(&f, &cand(EngineKind::Hbp)).is_some());
        assert!(rule_wide_vector(&f, &cand(EngineKind::Csr)).is_none());
        f.cols = 1000; // fits one 4096-wide segment
        assert!(rule_wide_vector(&f, &cand(EngineKind::Hbp)).is_none());
    }

    #[test]
    fn grid_occupancy_counts_candidate_blocks() {
        let mut f = base_features();
        f.rows = 100;
        f.cols = 100; // 1x1 grid under the default config
        assert!(rule_grid_occupancy(&f, &cand(EngineKind::Hbp)).is_none());
        f.rows = 100_000; // 196 row blocks
        assert!(rule_grid_occupancy(&f, &cand(EngineKind::Hbp)).is_some());
    }

    #[test]
    fn dense_block_rule_reads_the_histogram() {
        let mut f = base_features();
        f.block_fill_hist[5] = 0.8;
        assert!(rule_dense_blocks(&f, &cand(EngineKind::Plain2d)).is_some());
        assert!(rule_dense_blocks(&f, &cand(EngineKind::Hbp)).is_none());
    }

    #[test]
    fn uniform_short_rows_rule_prefers_flat() {
        let mut f = base_features();
        f.row_cv = 0.1;
        f.row_mean = 6.0;
        let (s, _) = rule_uniform_short_rows(&f, &cand(EngineKind::Flat)).unwrap();
        assert_eq!(s, 1.5);
        assert!(rule_uniform_short_rows(&f, &cand(EngineKind::Csr)).is_none());
        // long uniform rows: chunk cut rows get expensive, no fire
        f.row_mean = 40.0;
        assert!(rule_uniform_short_rows(&f, &cand(EngineKind::Flat)).is_none());
        // skewed rows: flat's equal chunks no longer mirror the rows
        f.row_mean = 6.0;
        f.row_cv = 0.8;
        assert!(rule_uniform_short_rows(&f, &cand(EngineKind::Flat)).is_none());
        // an all-empty matrix must not fire on 0.0 <= SHORT_ROW_MEAN
        f.row_cv = 0.0;
        f.row_mean = 0.0;
        assert!(rule_uniform_short_rows(&f, &cand(EngineKind::Flat)).is_none());
    }

    #[test]
    fn mixed_skew_rule_prefers_line_enhance() {
        let mut f = base_features();
        f.row_cv = 0.7;
        f.row_max = 100; // > 4x the mean of 10
        let (s, _) = rule_mixed_skew(&f, &cand(EngineKind::LineEnhance)).unwrap();
        assert_eq!(s, 1.25);
        assert!(rule_mixed_skew(&f, &cand(EngineKind::Hbp)).is_none());
        // skew without a real tail: nothing for the long-row path
        f.row_max = 30;
        assert!(rule_mixed_skew(&f, &cand(EngineKind::LineEnhance)).is_none());
        // a tail without skew: the body is uniform, bands suffice anyway
        f.row_max = 100;
        f.row_cv = 0.2;
        assert!(rule_mixed_skew(&f, &cand(EngineKind::LineEnhance)).is_none());
    }

    #[test]
    fn uniform_short_matrix_crowns_flat() {
        let mut f = base_features();
        f.row_cv = 0.1;
        f.row_mean = 6.0;
        let ranked = rank(&f, PartitionConfig::default());
        assert_eq!(ranked[0].candidate.kind, EngineKind::Flat);
        assert!(!ranked[0].reasons.is_empty(), "winning score must carry reasons");
    }

    #[test]
    fn mixed_skew_matrix_crowns_line_enhance() {
        let mut f = base_features();
        f.rows = 2000;
        f.cols = 1000;
        f.nnz = 40_000;
        f.row_mean = 20.0;
        f.row_max = 100;
        f.row_cv = 0.7;
        let ranked = rank(&f, PartitionConfig::default());
        assert_eq!(ranked[0].candidate.kind, EngineKind::LineEnhance);
        assert!(!ranked[0].reasons.is_empty(), "winning score must carry reasons");
    }

    #[test]
    fn uniform_matrix_competitive_winner_is_csr_native() {
        use crate::tune::trial::run_trials;
        use crate::tune::TrialConfig;
        // perfectly uniform short rows, nnz < TINY_NNZ: the model ranks
        // Csr (tiny + uniform = 3.0) then Flat (1.5); with top_k = 2 the
        // trial winner is a CSR-native engine by construction, and Flat
        // earned its trial slot over the blocked engines
        let m = random::with_row_lengths(&[8; 400], 200, 17);
        let f = MatrixFeatures::extract(&m, PartitionConfig::default());
        let ranked = rank(&f, PartitionConfig::default());
        assert_eq!(ranked[0].candidate.kind, EngineKind::Csr);
        assert_eq!(ranked[1].candidate.kind, EngineKind::Flat);
        let tc = TrialConfig { top_k: 2, ..TrialConfig::default() };
        let report = run_trials(&m, &ranked, &tc, 2);
        assert!(
            matches!(report.winner().kind, EngineKind::Csr | EngineKind::Flat),
            "winner {:?} is not CSR-native",
            report.winner().kind
        );
        assert!(
            report.trials.iter().any(|t| t.kind == EngineKind::Flat),
            "flat must have been trialed"
        );
    }

    #[test]
    fn candidate_set_is_valid_and_never_auto() {
        for base in [PartitionConfig::default(), PartitionConfig::test_small()] {
            let cands = candidates(base);
            assert!(cands.len() >= 5);
            for c in &cands {
                assert_ne!(c.kind, EngineKind::Auto);
                c.cfg.validate().unwrap();
            }
            // the five engines at base config are always present
            for kind in [
                EngineKind::Hbp,
                EngineKind::Csr,
                EngineKind::Plain2d,
                EngineKind::Flat,
                EngineKind::LineEnhance,
            ] {
                assert!(cands.iter().any(|c| c.kind == kind && c.cfg == base));
            }
        }
    }

    #[test]
    fn ranking_is_deterministic_and_sorted() {
        let m = random::power_law_rows(200, 200, 2.0, 60, 3);
        let f = MatrixFeatures::extract(&m, PartitionConfig::test_small());
        let a = rank(&f, PartitionConfig::test_small());
        let b = rank(&f, PartitionConfig::test_small());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.score, y.score);
        }
        for w in a.windows(2) {
            assert!(w[0].score >= w[1].score, "ranking not sorted");
        }
    }

    #[test]
    fn skewed_matrix_ranks_hbp_first() {
        let mut f = base_features();
        f.row_cv = 2.0;
        f.row_max = 5000;
        let ranked = rank(&f, PartitionConfig::default());
        assert_eq!(ranked[0].candidate.kind, EngineKind::Hbp);
        assert!(!ranked[0].reasons.is_empty(), "winning score must carry reasons");
    }

    #[test]
    fn tiny_uniform_matrix_ranks_csr_first() {
        let mut f = base_features();
        f.nnz = 500;
        f.row_cv = 0.05;
        f.rows = 100;
        f.cols = 100;
        let ranked = rank(&f, PartitionConfig::default());
        assert_eq!(ranked[0].candidate.kind, EngineKind::Csr);
    }
}

//! Matrix autotuning: features → cost model → competitive trials →
//! cached decision.
//!
//! The serving problem the SpMV literature keeps rediscovering is that
//! no single format or configuration wins across matrices — selection,
//! not execution, is the production bottleneck. This subsystem decides
//! *per matrix* which engine (and, for HBP, which partition grid)
//! should serve it:
//!
//! 1. [`features`] — one O(nnz) pass extracts [`MatrixFeatures`]
//!    (row-length moments, diagonal/bandwidth structure, block density
//!    histogram from the HBP planner's own counting pass).
//! 2. [`model`] — a transparent rule/score cost model ranks engine ×
//!    grid candidates; every rule is a named, unit-testable function.
//! 3. [`trial`] — the paper's competitive method generalized to engine
//!    selection: the top-k candidates are timed on real `spmv` calls
//!    (warmup + median-of-n, fixed deterministic budget) and the
//!    fastest wins.
//! 4. [`cache`] — the winner is remembered under the matrix's content
//!    hash mixed with the tuning context ([`Tuner::cache_key`]), in
//!    memory and optionally on disk, so a re-registered or
//!    server-restarted matrix skips straight to its decision.
//!
//! [`Tuner::tune`] is the entry point; the coordinator's router calls
//! it at registration (and again from `Router::resolve_blocking` when
//! an applied delta stales a decision) and resolves `EngineKind::Auto`
//! requests to the tuned decision.

#![warn(missing_docs)]

pub mod cache;
pub mod features;
pub mod model;
pub mod trial;

pub use cache::{content_hash, TuneCache};
pub use features::MatrixFeatures;
pub use model::{Candidate, ScoredCandidate};
pub use trial::{build_candidate, TrialConfig, TrialResult, TuneReport};

use crate::coordinator::EngineKind;
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use crate::util::Timer;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A tuned serving decision: which engine hosts the matrix, under which
/// partition grid, and the trial time that crowned it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// Never [`EngineKind::Auto`] — a decision is what Auto resolves to.
    pub kind: EngineKind,
    /// Partition grid the winning engine was measured with.
    pub cfg: PartitionConfig,
    /// The winning median SpMV seconds (from the crowning trial run).
    pub trial_secs: f64,
}

/// Wall-time decomposition of one [`Tuner::tune`] call. Selection
/// overhead must be accountable against its amortized gains (the
/// format-survey critique), so the tune cost is reported per phase,
/// not as one opaque number.
#[derive(Clone, Copy, Debug, Default)]
pub struct TunePhases {
    /// Feature extraction (the O(nnz) structural pass).
    pub features_secs: f64,
    /// Competitive trials (builds + timed runs); `0` on a cache hit.
    pub trials_secs: f64,
}

/// Everything one [`Tuner::tune`] call learned.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Cache key: the matrix content hash mixed with the tuning context
    /// (see [`Tuner::cache_key`]).
    pub key: u64,
    /// True when the decision came from the cache — no trials ran.
    pub cache_hit: bool,
    /// The structural features extracted for the model's ranking.
    pub features: MatrixFeatures,
    /// The crowned (or replayed) serving decision.
    pub decision: Decision,
    /// The trial record; `None` on a cache hit.
    pub report: Option<TuneReport>,
    /// Wall time of the whole tune call (hash + features + trials).
    pub tune_secs: f64,
    /// Per-phase decomposition of `tune_secs`.
    pub phases: TunePhases,
}

/// The autotuner: owns the trial budget and the (optionally persistent)
/// decision cache. Thread-safe: `tune` takes `&self`.
pub struct Tuner {
    /// Base partition config; grid candidates are derived from it.
    pub base_cfg: PartitionConfig,
    /// Worker threads used by trial engines (and the decided engine).
    pub threads: usize,
    /// Trial budget (top-k, warmup, timed iterations).
    pub trial: TrialConfig,
    cache_path: Option<PathBuf>,
    cache: Mutex<TuneCache>,
}

impl Tuner {
    /// In-memory tuner: decisions are remembered for the process
    /// lifetime only.
    pub fn new(base_cfg: PartitionConfig, threads: usize) -> Tuner {
        Tuner {
            base_cfg,
            threads: threads.max(1),
            trial: TrialConfig::default(),
            cache_path: None,
            cache: Mutex::new(TuneCache::new()),
        }
    }

    /// Persistent tuner: loads `path` (missing file = empty cache) and
    /// saves after every new decision. A corrupt cache file is
    /// downgraded to an empty cache with a warning — it costs one
    /// re-tune and is overwritten by the next save, never a panic and
    /// never a bogus decision.
    pub fn with_cache(base_cfg: PartitionConfig, threads: usize, path: PathBuf) -> Tuner {
        let cache = TuneCache::load(&path).unwrap_or_else(|e| {
            eprintln!("tune: ignoring corrupt cache {path:?}: {e:#}");
            TuneCache::new()
        });
        Tuner { cache: Mutex::new(cache), cache_path: Some(path), ..Tuner::new(base_cfg, threads) }
    }

    /// Where decisions persist, if this tuner was built with a cache
    /// file.
    pub fn cache_path(&self) -> Option<&Path> {
        self.cache_path.as_deref()
    }

    /// Cached decisions currently held (memory view).
    pub fn cached_decisions(&self) -> usize {
        self.cache.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Cache key: [`content_hash`] of the matrix mixed with the tuning
    /// context — worker threads and the base partition config. A
    /// decision is only as good as the context it was measured in
    /// (CSR may win single-threaded where HBP wins on 8 workers), so a
    /// decision tuned under one context must never be replayed in
    /// another; differing contexts simply miss and re-tune.
    pub fn cache_key(&self, m: &Csr) -> u64 {
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = content_hash(m);
        for v in [
            self.threads as u64,
            self.base_cfg.rows_per_block as u64,
            self.base_cfg.cols_per_block as u64,
            self.base_cfg.warp as u64,
        ] {
            h = (h ^ v).wrapping_mul(FNV_PRIME);
        }
        h
    }

    /// Tune one matrix: compute its cache key, return the cached
    /// decision if one exists, otherwise rank candidates and run
    /// competitive trials, remembering (and persisting) the winner.
    pub fn tune(&self, m: &Csr) -> TuneOutcome {
        let t = Timer::start();
        let key = self.cache_key(m);
        let (features, features_secs) =
            crate::util::timer::time(|| MatrixFeatures::extract(m, self.base_cfg));
        if let Some(decision) = self.cache.lock().unwrap_or_else(|e| e.into_inner()).get(key) {
            return TuneOutcome {
                key,
                cache_hit: true,
                features,
                decision,
                report: None,
                tune_secs: t.elapsed_secs(),
                phases: TunePhases { features_secs, trials_secs: 0.0 },
            };
        }
        let ranked = model::rank(&features, self.base_cfg);
        let (report, trials_secs) =
            crate::util::timer::time(|| trial::run_trials(m, &ranked, &self.trial, self.threads));
        let w = report.winner();
        let decision = Decision { kind: w.kind, cfg: w.cfg, trial_secs: w.median_secs };
        {
            let mut cache = self.cache.lock().unwrap_or_else(|e| e.into_inner());
            cache.put(key, decision);
            if let Some(path) = &self.cache_path {
                if let Err(e) = cache.save(path) {
                    eprintln!("tune: cache save to {path:?} failed: {e:#}");
                }
            }
        }
        TuneOutcome {
            key,
            cache_hit: false,
            features,
            decision,
            report: Some(report),
            tune_secs: t.elapsed_secs(),
            phases: TunePhases { features_secs, trials_secs },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SpmvEngine;
    use crate::gen::random;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("hbp_tuner_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("tune.cache")
    }

    fn quick_tuner(cfg: PartitionConfig) -> Tuner {
        let mut t = Tuner::new(cfg, 2);
        t.trial = TrialConfig { top_k: 3, warmup: 1, iters: 2, seed: 5 };
        t
    }

    #[test]
    fn second_tune_of_same_content_is_a_cache_hit() {
        let m = random::power_law_rows(120, 100, 2.0, 30, 21);
        let tuner = quick_tuner(PartitionConfig::test_small());
        let cold = tuner.tune(&m);
        assert!(!cold.cache_hit);
        assert!(cold.report.is_some(), "cold tune must run trials");
        assert_ne!(cold.decision.kind, EngineKind::Auto);

        assert!(cold.phases.trials_secs > 0.0, "cold tune must spend trial time");
        assert!(cold.phases.features_secs + cold.phases.trials_secs <= cold.tune_secs + 1e-6);

        let warm = tuner.tune(&m.clone());
        assert!(warm.cache_hit);
        assert!(warm.report.is_none(), "cache hit must skip trials");
        assert_eq!(warm.phases.trials_secs, 0.0, "cache hit must report zero trial time");
        assert_eq!(warm.key, cold.key);
        assert_eq!(warm.decision, cold.decision);
        assert_eq!(tuner.cached_decisions(), 1);
    }

    #[test]
    fn different_content_is_a_miss() {
        let tuner = quick_tuner(PartitionConfig::test_small());
        let a = tuner.tune(&random::power_law_rows(60, 60, 2.0, 15, 1));
        let b = tuner.tune(&random::power_law_rows(60, 60, 2.0, 15, 2));
        assert!(!a.cache_hit && !b.cache_hit);
        assert_ne!(a.key, b.key);
        assert_eq!(tuner.cached_decisions(), 2);
    }

    #[test]
    fn decisions_persist_across_tuner_instances() {
        let path = tmp("persist");
        let _ = std::fs::remove_file(&path); // stale state from earlier runs
        let m = random::power_law_rows(100, 90, 2.0, 25, 9);
        let first = Tuner::with_cache(PartitionConfig::test_small(), 2, path.clone());
        let cold = first.tune(&m);
        assert!(!cold.cache_hit);

        // a fresh tuner (= restarted server) loads the saved decision
        let second = Tuner::with_cache(PartitionConfig::test_small(), 2, path);
        let warm = second.tune(&m);
        assert!(warm.cache_hit, "persisted decision must survive a restart");
        assert_eq!(warm.decision, cold.decision);
    }

    #[test]
    fn different_tuning_context_is_a_miss() {
        let m = random::uniform(30, 30, 0.3, 8);
        let path = tmp("context");
        let _ = std::fs::remove_file(&path);
        let one = Tuner::with_cache(PartitionConfig::test_small(), 1, path.clone());
        assert!(!one.tune(&m).cache_hit);
        // same matrix, different thread count: decisions don't transfer
        let eight = Tuner::with_cache(PartitionConfig::test_small(), 8, path.clone());
        assert!(!eight.tune(&m).cache_hit, "a 1-thread decision must not serve 8 threads");
        // same matrix, different base grid: decisions don't transfer
        let other_grid = Tuner::with_cache(PartitionConfig::default(), 8, path.clone());
        assert!(!other_grid.tune(&m).cache_hit, "decisions are per base config");
        // identical context again: hit
        let eight2 = Tuner::with_cache(PartitionConfig::test_small(), 8, path);
        assert!(eight2.tune(&m).cache_hit);
    }

    #[test]
    fn corrupt_cache_file_degrades_to_a_miss() {
        let path = tmp("corrupt");
        std::fs::write(&path, b"not a cache file").unwrap();
        let m = random::uniform(40, 40, 0.2, 3);
        let tuner = Tuner::with_cache(PartitionConfig::test_small(), 1, path.clone());
        let outcome = tuner.tune(&m);
        assert!(!outcome.cache_hit, "corrupt cache must not fake a hit");
        // the save after the miss repaired the file
        assert_eq!(TuneCache::load(&path).unwrap().len(), 1);
    }

    #[test]
    fn decision_engine_serves_the_matrix_correctly() {
        let m = random::power_law_rows(80, 70, 2.0, 20, 13);
        let tuner = quick_tuner(PartitionConfig::test_small());
        let outcome = tuner.tune(&m);
        let engine =
            build_candidate(&m, outcome.decision.kind, outcome.decision.cfg, tuner.threads);
        let x = random::vector(70, 4);
        let mut y = vec![0.0; 80];
        engine.spmv(&x, &mut y);
        let mut expect = vec![0.0; 80];
        m.spmv(&x, &mut expect);
        assert!(crate::formats::dense::allclose(&y, &expect, 1e-10, 1e-12));
    }
}

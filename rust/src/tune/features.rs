//! Structural feature extraction — the cheap signals the cost model
//! reads.
//!
//! The paper's thesis is that inexpensive structural statistics (row
//! nonzero counts, hash groupings, block densities) predict how a
//! matrix should be laid out. [`MatrixFeatures::extract`] computes the
//! tuner's signal set in O(nnz): row-length moments (what the nonlinear
//! hash balances), diagonal/bandwidth structure (what makes CSR
//! streaming competitive), and the per-block nnz distribution from the
//! same [`block_map`] counting pass the HBP planner runs. Extraction is
//! deterministic: the same matrix always yields bit-identical features,
//! which keeps the model's ranking — and therefore the tuner's trial
//! set — reproducible.

use crate::formats::Csr;
use crate::partition::{block_map, BlockGrid, PartitionConfig};
use crate::util::json::{obj, Json};
use crate::util::Stats;

/// Fill-fraction histogram bucket upper bounds (last bucket is open):
/// `fill < 1e-4`, `< 1e-3`, `< 1e-2`, `< 0.1`, `< 0.5`, `>= 0.5`.
pub const FILL_EDGES: [f64; 5] = [1e-4, 1e-3, 1e-2, 0.1, 0.5];

/// Number of buckets in [`MatrixFeatures::block_fill_hist`].
pub const FILL_BUCKETS: usize = FILL_EDGES.len() + 1;

/// One-pass structural summary of a CSR matrix under a partition grid.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixFeatures {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Nonzero count.
    pub nnz: usize,
    /// Mean row nonzero count — the hash reorder's input statistic.
    pub row_mean: f64,
    /// Standard deviation of row nonzero counts.
    pub row_std: f64,
    /// Largest row nonzero count.
    pub row_max: usize,
    /// Coefficient of variation `row_std / row_mean` (0 for empty
    /// matrices) — the single strongest "does reordering pay?" signal.
    pub row_cv: f64,
    /// Fraction of rows with no nonzeros.
    pub zero_row_frac: f64,
    /// Fraction of nonzeros sitting exactly on the diagonal.
    pub diag_frac: f64,
    /// Mean `|col - row|` over all nonzeros — a bandwidth estimate.
    pub bandwidth_mean: f64,
    /// `bandwidth_mean / cols`: 0 for a pure diagonal, ~1/3 for uniform
    /// scatter.
    pub bandwidth_frac: f64,
    /// Non-empty blocks of the 2D grid (the HBP planner's block count).
    pub nonempty_blocks: usize,
    /// Coefficient of variation of per-block nnz across non-empty
    /// blocks — high values mean the competitive schedule has work to do.
    pub block_nnz_cv: f64,
    /// Fraction of non-empty blocks per fill-fraction bucket
    /// (see [`FILL_EDGES`]); sums to 1 when any block exists.
    pub block_fill_hist: [f64; FILL_BUCKETS],
}

/// Bucket index for a block fill fraction.
fn fill_bucket(fill: f64) -> usize {
    FILL_EDGES.iter().position(|&e| fill < e).unwrap_or(FILL_EDGES.len())
}

impl MatrixFeatures {
    /// Extract features in one O(nnz) sweep plus the [`block_map`]
    /// counting pass (itself O(nnz)) under `cfg`'s grid.
    pub fn extract(m: &Csr, cfg: PartitionConfig) -> MatrixFeatures {
        let nnz = m.nnz();
        let lens = m.row_lengths();
        let s = Stats::of_usize(&lens);
        let zeros = lens.iter().filter(|&&l| l == 0).count();

        let mut diag = 0usize;
        let mut band_sum = 0.0f64;
        for r in 0..m.rows {
            let (cols, _) = m.row(r);
            for &c in cols {
                let c = c as usize;
                if c == r {
                    diag += 1;
                }
                band_sum += (c as f64 - r as f64).abs();
            }
        }
        let bandwidth_mean = if nnz > 0 { band_sum / nnz as f64 } else { 0.0 };

        let grid = BlockGrid::new(m.rows, m.cols, cfg);
        let map = block_map(m, &grid);
        let block_nnz: Vec<usize> = map.blocks.iter().map(|b| b.nnz).collect();
        let bs = Stats::of_usize(&block_nnz);
        let mut hist = [0.0f64; FILL_BUCKETS];
        for b in &map.blocks {
            let rows_in = grid.rows_in(b.bi as usize);
            let (cs, ce) = grid.col_range(b.bj as usize);
            let cells = (rows_in * (ce - cs)).max(1);
            hist[fill_bucket(b.nnz as f64 / cells as f64)] += 1.0;
        }
        if !map.blocks.is_empty() {
            for h in &mut hist {
                *h /= map.blocks.len() as f64;
            }
        }

        MatrixFeatures {
            rows: m.rows,
            cols: m.cols,
            nnz,
            row_mean: s.mean,
            row_std: s.std,
            row_max: s.max as usize,
            row_cv: if s.mean > 0.0 { s.std / s.mean } else { 0.0 },
            zero_row_frac: zeros as f64 / m.rows.max(1) as f64,
            diag_frac: if nnz > 0 { diag as f64 / nnz as f64 } else { 0.0 },
            bandwidth_mean,
            bandwidth_frac: bandwidth_mean / m.cols.max(1) as f64,
            nonempty_blocks: map.blocks.len(),
            block_nnz_cv: if bs.mean > 0.0 { bs.std / bs.mean } else { 0.0 },
            block_fill_hist: hist,
        }
    }

    /// JSON view for the `tune` protocol op and the CLI.
    pub fn to_json(&self) -> Json {
        obj(&[
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("nnz", Json::Num(self.nnz as f64)),
            ("row_mean", Json::Num(self.row_mean)),
            ("row_std", Json::Num(self.row_std)),
            ("row_max", Json::Num(self.row_max as f64)),
            ("row_cv", Json::Num(self.row_cv)),
            ("zero_row_frac", Json::Num(self.zero_row_frac)),
            ("diag_frac", Json::Num(self.diag_frac)),
            ("bandwidth_mean", Json::Num(self.bandwidth_mean)),
            ("bandwidth_frac", Json::Num(self.bandwidth_frac)),
            ("nonempty_blocks", Json::Num(self.nonempty_blocks as f64)),
            ("block_nnz_cv", Json::Num(self.block_nnz_cv)),
            (
                "block_fill_hist",
                Json::Arr(self.block_fill_hist.iter().map(|&h| Json::Num(h)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::gen::random;

    fn cfg() -> PartitionConfig {
        PartitionConfig::test_small()
    }

    #[test]
    fn extraction_is_deterministic() {
        let m = random::power_law_rows(120, 150, 2.0, 40, 7);
        let a = MatrixFeatures::extract(&m, cfg());
        let b = MatrixFeatures::extract(&m, cfg());
        assert_eq!(a, b, "same matrix must yield bit-identical features");
    }

    #[test]
    fn diagonal_matrix_features() {
        let mut coo = Coo::new(50, 50);
        for i in 0..50 {
            coo.push(i, i, 1.0 + i as f64);
        }
        let f = MatrixFeatures::extract(&coo.to_csr(), cfg());
        assert_eq!(f.nnz, 50);
        assert_eq!(f.diag_frac, 1.0);
        assert_eq!(f.bandwidth_mean, 0.0);
        assert_eq!(f.row_cv, 0.0, "uniform single-entry rows");
        assert_eq!(f.zero_row_frac, 0.0);
    }

    #[test]
    fn zero_rows_and_skew_are_measured() {
        let m = random::with_row_lengths(&[0, 0, 12, 0, 1, 1], 40, 3);
        let f = MatrixFeatures::extract(&m, cfg());
        assert_eq!(f.zero_row_frac, 0.5);
        assert_eq!(f.row_max, 12);
        assert!(f.row_cv > 1.0, "skewed lengths must show high cv: {}", f.row_cv);
    }

    #[test]
    fn block_histogram_sums_to_one() {
        let m = random::power_law_rows(100, 200, 2.0, 50, 11);
        let f = MatrixFeatures::extract(&m, cfg());
        assert!(f.nonempty_blocks > 0);
        let total: f64 = f.block_fill_hist.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "hist sums to {total}");
    }

    #[test]
    fn empty_matrix_is_all_zero() {
        let f = MatrixFeatures::extract(&Csr::empty(8, 8), cfg());
        assert_eq!(f.nnz, 0);
        assert_eq!(f.row_cv, 0.0);
        assert_eq!(f.diag_frac, 0.0);
        assert_eq!(f.nonempty_blocks, 0);
        assert_eq!(f.zero_row_frac, 1.0);
        assert_eq!(f.block_fill_hist.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn fill_buckets_cover_the_range() {
        assert_eq!(fill_bucket(0.0), 0);
        assert_eq!(fill_bucket(5e-4), 1);
        assert_eq!(fill_bucket(5e-3), 2);
        assert_eq!(fill_bucket(0.05), 3);
        assert_eq!(fill_bucket(0.3), 4);
        assert_eq!(fill_bucket(0.9), 5);
        assert_eq!(fill_bucket(1.0), FILL_BUCKETS - 1);
    }

    #[test]
    fn json_view_carries_the_signals() {
        let m = random::uniform(30, 30, 0.2, 5);
        let f = MatrixFeatures::extract(&m, cfg());
        let j = f.to_json();
        assert_eq!(j.get("nnz").and_then(Json::as_usize), Some(f.nnz));
        assert_eq!(j.get("row_cv").and_then(Json::as_f64), Some(f.row_cv));
        assert_eq!(
            j.get("block_fill_hist").and_then(Json::as_arr).map(|a| a.len()),
            Some(FILL_BUCKETS)
        );
    }
}

//! Competitive trials: crown the winner by measurement, not modeling.
//!
//! This generalizes the paper's §III-C competitive method from block
//! scheduling to engine selection: the model's top-k candidates are
//! each built against the resident matrix and timed on real `spmv`
//! calls (warmup + median-of-n with a fixed, deterministic iteration
//! budget), and the fastest median wins. HBP candidate builds go
//! through [`build_hbp_parallel`], i.e. the process-wide
//! `util::pool::shared_pool` workers — trials reuse the same warm pools
//! the serving path fills on.
//!
//! Ties break toward the earlier (higher model score) candidate, so a
//! trial run is deterministic up to the timing measurements themselves.

use super::model::ScoredCandidate;
use crate::coordinator::EngineKind;
use crate::exec::{CsrParallel, FlatEngine, HbpEngine, LineEnhanceEngine, SpmvEngine, Spmv2dEngine};
use crate::formats::Csr;
use crate::gen::random;
use crate::partition::PartitionConfig;
use crate::preprocess::{build_hbp_parallel, HashReorder};
use crate::util::json::{obj, Json};
use crate::util::stats::percentile;
use crate::util::Timer;

/// Trial budget. Fixed counts (not a time budget) keep the trial
/// deterministic in its *shape*; only the measured durations vary.
#[derive(Clone, Copy, Debug)]
pub struct TrialConfig {
    /// How many of the model's ranked candidates get measured.
    pub top_k: usize,
    /// Untimed warmup iterations per candidate.
    pub warmup: usize,
    /// Timed iterations per candidate (median is the score).
    pub iters: usize,
    /// Seed of the trial input vector.
    pub seed: u64,
}

impl Default for TrialConfig {
    fn default() -> Self {
        TrialConfig { top_k: 3, warmup: 1, iters: 5, seed: 0x7E57 }
    }
}

/// One measured candidate.
#[derive(Clone, Copy, Debug)]
pub struct TrialResult {
    /// Engine the candidate ran on.
    pub kind: EngineKind,
    /// Partition grid the candidate was built with.
    pub cfg: PartitionConfig,
    /// The model score that earned the trial slot.
    pub model_score: f64,
    /// Median SpMV seconds over the timed iterations.
    pub median_secs: f64,
}

/// The full trial record: every measured candidate (in model-rank
/// order) and the winner's index.
#[derive(Clone, Debug)]
pub struct TuneReport {
    /// Every measured candidate, in model-rank order.
    pub trials: Vec<TrialResult>,
    /// Index of the fastest median in `trials`.
    pub winner: usize,
}

impl TuneReport {
    /// The crowned candidate.
    pub fn winner(&self) -> &TrialResult {
        &self.trials[self.winner]
    }

    /// JSON view for the `tune` protocol op and the CLI.
    pub fn to_json(&self) -> Json {
        let trials: Vec<Json> = self
            .trials
            .iter()
            .map(|t| {
                obj(&[
                    ("engine", Json::Str(t.kind.to_string())),
                    ("rows_per_block", Json::Num(t.cfg.rows_per_block as f64)),
                    ("cols_per_block", Json::Num(t.cfg.cols_per_block as f64)),
                    ("model_score", Json::Num(t.model_score)),
                    ("median_secs", Json::Num(t.median_secs)),
                ])
            })
            .collect();
        obj(&[("winner", Json::Num(self.winner as f64)), ("trials", Json::Arr(trials))])
    }
}

/// Build the engine a candidate describes. HBP builds run on the shared
/// worker pools; the CSR/2D baselines clone the matrix as their engines
/// require. Panics on [`EngineKind::Auto`] — the tuner resolves Auto,
/// it never builds it.
pub fn build_candidate(
    m: &Csr,
    kind: EngineKind,
    cfg: PartitionConfig,
    threads: usize,
) -> Box<dyn SpmvEngine> {
    match kind {
        EngineKind::Hbp => {
            let hbp = build_hbp_parallel(m, cfg, &HashReorder::default(), threads);
            Box::new(HbpEngine::new(hbp, threads, 0.25))
        }
        EngineKind::Csr => Box::new(CsrParallel::new(m.clone(), threads)),
        EngineKind::Plain2d => Box::new(Spmv2dEngine::new(m.clone(), cfg, threads)),
        EngineKind::Flat => Box::new(FlatEngine::new(m.clone(), threads)),
        EngineKind::LineEnhance => Box::new(LineEnhanceEngine::new(m.clone(), threads)),
        EngineKind::Auto => panic!("EngineKind::Auto must be resolved before engine construction"),
    }
}

/// Time the top-k ranked candidates on real SpMV calls and crown the
/// fastest median. `ranked` must be non-empty (the model always emits
/// the three base engines).
pub fn run_trials(
    m: &Csr,
    ranked: &[ScoredCandidate],
    tc: &TrialConfig,
    threads: usize,
) -> TuneReport {
    assert!(!ranked.is_empty(), "no candidates to trial");
    let k = tc.top_k.clamp(1, ranked.len());
    let x = random::vector(m.cols, tc.seed);
    let mut y = vec![0.0; m.rows];
    let mut trials = Vec::with_capacity(k);
    for sc in &ranked[..k] {
        let engine = build_candidate(m, sc.candidate.kind, sc.candidate.cfg, threads);
        for _ in 0..tc.warmup {
            engine.spmv(&x, &mut y);
        }
        let mut samples = Vec::with_capacity(tc.iters.max(1));
        for _ in 0..tc.iters.max(1) {
            let t = Timer::start();
            engine.spmv(&x, &mut y);
            samples.push(t.elapsed_secs());
        }
        trials.push(TrialResult {
            kind: sc.candidate.kind,
            cfg: sc.candidate.cfg,
            model_score: sc.score,
            median_secs: percentile(&samples, 50.0),
        });
    }
    // strict < keeps the first (highest model score) candidate on ties
    let mut winner = 0;
    for (i, t) in trials.iter().enumerate() {
        if t.median_secs < trials[winner].median_secs {
            winner = i;
        }
    }
    TuneReport { trials, winner }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::tune::features::MatrixFeatures;
    use crate::tune::model;

    #[test]
    fn trials_cover_top_k_in_rank_order() {
        let m = random::power_law_rows(150, 120, 2.0, 30, 9);
        let cfg = PartitionConfig::test_small();
        let ranked = model::rank(&MatrixFeatures::extract(&m, cfg), cfg);
        let tc = TrialConfig { top_k: 3, warmup: 1, iters: 3, seed: 1 };
        let report = run_trials(&m, &ranked, &tc, 2);
        assert_eq!(report.trials.len(), 3);
        assert!(report.winner < report.trials.len());
        for (t, sc) in report.trials.iter().zip(&ranked) {
            assert_eq!(t.kind, sc.candidate.kind);
            assert_eq!(t.cfg, sc.candidate.cfg);
            assert_eq!(t.model_score, sc.score);
            assert!(t.median_secs >= 0.0);
        }
        // the winner is the fastest median
        for t in &report.trials {
            assert!(report.winner().median_secs <= t.median_secs);
        }
    }

    #[test]
    fn top_k_clamps_to_candidate_count() {
        let m = random::uniform(40, 40, 0.2, 3);
        let cfg = PartitionConfig::test_small();
        let ranked = model::rank(&MatrixFeatures::extract(&m, cfg), cfg);
        let tc = TrialConfig { top_k: 99, warmup: 0, iters: 1, seed: 2 };
        let report = run_trials(&m, &ranked, &tc, 1);
        assert_eq!(report.trials.len(), ranked.len());
    }

    #[test]
    fn every_candidate_engine_computes_the_same_product() {
        let m = random::power_law_rows(90, 110, 2.0, 25, 17);
        let x = random::vector(110, 5);
        let mut expect = vec![0.0; 90];
        m.spmv(&x, &mut expect);
        let cfg = PartitionConfig::test_small();
        for c in model::candidates(cfg) {
            let engine = build_candidate(&m, c.kind, c.cfg, 2);
            let mut y = vec![0.0; 90];
            engine.spmv(&x, &mut y);
            assert!(
                allclose(&y, &expect, 1e-10, 1e-12),
                "{:?} at {}x{} diverged",
                c.kind,
                c.cfg.rows_per_block,
                c.cfg.cols_per_block
            );
        }
    }

    #[test]
    fn report_json_names_the_winner() {
        let m = random::uniform(30, 30, 0.3, 7);
        let cfg = PartitionConfig::test_small();
        let ranked = model::rank(&MatrixFeatures::extract(&m, cfg), cfg);
        let report = run_trials(&m, &ranked, &TrialConfig::default(), 1);
        let j = report.to_json();
        assert_eq!(j.get("winner").and_then(Json::as_usize), Some(report.winner));
        let trials = j.get("trials").and_then(Json::as_arr).unwrap();
        assert_eq!(trials.len(), report.trials.len());
        assert!(trials[0].get("engine").is_some());
    }

    #[test]
    #[should_panic(expected = "Auto must be resolved")]
    fn building_auto_is_a_bug() {
        let m = random::uniform(10, 10, 0.3, 1);
        let _ = build_candidate(&m, EngineKind::Auto, PartitionConfig::test_small(), 1);
    }
}

//! Shared-mutable slice for provably disjoint parallel writes.
//!
//! The SpMV engines write per-block partial vectors from multiple worker
//! threads. Every block owns a *disjoint* range of the partial buffer
//! (`slot_start..slot_start+nrows`), so the writes can never alias — but
//! safe Rust cannot express "disjointness decided at runtime by the
//! scheduler". [`SharedMut`] is the narrow unsafe escape hatch: callers
//! promise ranges handed to different threads do not overlap.

use std::cell::UnsafeCell;

/// A slice writable from multiple threads under a caller-enforced
/// disjointness contract.
pub struct SharedMut<'a, T> {
    data: &'a UnsafeCell<[T]>,
}

// SAFETY: all mutation goes through `write`/`slice_mut`, whose contracts
// require disjoint index ranges across threads.
unsafe impl<T: Send> Sync for SharedMut<'_, T> {}
unsafe impl<T: Send> Send for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        // SAFETY: &mut guarantees exclusivity; UnsafeCell re-enables
        // interior mutability which we then partition manually.
        let data = unsafe { &*(slice as *mut [T] as *const UnsafeCell<[T]>) };
        SharedMut { data }
    }

    pub fn len(&self) -> usize {
        self.data.get().len() // raw-slice len: never races
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other thread may concurrently access index `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len());
        let ptr = self.data.get().cast::<T>();
        unsafe { ptr.add(i).write(v) };
    }

    /// Mutable subslice `[start, start+len)`.
    ///
    /// # Safety
    /// No other thread may concurrently access any index in the range.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len());
        let ptr = self.data.get().cast::<T>();
        unsafe { std::slice::from_raw_parts_mut(ptr.add(start), len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_parallel_writes() {
        let mut buf = vec![0u64; 1024];
        {
            let shared = SharedMut::new(&mut buf);
            std::thread::scope(|s| {
                for t in 0..8 {
                    let shared = &shared;
                    s.spawn(move || {
                        let chunk = unsafe { shared.slice_mut(t * 128, 128) };
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = (t * 128 + i) as u64;
                        }
                    });
                }
            });
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn single_writes() {
        let mut buf = vec![0u32; 4];
        {
            let shared = SharedMut::new(&mut buf);
            unsafe {
                shared.write(2, 7);
            }
            assert_eq!(shared.len(), 4);
        }
        assert_eq!(buf, vec![0, 0, 7, 0]);
    }
}

//! Summary statistics used across benches and the Fig. 6 analysis
//! (standard deviation of nonzeros per warp-group).

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    /// Compute statistics of an f64 slice (population standard deviation,
    /// matching the paper's per-group dispersion metric).
    pub fn of(xs: &[f64]) -> Stats {
        if xs.is_empty() {
            return Stats::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &x in xs {
            if x < min {
                min = x;
            }
            if x > max {
                max = x;
            }
        }
        Stats { n, mean, std: var.sqrt(), min, max }
    }

    /// Convenience for integer samples.
    pub fn of_usize(xs: &[usize]) -> Stats {
        let v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
        Stats::of(&v)
    }
}

/// `q`-th percentile (0..=100) via linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean (used for paper-style "average speedup" aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Streaming mean/variance (Welford). Used by the simulator's counters
/// where samples are too many to buffer.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.m2 / self.n as f64).sqrt()
        }
    }
}

/// Fixed-bucket histogram for latency reporting in the coordinator.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Exponential bucket bounds from `lo` doubling `n` times.
    pub fn exponential(lo: f64, n: usize) -> Self {
        let bounds: Vec<f64> = (0..n).map(|i| lo * 2f64.powi(i as i32)).collect();
        let counts = vec![0; n + 1];
        Histogram { bounds, counts, total: 0, sum: 0.0 }
    }

    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.iter().position(|&b| x < b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Sum of all recorded observations (Prometheus `_sum`).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Bucket upper bounds, exclusive of the open top bucket.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket counts; one longer than `bounds()` — the last entry is
    /// the open top bucket.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// `(upper_bound, cumulative_count)` pairs in Prometheus exposition
    /// shape: counts are cumulative (each bucket includes everything
    /// below it) and the final entry is `(+inf, total)`.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        for (i, &b) in self.bounds.iter().enumerate() {
            acc += self.counts[i];
            out.push((b, acc));
        }
        out.push((f64::INFINITY, self.total));
        out
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-quantile).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(s.n, 8);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.std - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Stats::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::exponential(1.0, 10);
        for i in 1..=1000 {
            h.record(i as f64 / 10.0);
        }
        assert_eq!(h.total(), 1000);
        let p50 = h.quantile(0.5);
        assert!(p50 >= 32.0 && p50 <= 128.0, "p50={p50}");
    }

    #[test]
    fn histogram_empty_quantile_is_nan() {
        let h = Histogram::exponential(1e-6, 21);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.quantile(0.99).is_nan());
        assert_eq!(h.sum(), 0.0);
    }

    #[test]
    fn histogram_cumulative_is_monotone_and_ends_at_total() {
        let mut h = Histogram::exponential(1.0, 4); // bounds 1,2,4,8
        for x in [0.5, 1.5, 3.0, 3.5, 100.0] {
            h.record(x);
        }
        let cum = h.cumulative();
        assert_eq!(cum.len(), h.bounds().len() + 1);
        let mut prev = 0;
        for &(b, c) in &cum {
            assert!(c >= prev, "cumulative counts must be monotone at le={b}");
            prev = c;
        }
        let (last_b, last_c) = cum[cum.len() - 1];
        assert!(last_b.is_infinite());
        assert_eq!(last_c, h.total());
        assert!((h.sum() - 108.5).abs() < 1e-12);
        // spot-check: two observations at or below 2.0 (0.5 and 1.5)
        assert_eq!(cum[1], (2.0, 2));
    }
}

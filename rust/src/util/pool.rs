//! Persistent worker pool.
//!
//! §Perf (L3): the engines originally used `std::thread::scope` per SpMV
//! call; spawning N threads costs ~100µs each, which dominated both the
//! SpMV and combine phases at small matrix sizes (quickstart showed a
//! 3.7ms combine for 30K slots — pure spawn overhead). The pool keeps
//! workers parked on a condvar and hands them one *generation* of work
//! at a time; the mixed fixed/competitive schedule of §III-C runs on top
//! unchanged (worker identity = pool index).

use super::Timer;
use crate::exec::scheduler::{MixedSchedule, WorkerStats};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Type-erased per-generation job.
struct Job {
    /// `work(worker_index)`; must be safe to call from many threads.
    work: *const (dyn Fn(usize, &mut WorkerStats) + Sync),
}
// SAFETY: the pointer is only dereferenced while `run_generation` blocks
// the submitting thread (the pointee outlives every worker's use).
unsafe impl Send for Job {}

struct Shared {
    job: Mutex<(u64, Option<Job>)>,
    job_cv: Condvar,
    /// (generation, workers done, per-worker stats, workers panicked).
    done: Mutex<(u64, usize, Vec<WorkerStats>, usize)>,
    done_cv: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-size persistent worker pool.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub workers: usize,
    /// Serializes concurrent `run_generation` callers: the process-wide
    /// [`shared_pool`]s are reachable from many threads at once (parallel
    /// HBP builds from tests/services), and a generation's job slot and
    /// done-counter are single-occupancy.
    submit: Mutex<()>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            job: Mutex::new((0, None)),
            job_cv: Condvar::new(),
            done: Mutex::new((0, 0, vec![WorkerStats::default(); workers], 0)),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("hbp-worker-{w}"))
                    .spawn(move || worker_loop(w, shared))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles, workers, submit: Mutex::new(()) }
    }

    /// Run `work(worker_index, stats)` once on every worker; blocks until
    /// all workers finish the generation. Returns per-worker stats.
    /// Concurrent callers are serialized (generations never overlap).
    /// A panic inside `work` is caught on the worker (which stays alive
    /// for later generations) and re-raised here on the submitter — the
    /// same propagation the old per-call `thread::scope` builders had.
    pub fn run_generation<F>(&self, work: F) -> Vec<WorkerStats>
    where
        F: Fn(usize, &mut WorkerStats) + Sync,
    {
        // tolerate poison: it only means a previous submitter re-raised a
        // worker panic; the guarded state is () and generations are
        // self-resetting, so there is nothing inconsistent to inherit.
        let _submit = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        let gen = {
            let mut job = self.shared.job.lock().unwrap_or_else(|e| e.into_inner());
            job.0 += 1;
            let local: &(dyn Fn(usize, &mut WorkerStats) + Sync) = &work;
            // SAFETY: we erase the closure's lifetime to the pointer's
            // implicit 'static bound; `work` outlives every worker's use
            // because we block on the done condvar below before returning.
            #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
            let erased: *const (dyn Fn(usize, &mut WorkerStats) + Sync) =
                unsafe { std::mem::transmute(local) };
            job.1 = Some(Job { work: erased });
            self.shared.job_cv.notify_all();
            job.0
        };
        let mut done = self.shared.done.lock().unwrap_or_else(|e| e.into_inner());
        while !(done.0 == gen && done.1 == self.workers) {
            done = self.shared.done_cv.wait(done).unwrap_or_else(|e| e.into_inner());
        }
        let (stats, panics) = (done.2.clone(), done.3);
        drop(done);
        assert!(panics == 0, "{panics} pool worker(s) panicked during generation {gen}");
        stats
    }

    /// Execute a mixed fixed/competitive schedule on the pool (the §III-C
    /// semantics of [`crate::exec::run_mixed`], without thread spawns).
    /// `sched.fixed.len()` must equal the pool size.
    pub fn run_mixed<F>(&self, sched: &MixedSchedule, work: F) -> Vec<WorkerStats>
    where
        F: Fn(usize) + Sync,
    {
        assert_eq!(sched.fixed.len(), self.workers, "schedule/pool size mismatch");
        let ticket = AtomicUsize::new(sched.fixed_end);
        self.run_generation(|w, stats| {
            let t = Timer::start();
            let (lo, hi) = sched.fixed[w];
            for i in lo..hi {
                work(i);
                stats.fixed_done += 1;
            }
            loop {
                let i = ticket.fetch_add(1, Ordering::Relaxed);
                if i >= sched.total {
                    break;
                }
                work(i);
                stats.competitive_done += 1;
            }
            stats.busy_secs = t.elapsed_secs();
        })
    }
}

/// Process-wide persistent pools keyed by worker count, for callers that
/// do not own a long-lived engine (the parallel HBP builder, tests, the
/// CLI). Created on first use, then parked between calls — repeated
/// builds at the same thread count reuse warm workers instead of paying
/// a per-call `thread::scope` spawn (§Perf: ~100µs per thread, which
/// dominated small-matrix preprocessing). The distinct sizes requested
/// by a process are few, so the registry stays tiny; pools live until
/// process exit.
pub fn shared_pool(workers: usize) -> Arc<WorkerPool> {
    static POOLS: OnceLock<Mutex<Vec<Arc<WorkerPool>>>> = OnceLock::new();
    let registry = POOLS.get_or_init(|| Mutex::new(Vec::new()));
    let mut pools = registry.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(p) = pools.iter().find(|p| p.workers == workers) {
        return Arc::clone(p);
    }
    let p = Arc::new(WorkerPool::new(workers));
    pools.push(Arc::clone(&p));
    p
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.job_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(w: usize, shared: Arc<Shared>) {
    let mut seen_gen = 0u64;
    loop {
        // wait for a new generation (or shutdown)
        let job_ptr = {
            let mut job = shared.job.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if job.0 > seen_gen {
                    seen_gen = job.0;
                    break job.1.as_ref().map(|j| j.work);
                }
                job = shared.job_cv.wait(job).unwrap_or_else(|e| e.into_inner());
            }
        };
        let mut stats = WorkerStats::default();
        let mut panicked = false;
        if let Some(ptr) = job_ptr {
            // SAFETY: run_generation blocks until we report done, so the
            // closure behind `ptr` is alive for the whole call.
            let work = unsafe { &*ptr };
            // catch panics so the generation still completes (no hang on
            // the done condvar) and the worker survives for later
            // generations; run_generation re-raises on the submitter.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                work(w, &mut stats);
            }));
            panicked = result.is_err();
        }
        // report completion
        let mut done = shared.done.lock().unwrap_or_else(|e| e.into_inner());
        if done.0 != seen_gen {
            done.0 = seen_gen;
            done.1 = 0;
            done.3 = 0;
        }
        done.2[w] = stats;
        done.1 += 1;
        if panicked {
            done.3 += 1;
        }
        if done.1 == done.2.len() {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::scheduler::mixed_schedule;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn generation_runs_every_worker_once() {
        let pool = WorkerPool::new(6);
        let hits: Vec<AtomicU32> = (0..6).map(|_| AtomicU32::new(0)).collect();
        for _ in 0..10 {
            pool.run_generation(|w, _| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
        }
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 10);
        }
    }

    #[test]
    fn mixed_on_pool_is_exactly_once() {
        let pool = WorkerPool::new(5);
        let total = 3000;
        let counts: Vec<AtomicU32> = (0..total).map(|_| AtomicU32::new(0)).collect();
        let sched = mixed_schedule(total, 5, 0.4);
        let stats = pool.run_mixed(&sched, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
        let done: usize = stats.iter().map(|s| s.fixed_done + s.competitive_done).sum();
        assert_eq!(done, total);
    }

    #[test]
    fn pool_reuse_is_cheap() {
        // 100 empty generations should be far faster than 100 x N spawns
        let pool = WorkerPool::new(8);
        pool.run_generation(|_, _| {}); // warm
        let t = Timer::start();
        for _ in 0..100 {
            pool.run_generation(|_, _| {});
        }
        let pool_time = t.elapsed_secs();
        let t = Timer::start();
        for _ in 0..100 {
            std::thread::scope(|s| {
                for _ in 0..8 {
                    s.spawn(|| {});
                }
            });
        }
        let spawn_time = t.elapsed_secs();
        assert!(
            pool_time < spawn_time,
            "pool {pool_time:.4}s should beat spawn {spawn_time:.4}s"
        );
    }

    #[test]
    fn concurrent_generations_serialize() {
        let pool = Arc::new(WorkerPool::new(3));
        let total = Arc::new(AtomicU32::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    for _ in 0..20 {
                        pool.run_generation(|_, _| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 3);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_generation(|w, _| {
                if w == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "worker panic must propagate to the submitter");
        // the pool must still serve later generations
        let stats = pool.run_generation(|_, _| {});
        assert_eq!(stats.len(), 2);
    }

    #[test]
    fn shared_pool_registry_reuses_instances() {
        let a = shared_pool(2);
        let b = shared_pool(2);
        assert!(Arc::ptr_eq(&a, &b), "same size must return the same pool");
        assert_eq!(a.workers, 2);
        let c = shared_pool(5);
        assert_eq!(c.workers, 5);
        assert!(!Arc::ptr_eq(&a, &c));
        a.run_generation(|_, _| {});
        c.run_generation(|_, _| {});
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = WorkerPool::new(3);
        pool.run_generation(|_, _| {});
        drop(pool); // must not hang
    }

    #[test]
    fn parallel_results_visible_after_return() {
        let pool = WorkerPool::new(4);
        let mut buf = vec![0usize; 4096];
        {
            let shared = crate::util::sync::SharedMut::new(&mut buf);
            pool.run_generation(|w, _| {
                let chunk = unsafe { shared.slice_mut(w * 1024, 1024) };
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = w * 1024 + i;
                }
            });
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i);
        }
    }
}

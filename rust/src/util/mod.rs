//! Support substrate: PRNG, statistics, timing, CLI parsing, bench harness
//! and a miniature property-testing framework.
//!
//! The build environment is fully offline (`anyhow` is a vendored shim in
//! `vendor/anyhow`; the `xla` bindings are stubbed behind a feature), so
//! everything that would normally come from `rand`, `clap`, `criterion` or
//! `proptest` is implemented here.

pub mod rng;
pub mod stats;
pub mod timer;
pub mod cli;
pub mod bench;
pub mod quickcheck;
pub mod sync;
pub mod json;
pub mod pool;

pub use rng::Rng;
pub use stats::Stats;
pub use timer::Timer;

//! Minimal JSON parser/serializer (serde is not in the offline cache).
//!
//! Parses the AOT `manifest.json` and carries the coordinator's TCP
//! request protocol. Supports the full JSON value grammar minus exotic
//! number forms; strings support the standard escapes.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field helpers with error context.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .with_context(|| format!("missing string field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .with_context(|| format!("missing numeric field {key:?}"))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // JSON has no NaN/Infinity literal — writing them
                // verbatim produces unparseable output (a fresh server's
                // stats reply used to do exactly that via empty-histogram
                // quantiles). Non-finite serializes as null.
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization; `value.to_string()` comes from this impl.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Build an object from pairs (protocol convenience).
pub fn obj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Build a numeric array from f64s.
pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// A number when finite, `null` otherwise — for values like histogram
/// quantiles that are legitimately undefined on an empty histogram
/// (`NaN`) or unbounded in the open top bucket (`+inf`). Using this at
/// construction keeps the JSON *value* honest (`Json::Null`, not a
/// `Num` that merely serializes as null), so parse round-trips and
/// doc-example matching see the same shape clients do.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() { Json::Num(x) } else { Json::Null }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().context("unexpected end of input")? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).context("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).context("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .context("short \\u escape")?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let chunk = self.b.get(start..self.i).context("truncated utf8")?;
                    s.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse().with_context(|| format!("bad number {s:?}"))?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrips() {
        let texts = [
            r#"{"a":[1,2,3],"b":"hi","c":true,"d":null,"e":{"f":0.5}}"#,
            r#"[[],{},""]"#,
        ];
        for t in texts {
            let v = Json::parse(t).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café — ünïcode""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ünïcode");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(bad).to_string(), "null");
        }
        // and the round trip parses back as Null, not an error
        let v = obj(&[("p99", Json::Num(f64::NAN)), ("n", Json::Num(0.0))]);
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back.get("p99"), Some(&Json::Null));
        assert_eq!(back.get("n"), Some(&Json::Num(0.0)));
        // the constructor-side helper produces Null directly
        assert_eq!(num_or_null(f64::NAN), Json::Null);
        assert_eq!(num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(num_or_null(1.5), Json::Num(1.5));
    }

    #[test]
    fn req_helpers() {
        let v = Json::parse(r#"{"name":"x","n":5}"#).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "x");
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert!(v.req_str("missing").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"groups":16,"warp":32,"seg":4096,
          "executables":[{"name":"spmv_g16_l4_w32_s4096","kind":"spmv",
            "groups":16,"lmax":4,"warp":32,"seg":4096,
            "vmem_bytes_per_step":17536,"file":"spmv_g16_l4_w32_s4096.hlo.txt",
            "sha256":"abcd"}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req_usize("seg").unwrap(), 4096);
        let execs = v.get("executables").unwrap().as_arr().unwrap();
        assert_eq!(execs[0].req_str("kind").unwrap(), "spmv");
    }
}

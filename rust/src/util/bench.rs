//! Miniature benchmark harness (criterion is not in the offline cache).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`Bench`] to run warmups + timed iterations and print a column-aligned
//! table, mirroring the rows/series of the corresponding paper figure.

use super::stats::{percentile, Stats};
use super::timer::Timer;

/// Result of benchmarking one case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        Stats::of(&self.samples).mean
    }
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }
    pub fn std(&self) -> f64 {
        Stats::of(&self.samples).std
    }
    pub fn min(&self) -> f64 {
        Stats::of(&self.samples).min
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Minimum total measured time; iterations extend until reached.
    pub min_time_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, iters: 5, min_time_secs: 0.2 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, iters: 3, min_time_secs: 0.05 }
    }

    /// Honors `HBP_BENCH_FAST=1` for CI smoke runs.
    pub fn from_env() -> Self {
        if std::env::var("HBP_BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
            Bench::quick()
        } else {
            Bench::default()
        }
    }

    /// Run `f` repeatedly, returning per-iteration timings. A `black_box`
    /// on the closure result prevents the optimizer from deleting work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let total = Timer::start();
        let mut i = 0;
        while i < self.iters || total.elapsed_secs() < self.min_time_secs {
            let t = Timer::start();
            std::hint::black_box(f());
            samples.push(t.elapsed_secs());
            i += 1;
            if i > 10_000 {
                break; // safety valve for ~ns-scale closures
            }
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Column-aligned table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        print!("{self}");
    }
}

/// Column-aligned rendering; `table.to_string()` comes from this impl.
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        f.write_str(&out)
    }
}

/// Print a standard bench header so every figure bench output is
/// self-describing in `bench_output.txt`.
pub fn banner(figure: &str, description: &str) {
    println!();
    println!("=== {figure} ===");
    println!("{description}");
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bench { warmup_iters: 1, iters: 3, min_time_secs: 0.0 };
        let r = b.run("noop", || 1 + 1);
        assert!(r.samples.len() >= 3);
        assert!(r.mean() >= 0.0);
        assert!(r.median() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2".into()]);
        let s = t.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}

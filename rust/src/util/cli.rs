//! Minimal command-line argument parser (no `clap` in the offline cache).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; typed getters with defaults; and generated usage text.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (not including argv[0]).
    /// `known_flags` lists boolean options that do not consume a value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.opts.insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.opts.insert(stripped.to_string(), v);
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments after the subcommand position.
    pub fn from_env(skip: usize, known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(skip), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse(argv("--rows 100 --cols=200 file.mtx"), &[]);
        assert_eq!(a.usize_or("rows", 0), 100);
        assert_eq!(a.usize_or("cols", 0), 200);
        assert_eq!(a.positional(), &["file.mtx".to_string()]);
    }

    #[test]
    fn parses_known_flags() {
        let a = Args::parse(argv("--verbose --rows 5"), &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("rows", 0), 5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(argv("--rows 5 --check"), &[]);
        assert!(a.flag("check"));
    }

    #[test]
    fn adjacent_flags() {
        let a = Args::parse(argv("--check --verify --rows 3"), &[]);
        assert!(a.flag("check"));
        assert!(a.flag("verify"));
        assert_eq!(a.usize_or("rows", 0), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(argv(""), &[]);
        assert_eq!(a.str_or("name", "x"), "x");
        assert_eq!(a.f64_or("p", 0.5), 0.5);
        assert_eq!(a.u64_or("seed", 42), 42);
    }
}

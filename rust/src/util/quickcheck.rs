//! Miniature property-based testing harness (proptest is not in the
//! offline cache).
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with sized
//! generators). [`check`] runs it for N seeds and reports the first
//! failing seed; failures are reproducible by construction because every
//! random choice derives from the case seed.

use super::rng::Rng;

/// Sized random-value generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Size hint: properties should scale their structures by this.
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            lo
        } else {
            self.rng.range(lo, hi)
        }
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Vector of f64 values with sized length in `[0, max_len]`.
    pub fn vec_f64(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(0, max_len + 1);
        (0..n).map(|_| self.rng.range_f64(lo, hi)).collect()
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut v);
        v
    }
}

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Convenience: assert within a property, returning `Err` with a message.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Run `prop` over `cases` generated cases with growing size. Panics with
/// the failing seed + message on the first failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 2 + case * 97 / cases.max(1); // grow roughly to ~100
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed on case {case}/{cases} (seed={seed:#x}, size={size}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("reverse-twice", 50, |g| {
            let v = g.vec_f64(g.size, -1.0, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "reverse twice changed the vec");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails-on-big", 50, |g| {
            prop_assert!(g.size < 10, "size {} too big", g.size);
            Ok(())
        });
    }

    #[test]
    fn permutation_is_valid() {
        check("perm", 30, |g| {
            let n = g.usize_in(0, g.size + 1);
            let p = g.permutation(n);
            let mut sorted = p.clone();
            sorted.sort_unstable();
            prop_assert!(sorted == (0..n).collect::<Vec<_>>(), "not a permutation");
            Ok(())
        });
    }
}

//! Wall-clock timing helpers used by the bench harness and the
//! preprocessing/SpMV measurements (Figs 7-10).

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_secs())
}

/// Human-friendly duration formatting for bench tables.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// GFLOPS for SpMV per the paper: `G = 2*nnz / t`.
pub fn spmv_gflops(nnz: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::INFINITY;
    }
    2.0 * nnz as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }

    #[test]
    fn time_returns_value() {
        let (v, secs) = time(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with(" s"));
    }

    #[test]
    fn gflops_formula() {
        // 1e9 nnz in 2 seconds => 2*1e9/2/1e9 = 1 GFLOPS
        assert!((spmv_gflops(1_000_000_000, 2.0) - 1.0).abs() < 1e-12);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64` — the standard construction
//! for reproducible simulation workloads. All matrix generators and
//! property tests take an explicit seed so every figure in EXPERIMENTS.md
//! is exactly reproducible.

/// A deterministic xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is fine here; bias
        // at n << 2^64 is negligible for simulation purposes, but we use
        // 128-bit multiply to keep it uniform-enough and fast.
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Sample from a (truncated) power-law on `[1, max]` with exponent
    /// `alpha > 1`: `P(x) ~ x^-alpha`. Used by the circuit-style matrix
    /// generators to reproduce UF-collection row-degree tails.
    pub fn power_law(&mut self, alpha: f64, max: usize) -> usize {
        debug_assert!(alpha > 1.0 && max >= 1);
        let a1 = 1.0 - alpha;
        let max_f = max as f64;
        // inverse-CDF sampling of the continuous law, then floor.
        let u = self.f64();
        let x = ((max_f.powf(a1) - 1.0) * u + 1.0).powf(1.0 / a1);
        (x.floor() as usize).clamp(1, max)
    }

    /// Geometric-ish exponential sample with mean `mean`, clamped to
    /// `[min, max]`.
    pub fn exponential(&mut self, mean: f64, min: usize, max: usize) -> usize {
        let u = self.f64().max(1e-300);
        let x = -mean * u.ln();
        (x.round() as usize).clamp(min, max)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (k <= n), unordered.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        if k * 4 >= n {
            // dense case: shuffle prefix
            let mut all: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = self.range(i, n);
                all.swap(i, j);
            }
            all.truncate(k);
            all
        } else {
            // sparse case: rejection with a sorted probe set
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }

    /// Fork a statistically independent child generator (for parallel
    /// deterministic generation).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(splitmix64(&mut sm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            hits[r.below(10)] += 1;
        }
        for h in hits {
            assert!(h > 700, "bucket underpopulated: {h}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn power_law_bounds_and_skew() {
        let mut r = Rng::new(13);
        let xs: Vec<usize> = (0..20_000).map(|_| r.power_law(2.2, 1000)).collect();
        assert!(xs.iter().all(|&x| (1..=1000).contains(&x)));
        let ones = xs.iter().filter(|&&x| x == 1).count();
        // heavy head: majority of mass at small values
        assert!(ones > xs.len() / 3, "ones={ones}");
        assert!(xs.iter().any(|&x| x > 50), "no tail present");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, k) in &[(10usize, 10usize), (1000, 10), (50, 40)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(3);
        let mut c1 = base.fork(0);
        let mut c2 = base.fork(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}

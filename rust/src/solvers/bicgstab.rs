//! BiCGSTAB for general (nonsymmetric) systems — circuit matrices
//! (ASIC/rajat profiles) are nonsymmetric, so CG does not apply to them;
//! this is the solver a circuit-simulation user would actually run on
//! top of HBP SpMV.

use super::{axpy, dot, norm2, SolveStats};
use crate::exec::SpmvEngine;
use crate::util::Timer;

/// Solve `A x = b` by BiCGSTAB. `x` holds the initial guess on entry and
/// the solution on exit.
pub fn bicgstab(
    a: &dyn SpmvEngine,
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iter: usize,
) -> SolveStats {
    let n = b.len();
    assert_eq!(a.rows(), n);
    assert_eq!(a.cols(), n, "BiCGSTAB needs a square system");
    assert_eq!(x.len(), n);

    let mut spmv_secs = 0.0;
    let mut spmv = |v: &[f64], out: &mut [f64]| {
        let t = Timer::start();
        a.spmv(v, out);
        spmv_secs += t.elapsed_secs();
    };

    let b_norm = norm2(b).max(1e-300);
    let mut av = vec![0.0; n];
    spmv(x, &mut av);
    let mut r: Vec<f64> = b.iter().zip(&av).map(|(bi, ai)| bi - ai).collect();
    let r0 = r.clone();
    let mut p = r.clone();
    let mut v = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t_vec = vec![0.0; n];
    let mut rho = dot(&r0, &r);

    for it in 0..max_iter {
        let resid = norm2(&r) / b_norm;
        if resid < tol {
            return SolveStats { iterations: it, residual: resid, converged: true, spmv_secs };
        }
        spmv(&p, &mut v);
        let alpha = rho / dot(&r0, &v).max(f64::MIN_POSITIVE).copysign(dot(&r0, &v));
        s.copy_from_slice(&r);
        axpy(-alpha, &v, &mut s);
        if norm2(&s) / b_norm < tol {
            axpy(alpha, &p, x);
            return SolveStats {
                iterations: it + 1,
                residual: norm2(&s) / b_norm,
                converged: true,
                spmv_secs,
            };
        }
        spmv(&s, &mut t_vec);
        let tt = dot(&t_vec, &t_vec).max(f64::MIN_POSITIVE);
        let omega = dot(&t_vec, &s) / tt;
        axpy(alpha, &p, x);
        axpy(omega, &s, x);
        r.copy_from_slice(&s);
        axpy(-omega, &t_vec, &mut r);

        let rho_new = dot(&r0, &r);
        let beta = (rho_new / rho) * (alpha / omega);
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        if rho.abs() < 1e-300 || !rho.is_finite() {
            break; // breakdown
        }
    }
    let resid = norm2(&r) / b_norm;
    SolveStats { iterations: max_iter, residual: resid, converged: resid < tol, spmv_secs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{CsrSerial, HbpEngine};
    use crate::partition::PartitionConfig;
    use crate::preprocess::build_hbp;

    /// Diagonally dominant nonsymmetric matrix (circuit-flavoured).
    fn nonsym(n: usize, seed: u64) -> crate::formats::Csr {
        let base = crate::gen::circuit::circuit(&crate::gen::circuit::CircuitConfig {
            n,
            mean_row_nnz: 3.0,
            max_row_nnz: 10,
            locality: 16,
            long_range_frac: 0.05,
            hub_rows: 1,
            hub_divisor: 8,
            hub_cols: false,
            seed,
        });
        // boost the diagonal for guaranteed convergence
        let mut coo = base.to_coo();
        for r in 0..n {
            let (_, vals) = base.row(r);
            let rowsum: f64 = vals.iter().map(|v| v.abs()).sum();
            coo.push(r, r, rowsum + 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn solves_nonsymmetric_system() {
        let m = nonsym(150, 3);
        let eng = CsrSerial::new(m.clone());
        let expect: Vec<f64> = (0..150).map(|i| ((i * 7) % 11) as f64 / 11.0).collect();
        let mut b = vec![0.0; 150];
        m.spmv(&expect, &mut b);
        let mut x = vec![0.0; 150];
        let stats = bicgstab(&eng, &b, &mut x, 1e-10, 500);
        assert!(stats.converged, "residual {}", stats.residual);
        for (xi, ei) in x.iter().zip(&expect) {
            assert!((xi - ei).abs() < 1e-6, "{xi} vs {ei}");
        }
    }

    #[test]
    fn hbp_engine_matches_csr_solution() {
        let m = nonsym(200, 9);
        let hbp = HbpEngine::new(build_hbp(&m, PartitionConfig::test_small()), 3, 0.25);
        let csr = CsrSerial::new(m.clone());
        let b = vec![1.0; 200];
        let mut x1 = vec![0.0; 200];
        let mut x2 = vec![0.0; 200];
        let s1 = bicgstab(&hbp, &b, &mut x1, 1e-9, 1000);
        let s2 = bicgstab(&csr, &b, &mut x2, 1e-9, 1000);
        assert!(s1.converged && s2.converged);
        // verify both solve the system (paths may differ in rounding)
        let mut ax = vec![0.0; 200];
        m.spmv(&x1, &mut ax);
        let resid: f64 = ax.iter().zip(&b).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(resid < 1e-6, "hbp solution residual {resid}");
    }
}

//! Iterative solvers on top of the SpMV engines.
//!
//! The paper's introduction motivates SpMV through "mathematical
//! solutions for sparse linear equations", "iterative algorithm-solving"
//! and "graph processing" — this module is that downstream API: solvers
//! are generic over [`crate::exec::SpmvEngine`], so the HBP engine (or
//! any baseline) plugs in unchanged, and the preprocessing cost
//! amortizes over the iteration count.

pub mod cg;
pub mod bicgstab;
pub mod power;

pub use bicgstab::bicgstab;
pub use cg::cg;
pub use power::{pagerank, power_iteration};

/// Convergence report shared by the solvers.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    pub iterations: usize,
    /// Final relative residual (solvers) or iterate delta (power).
    pub residual: f64,
    pub converged: bool,
    /// Seconds spent inside SpMV calls.
    pub spmv_secs: f64,
}

pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub(crate) fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x
pub(crate) fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

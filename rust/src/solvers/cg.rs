//! Conjugate gradient for symmetric positive-definite systems.

use super::{axpy, dot, norm2, SolveStats};
use crate::exec::SpmvEngine;
use crate::util::Timer;

/// Solve `A x = b` by CG. `x` holds the initial guess on entry and the
/// solution on exit. A must be SPD (not checked).
pub fn cg(
    a: &dyn SpmvEngine,
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iter: usize,
) -> SolveStats {
    let n = b.len();
    assert_eq!(a.rows(), n);
    assert_eq!(a.cols(), n, "CG needs a square system");
    assert_eq!(x.len(), n);

    let mut spmv_secs = 0.0;
    let mut ap = vec![0.0; n];

    // r = b - A x0
    let t = Timer::start();
    a.spmv(x, &mut ap);
    spmv_secs += t.elapsed_secs();
    let mut r: Vec<f64> = b.iter().zip(&ap).map(|(bi, ai)| bi - ai).collect();
    let mut p = r.clone();
    let mut rs = dot(&r, &r);
    let b_norm = norm2(b).max(1e-300);

    for it in 0..max_iter {
        if rs.sqrt() / b_norm < tol {
            return SolveStats {
                iterations: it,
                residual: rs.sqrt() / b_norm,
                converged: true,
                spmv_secs,
            };
        }
        let t = Timer::start();
        a.spmv(&p, &mut ap);
        spmv_secs += t.elapsed_secs();
        let alpha = rs / dot(&p, &ap).max(f64::MIN_POSITIVE);
        axpy(alpha, &p, x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        for (pi, ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs = rs_new;
    }
    SolveStats {
        iterations: max_iter,
        residual: rs.sqrt() / b_norm,
        converged: rs.sqrt() / b_norm < tol,
        spmv_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{CsrSerial, HbpEngine};
    use crate::formats::Coo;
    use crate::partition::PartitionConfig;
    use crate::preprocess::build_hbp;

    fn laplacian_1d(n: usize) -> crate::formats::Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 2.0);
            if i > 0 {
                coo.push(i, i - 1, -1.0);
            }
            if i + 1 < n {
                coo.push(i, i + 1, -1.0);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn solves_laplacian_exactly() {
        let m = laplacian_1d(64);
        let eng = CsrSerial::new(m.clone());
        let expect: Vec<f64> = (0..64).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut b = vec![0.0; 64];
        m.spmv(&expect, &mut b);
        let mut x = vec![0.0; 64];
        let stats = cg(&eng, &b, &mut x, 1e-12, 1000);
        assert!(stats.converged, "residual {}", stats.residual);
        for (xi, ei) in x.iter().zip(&expect) {
            assert!((xi - ei).abs() < 1e-8);
        }
        assert!(stats.spmv_secs > 0.0);
    }

    #[test]
    fn hbp_engine_converges_identically() {
        let m = laplacian_1d(200);
        let hbp = HbpEngine::new(build_hbp(&m, PartitionConfig::test_small()), 2, 0.25);
        let csr = CsrSerial::new(m.clone());
        let b = vec![1.0; 200];
        let mut x1 = vec![0.0; 200];
        let mut x2 = vec![0.0; 200];
        let s1 = cg(&hbp, &b, &mut x1, 1e-10, 2000);
        let s2 = cg(&csr, &b, &mut x2, 1e-10, 2000);
        assert!(s1.converged && s2.converged);
        assert_eq!(s1.iterations, s2.iterations, "engines changed convergence");
        for (a, b) in x1.iter().zip(&x2) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn warm_start_converges_immediately() {
        let m = laplacian_1d(32);
        let eng = CsrSerial::new(m.clone());
        let expect = vec![1.0; 32];
        let mut b = vec![0.0; 32];
        m.spmv(&expect, &mut b);
        let mut x = expect.clone(); // exact initial guess
        let stats = cg(&eng, &b, &mut x, 1e-10, 100);
        assert_eq!(stats.iterations, 0);
        assert!(stats.converged);
    }

    #[test]
    fn reports_nonconvergence() {
        let m = laplacian_1d(512);
        let eng = CsrSerial::new(m);
        let b = vec![1.0; 512];
        let mut x = vec![0.0; 512];
        let stats = cg(&eng, &b, &mut x, 1e-14, 3);
        assert!(!stats.converged);
        assert_eq!(stats.iterations, 3);
    }
}

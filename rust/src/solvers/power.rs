//! Power iteration + PageRank — the graph-processing workload.

use super::{norm2, SolveStats};
use crate::exec::SpmvEngine;
use crate::formats::Csr;
use crate::util::Timer;

/// Dominant eigenvector by power iteration (L2-normalized). Returns the
/// eigenvalue estimate alongside the stats; `x` holds the start vector
/// on entry (all-ones works for connected non-negative matrices).
pub fn power_iteration(
    a: &dyn SpmvEngine,
    x: &mut [f64],
    tol: f64,
    max_iter: usize,
) -> (f64, SolveStats) {
    let n = x.len();
    assert_eq!(a.rows(), n);
    assert_eq!(a.cols(), n);
    let mut next = vec![0.0; n];
    let mut spmv_secs = 0.0;
    let mut lambda = 0.0;

    let norm = norm2(x).max(1e-300);
    for xi in x.iter_mut() {
        *xi /= norm;
    }
    for it in 0..max_iter {
        let t = Timer::start();
        a.spmv(x, &mut next);
        spmv_secs += t.elapsed_secs();
        lambda = norm2(&next);
        if lambda < 1e-300 {
            return (0.0, SolveStats { iterations: it, residual: 0.0, converged: true, spmv_secs });
        }
        let mut delta = 0.0f64;
        for (xi, ni) in x.iter_mut().zip(&next) {
            let v = ni / lambda;
            delta = delta.max((v - *xi).abs());
            *xi = v;
        }
        if delta < tol {
            return (
                lambda,
                SolveStats { iterations: it + 1, residual: delta, converged: true, spmv_secs },
            );
        }
    }
    (lambda, SolveStats { iterations: max_iter, residual: f64::NAN, converged: false, spmv_secs })
}

/// Column-normalize an adjacency matrix for PageRank.
pub fn column_stochastic(m: &Csr) -> Csr {
    let mut outdeg = vec![0.0f64; m.cols];
    for &c in &m.col {
        outdeg[c as usize] += 1.0;
    }
    let mut out = m.clone();
    for k in 0..out.nnz() {
        out.data[k] = 1.0 / outdeg[out.col[k] as usize].max(1.0);
    }
    out
}

/// PageRank by power iteration with damping; `engine` must wrap a
/// column-stochastic matrix (see [`column_stochastic`]). Returns the
/// rank vector (L1-normalized).
pub fn pagerank(
    engine: &dyn SpmvEngine,
    damping: f64,
    tol: f64,
    max_iter: usize,
) -> (Vec<f64>, SolveStats) {
    let n = engine.rows();
    let mut rank = vec![1.0 / n as f64; n];
    let mut next = vec![0.0; n];
    let mut spmv_secs = 0.0;
    for it in 0..max_iter {
        let t = Timer::start();
        engine.spmv(&rank, &mut next);
        spmv_secs += t.elapsed_secs();
        let teleport = (1.0 - damping) / n as f64;
        for v in next.iter_mut() {
            *v = damping * *v + teleport;
        }
        let sum: f64 = next.iter().sum();
        let mut delta = 0.0f64;
        for (r, v) in rank.iter_mut().zip(next.iter()) {
            let nv = v / sum;
            delta += (nv - *r).abs();
            *r = nv;
        }
        if delta < tol {
            return (
                rank,
                SolveStats { iterations: it + 1, residual: delta, converged: true, spmv_secs },
            );
        }
    }
    (rank, SolveStats { iterations: max_iter, residual: f64::NAN, converged: false, spmv_secs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::CsrSerial;
    use crate::formats::Coo;

    #[test]
    fn power_finds_dominant_eigenpair() {
        // diag(3, 1): dominant eigenvalue 3, eigenvector e0
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 3.0);
        coo.push(1, 1, 1.0);
        let eng = CsrSerial::new(coo.to_csr());
        let mut x = vec![1.0, 1.0];
        let (lambda, stats) = power_iteration(&eng, &mut x, 1e-12, 500);
        assert!(stats.converged);
        assert!((lambda - 3.0).abs() < 1e-9, "lambda={lambda}");
        assert!(x[0].abs() > 0.999 && x[1].abs() < 1e-5);
    }

    #[test]
    fn pagerank_ranks_hub_highest() {
        // star graph: all vertices link to 0
        let n = 20;
        let mut coo = Coo::new(n, n);
        for v in 1..n {
            coo.push(0, v, 1.0); // column v links to row 0
            coo.push(v, 0, 1.0); // hub links back (makes it ergodic)
        }
        let m = column_stochastic(&coo.to_csr());
        let eng = CsrSerial::new(m);
        let (rank, stats) = pagerank(&eng, 0.85, 1e-12, 1000);
        assert!(stats.converged);
        let hub = rank[0];
        assert!(rank[1..].iter().all(|&r| r < hub), "hub not top-ranked");
        assert!((rank.iter().sum::<f64>() - 1.0).abs() < 1e-9, "not a distribution");
    }

    #[test]
    fn pagerank_on_kron_profile_engines_agree() {
        let (_, adj) = crate::gen::matrix_by_id("m4", crate::gen::Scale::Ci).unwrap();
        let m = column_stochastic(&adj);
        let csr = CsrSerial::new(m.clone());
        let hbp = crate::exec::HbpEngine::new(
            crate::preprocess::build_hbp(&m, crate::partition::PartitionConfig::default()),
            4,
            0.25,
        );
        let (r1, s1) = pagerank(&csr, 0.85, 1e-10, 300);
        let (r2, s2) = pagerank(&hbp, 0.85, 1e-10, 300);
        assert!(s1.converged && s2.converged);
        assert!(crate::formats::dense::allclose(&r1, &r2, 1e-8, 1e-12));
    }
}

//! `hbp` — the command-line entry point.
//!
//! Subcommands:
//! - `gen`        — generate a suite matrix (or all) to MatrixMarket/binary
//! - `info`       — print matrix structure statistics
//! - `preprocess` — time the preprocessing strategies on a matrix (Fig. 7 style)
//! - `update`     — time incremental delta-repair vs a full HBP rebuild
//! - `spmv`       — run SpMV with a chosen engine, verify vs CSR, report GFLOPS
//! - `tune`       — autotune: features, ranked candidates, trial winner
//! - `sim`        — run the GPU cost model (Orin / RTX 4090)
//! - `serve`      — start the TCP serving coordinator (`--batch-stats`
//!   periodically prints a structured stats line via the telemetry
//!   reporter; `--max-queue`, `--deadline-ms`, and `--max-conns` bound
//!   admission; `--trace-capacity` sizes the per-shard trace ring and
//!   `--slow-ms` arms the slow-request log; the `HBP_FAULTS` env var
//!   arms fault-injection probes for degradation rehearsal)
//! - `stats`      — query a running server: `--format json` prints the
//!   `stats` reply, `--format prom` prints the Prometheus text
//!   exposition from the `metrics` op
//!
//! Matrices are named either by suite id (`m1`..`m14`, Table I) or by a
//! path to a `.mtx` / `.bin` file. The tuning cache defaults to
//! `$HBP_TUNE_CACHE` (or the system temp dir); `--cache <path>`
//! overrides it and `--no-cache` disables persistence.

use anyhow::{bail, Context, Result};
use hbp_spmv::coordinator::{BatcherConfig, Client, Coordinator, Router};
use hbp_spmv::exec::{CsrParallel, HbpEngine, SpmvEngine, Spmv2dEngine};
use hbp_spmv::formats::Csr;
use hbp_spmv::gen::{matrix_by_id, suite, Scale};
use hbp_spmv::partition::PartitionConfig;
use hbp_spmv::preprocess::{
    build_hbp_parallel, DpReorder, HashReorder, IdentityReorder, Reorder, SortReorder,
};
use hbp_spmv::sim::{simulate_csr, simulate_hbp, simulate_spmv2d, DeviceConfig};
use hbp_spmv::tune::Tuner;
use hbp_spmv::util::bench::Table;
use hbp_spmv::util::cli::Args;
use hbp_spmv::util::json::{obj, Json};
use hbp_spmv::util::timer::{fmt_duration, time};
use hbp_spmv::util::Stats;

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(String::as_str).unwrap_or("help");
    let args =
        Args::from_env(2, &["verify", "all", "parallel", "no-cache", "batch-stats", "profile"]);
    let result = match cmd {
        "gen" => cmd_gen(&args),
        "info" => cmd_info(&args),
        "preprocess" => cmd_preprocess(&args),
        "update" => cmd_update(&args),
        "spmv" => cmd_spmv(&args),
        "tune" => cmd_tune(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown subcommand {other:?}"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "hbp — Nonlinear hash-based partition SpMV (paper reproduction)

USAGE: hbp <subcommand> [options]

SUBCOMMANDS
  gen        --matrix m4 --scale ci|small|full [--out file.mtx|file.bin] [--all]
  info       --matrix <id|path> [--scale ci] [--threads N] [--profile]
  preprocess --matrix <id|path> [--scale ci] [--threads N]
  update     --matrix <id|path> [--scale ci] [--frac 0.01] [--iters 3] [--threads N]
  spmv       --matrix <id|path> [--engine auto|hbp|csr|2d|nnz-split|flat|line-enhance] [--iters 10]
             [--batch k] [--verify]
  tune       --matrix <id|path> [--scale ci] [--threads N] [--top-k 3] [--iters 5]
             [--cache path] [--no-cache]
  sim        --matrix <id|path> [--device orin|rtx4090]
  serve      --addr 127.0.0.1:7700 --matrices m1,m3 [--scale ci] [--cache path] [--no-cache]
             [--batch-stats] [--max-queue N] [--deadline-ms MS] [--max-conns N] [--shards N]
             [--trace-capacity N] [--slow-ms MS]
  stats      --addr 127.0.0.1:7700 [--format json|prom]"
    );
}

/// Resolve a matrix argument: suite id or file path.
fn load_matrix(args: &Args) -> Result<(String, Csr)> {
    let name = args
        .get("matrix")
        .context("--matrix <id|path> is required")?
        .to_string();
    let scale = Scale::parse(args.str_or("scale", "ci")).context("bad --scale")?;
    if let Some((meta, m)) = matrix_by_id(&name, scale) {
        return Ok((format!("{} ({})", meta.id, meta.name), m));
    }
    let path = std::path::Path::new(&name);
    let m = if path.extension().map(|e| e == "bin").unwrap_or(false) {
        hbp_spmv::io::read_bin(path)?
    } else {
        hbp_spmv::io::read_matrix_market(path)?.to_csr()
    };
    Ok((name, m))
}

fn threads(args: &Args) -> usize {
    args.usize_or(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )
}

/// Tuning-cache location: `--cache <path>` wins, then `$HBP_TUNE_CACHE`,
/// then a per-user file in the system temp dir (the username is in the
/// file name so users on a shared machine don't fight over one cache —
/// decisions are context-keyed anyway, but the file itself is
/// owner-writable only).
fn tune_cache_path(args: &Args) -> std::path::PathBuf {
    if let Some(p) = args.get("cache") {
        return p.into();
    }
    if let Some(p) = std::env::var_os("HBP_TUNE_CACHE") {
        return p.into();
    }
    let user = std::env::var("USER").unwrap_or_else(|_| "default".to_string());
    std::env::temp_dir().join(format!("hbp-tune-{user}.cache"))
}

/// The CLI's tuner: persistent unless `--no-cache`. Trial-budget knobs
/// are applied by `cmd_tune` only — `hbp spmv --engine auto` keeps the
/// default budget so its own `--iters` (benchmark iterations) flag
/// doesn't silently change how long the tuner measures.
fn make_tuner(args: &Args, cfg: PartitionConfig, nthreads: usize) -> Tuner {
    if args.flag("no-cache") {
        Tuner::new(cfg, nthreads)
    } else {
        Tuner::with_cache(cfg, nthreads, tune_cache_path(args))
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    let scale = Scale::parse(args.str_or("scale", "ci")).context("bad --scale")?;
    let ids: Vec<&str> = if args.flag("all") {
        suite().iter().map(|e| e.id).collect()
    } else {
        vec![args.get("matrix").context("--matrix or --all required")?]
    };
    for id in ids {
        let (meta, m) = matrix_by_id(id, scale).with_context(|| format!("unknown id {id}"))?;
        let out = args
            .get("out")
            .map(String::from)
            .unwrap_or_else(|| format!("{}.bin", meta.id));
        if out.ends_with(".mtx") {
            hbp_spmv::io::write_matrix_market(&out, &m.to_coo())?;
        } else {
            hbp_spmv::io::write_bin(&out, &m)?;
        }
        println!(
            "{}: {} ({}x{}, {} nnz) -> {out}",
            meta.id,
            meta.name,
            m.rows,
            m.cols,
            m.nnz()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let lens = m.row_lengths();
    let s = Stats::of_usize(&lens);
    let zeros = lens.iter().filter(|&&l| l == 0).count();
    println!("matrix     {name}");
    println!("shape      {} x {}", m.rows, m.cols);
    println!("nnz        {}", m.nnz());
    println!(
        "row nnz    mean {:.2}  std {:.2}  max {}",
        s.mean, s.std, s.max as usize
    );
    println!("zero rows  {zeros}");
    println!("density    {:.3e}", m.info().density());
    let cfg = PartitionConfig::default();
    let nthreads = threads(args);
    let (hbp, serial_secs) = time(|| hbp_spmv::preprocess::build_hbp(&m, cfg));
    println!(
        "2D blocks  {} non-empty (grid {} x {})",
        hbp.blocks.len(),
        hbp.grid.row_blocks,
        hbp.grid.col_blocks
    );
    println!("hbp bytes  {} (storage_bytes)", hbp.storage_bytes());
    // warm-up: the first parallel build pays the one-time shared-pool
    // worker spawn, which would skew a single timed call on small inputs
    let _ = build_hbp_parallel(&m, cfg, &HashReorder::default(), nthreads);
    let (_, par_secs) = time(|| build_hbp_parallel(&m, cfg, &HashReorder::default(), nthreads));
    println!(
        "hbp build  serial {}  |  {nthreads} threads {}  ({:.2}x)",
        fmt_duration(serial_secs),
        fmt_duration(par_secs),
        serial_secs / par_secs.max(1e-12)
    );
    if args.flag("profile") {
        // phase decomposition of the parallel build: where the
        // preprocessing wall-time actually goes (plan vs hash-reorder
        // vs block fill; the residue is thread fork/join overhead)
        let (_, p) =
            hbp_spmv::preprocess::build_hbp_profiled(&m, cfg, &HashReorder::default(), nthreads);
        let pct = |x: f64| 100.0 * x / p.total_secs.max(1e-12);
        println!(
            "profile    plan    {:>10}  ({:.1}%)",
            fmt_duration(p.plan_secs),
            pct(p.plan_secs)
        );
        println!(
            "           reorder {:>10}  ({:.1}%)",
            fmt_duration(p.reorder_secs),
            pct(p.reorder_secs)
        );
        println!(
            "           fill    {:>10}  ({:.1}%)",
            fmt_duration(p.fill_secs),
            pct(p.fill_secs)
        );
        println!("           total   {:>10}", fmt_duration(p.total_secs));
    }
    Ok(())
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let nthreads = threads(args);
    let cfg = PartitionConfig::default();
    println!("preprocessing {name} with {nthreads} threads\n");
    let strategies: Vec<Box<dyn Reorder + Sync>> = vec![
        Box::new(HashReorder::default()),
        Box::new(SortReorder),
        Box::new(DpReorder::default()),
        Box::new(IdentityReorder),
    ];
    let mut base = None;
    for s in &strategies {
        let (hbp, secs) = time(|| build_hbp_parallel(&m, cfg, s.as_ref(), nthreads));
        let ratio = match base {
            None => {
                base = Some(secs);
                1.0
            }
            Some(b) => secs / b,
        };
        println!(
            "{:8} {:>12}   {:.2}x vs hbp   ({} blocks)",
            s.name(),
            fmt_duration(secs),
            ratio,
            hbp.blocks.len()
        );
    }
    Ok(())
}

/// `hbp update`: demonstrate the incremental-rebuild path — scale a
/// fraction of the rows, repair only the touched blocks, and compare
/// against the full plan/fill rebuild the same change would otherwise
/// cost.
fn cmd_update(args: &Args) -> Result<()> {
    let (name, mut m) = load_matrix(args)?;
    let nthreads = threads(args);
    let frac = args.f64_or("frac", 0.01);
    let iters = args.usize_or("iters", 3).max(1);
    let cfg = PartitionConfig::default();
    let reorder = HashReorder::default();

    let (built, build_secs) =
        time(|| hbp_spmv::preprocess::build_hbp_updatable(&m, cfg, &reorder, nthreads));
    let (mut hbp, map) = built;
    let nonzero_rows: Vec<usize> = (0..m.rows).filter(|&r| m.row_nnz(r) > 0).collect();
    if nonzero_rows.is_empty() {
        println!("{name}: matrix has no nonzeros — nothing to update");
        return Ok(());
    }
    let k = ((frac * m.rows as f64).ceil() as usize).clamp(1, nonzero_rows.len());
    let stride = (nonzero_rows.len() / k).max(1);
    let rows: Vec<usize> = nonzero_rows.into_iter().step_by(stride).take(k).collect();
    // factor 1.0: every repair iteration writes the same bits, so the
    // timing loop measures steady-state repair, not value drift
    let mut delta = hbp_spmv::preprocess::MatrixDelta::new();
    for &r in &rows {
        delta = delta.scale_row(r, 1.0);
    }

    let mut report = hbp_spmv::preprocess::UpdateReport::default();
    let mut repair_secs = f64::INFINITY;
    for _ in 0..iters {
        let t = hbp_spmv::util::Timer::start();
        report = hbp.apply_delta(&mut m, &map, &delta, &reorder, nthreads)?;
        repair_secs = repair_secs.min(t.elapsed_secs());
    }
    let (_, rebuild_secs) = time(|| build_hbp_parallel(&m, cfg, &reorder, nthreads));

    println!("matrix        {name}");
    println!("rows touched  {} of {} (frac {frac})", report.rows_touched, m.rows);
    println!(
        "blocks        touched {} / {} ({})",
        report.blocks_touched,
        report.blocks_total,
        if report.full_rebuild { "full rebuild fallback" } else { "partial re-fill" }
    );
    println!("first build   {}", fmt_duration(build_secs));
    println!("delta repair  {} (best of {iters})", fmt_duration(repair_secs));
    println!("full rebuild  {}", fmt_duration(rebuild_secs));
    println!("speedup       {:.2}x", rebuild_secs / repair_secs.max(1e-12));

    // the repaired HBP must serve the mutated matrix exactly
    let x = hbp_spmv::gen::random::vector(m.cols, 42);
    let eng = HbpEngine::new(hbp, nthreads, 0.25);
    let mut y = vec![0.0; m.rows];
    eng.spmv(&x, &mut y);
    let mut expect = vec![0.0; m.rows];
    m.spmv(&x, &mut expect);
    let ok = hbp_spmv::formats::dense::allclose(&y, &expect, 1e-9, 1e-11);
    println!("verify vs serial CSR: {}", if ok { "OK" } else { "MISMATCH" });
    if !ok {
        bail!("verification failed");
    }
    Ok(())
}

fn cmd_spmv(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let nthreads = threads(args);
    let engine_name = args.str_or("engine", "hbp");
    let iters = args.usize_or("iters", 10);
    let cfg = PartitionConfig::default();

    let engine: Box<dyn SpmvEngine> = match engine_name {
        "hbp" => {
            let hbp = build_hbp_parallel(&m, cfg, &HashReorder::default(), nthreads);
            Box::new(HbpEngine::new(hbp, nthreads, args.f64_or("competitive", 0.25)))
        }
        "csr" => Box::new(CsrParallel::new(m.clone(), nthreads)),
        "2d" => Box::new(Spmv2dEngine::new(m.clone(), cfg, nthreads)),
        "nnz-split" => Box::new(hbp_spmv::exec::NnzSplitEngine::new(m.clone(), nthreads)),
        "flat" => Box::new(hbp_spmv::exec::FlatEngine::new(m.clone(), nthreads)),
        "line-enhance" => Box::new(hbp_spmv::exec::LineEnhanceEngine::new(m.clone(), nthreads)),
        "auto" => {
            let tuner = make_tuner(args, cfg, nthreads);
            let outcome = tuner.tune(&m);
            let d = outcome.decision;
            println!(
                "auto-tuned -> {} (rows/blk {}, cols/blk {}, {})",
                d.kind,
                d.cfg.rows_per_block,
                d.cfg.cols_per_block,
                if outcome.cache_hit { "tuning cache hit" } else { "competitive trial" }
            );
            hbp_spmv::tune::build_candidate(&m, d.kind, d.cfg, nthreads)
        }
        other => bail!("unknown engine {other:?}"),
    };

    let batch = args.usize_or("batch", 1);
    if batch >= 2 {
        // fused SpMM: one engine call serves all k vectors, streaming
        // each matrix element once per tile instead of once per vector
        let xs: Vec<Vec<f64>> = (0..batch)
            .map(|i| hbp_spmv::gen::random::vector(m.cols, 42 + i as u64))
            .collect();
        let mut ys: Vec<Vec<f64>> = vec![vec![0.0; m.rows]; batch];
        engine.spmm(&xs, &mut ys); // warmup
        let t = hbp_spmv::util::Timer::start();
        for _ in 0..iters {
            engine.spmm(&xs, &mut ys);
        }
        let secs = t.elapsed_secs() / iters as f64;
        println!(
            "{name} engine={} threads={nthreads} batch={batch}: {} / iter ({} / vector), {:.3} GFLOPS",
            engine.name(),
            fmt_duration(secs),
            fmt_duration(secs / batch as f64),
            batch as f64 * engine.gflops(secs)
        );
        if args.flag("verify") {
            let mut expect = vec![0.0; m.rows];
            for (i, (x, y)) in xs.iter().zip(&ys).enumerate() {
                expect.fill(0.0);
                m.spmv(x, &mut expect);
                if !hbp_spmv::formats::dense::allclose(y, &expect, 1e-9, 1e-11) {
                    println!("verify vs serial CSR: MISMATCH (vector {i})");
                    bail!("verification failed");
                }
            }
            println!("verify vs serial CSR: OK ({batch} vectors)");
        }
        return Ok(());
    }

    let x = hbp_spmv::gen::random::vector(m.cols, 42);
    let mut y = vec![0.0; m.rows];
    engine.spmv(&x, &mut y); // warmup
    let t = hbp_spmv::util::Timer::start();
    for _ in 0..iters {
        engine.spmv(&x, &mut y);
    }
    let secs = t.elapsed_secs() / iters as f64;
    println!(
        "{name} engine={} threads={nthreads}: {} / iter, {:.3} GFLOPS",
        engine.name(),
        fmt_duration(secs),
        engine.gflops(secs)
    );

    if args.flag("verify") {
        let mut expect = vec![0.0; m.rows];
        m.spmv(&x, &mut expect);
        let ok = hbp_spmv::formats::dense::allclose(&y, &expect, 1e-9, 1e-11);
        println!("verify vs serial CSR: {}", if ok { "OK" } else { "MISMATCH" });
        if !ok {
            bail!("verification failed");
        }
    }
    Ok(())
}

/// `hbp tune`: run the autotuner on one matrix and print what it saw —
/// extracted features, the model's ranked candidates (top-k measured by
/// competitive trial), and the crowned winner. A second run on
/// unchanged content hits the tuning cache and skips the trial run.
fn cmd_tune(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let nthreads = threads(args);
    let cfg = PartitionConfig::default();
    let mut tuner = make_tuner(args, cfg, nthreads);
    tuner.trial.top_k = args.usize_or("top-k", tuner.trial.top_k);
    tuner.trial.iters = args.usize_or("iters", tuner.trial.iters);
    let outcome = tuner.tune(&m);

    println!("matrix      {name}");
    match tuner.cache_path() {
        Some(p) => println!(
            "content     {:016x}  ({} @ {})",
            outcome.key,
            if outcome.cache_hit { "cache hit" } else { "cache miss" },
            p.display()
        ),
        None => println!("content     {:016x}  (cache disabled)", outcome.key),
    }
    let f = &outcome.features;
    println!("features    rows {}  cols {}  nnz {}", f.rows, f.cols, f.nnz);
    println!(
        "            row nnz mean {:.2}  std {:.2}  max {}  cv {:.2}",
        f.row_mean, f.row_std, f.row_max, f.row_cv
    );
    println!(
        "            zero rows {:.1}%  diag {:.1}%  bandwidth {:.1} cols ({:.3} of width)",
        100.0 * f.zero_row_frac,
        100.0 * f.diag_frac,
        f.bandwidth_mean,
        f.bandwidth_frac
    );
    println!(
        "            non-empty blocks {}  block-nnz cv {:.2}",
        f.nonempty_blocks, f.block_nnz_cv
    );

    println!("\ncandidates  (model-ranked; top {} measured by trial)\n", tuner.trial.top_k);
    let mut t = Table::new(&["rank", "engine", "rows/blk", "cols/blk", "score", "median spmv", ""]);
    match &outcome.report {
        Some(report) => {
            for (i, tr) in report.trials.iter().enumerate() {
                t.row(&[
                    format!("{}", i + 1),
                    tr.kind.to_string(),
                    format!("{}", tr.cfg.rows_per_block),
                    format!("{}", tr.cfg.cols_per_block),
                    format!("{:.2}", tr.model_score),
                    fmt_duration(tr.median_secs),
                    if i == report.winner { "<- winner".into() } else { String::new() },
                ]);
            }
        }
        None => {
            // cache hit: show the model's ranking; no measurements ran
            for (i, sc) in hbp_spmv::tune::model::rank(f, cfg).iter().enumerate() {
                let is_winner = sc.candidate.kind == outcome.decision.kind
                    && sc.candidate.cfg == outcome.decision.cfg;
                t.row(&[
                    format!("{}", i + 1),
                    sc.candidate.kind.to_string(),
                    format!("{}", sc.candidate.cfg.rows_per_block),
                    format!("{}", sc.candidate.cfg.cols_per_block),
                    format!("{:.2}", sc.score),
                    "(cached)".into(),
                    if is_winner { "<- winner".into() } else { String::new() },
                ]);
            }
        }
    }
    t.print();

    let d = &outcome.decision;
    println!(
        "\nwinner      {} rows_per_block={} cols_per_block={} ({}; median {})",
        d.kind,
        d.cfg.rows_per_block,
        d.cfg.cols_per_block,
        if outcome.cache_hit { "from tuning cache, no trial run" } else { "competitive trial" },
        fmt_duration(d.trial_secs)
    );
    println!(
        "tune cost   {}  (features {}, trials {})",
        fmt_duration(outcome.tune_secs),
        fmt_duration(outcome.phases.features_secs),
        fmt_duration(outcome.phases.trials_secs)
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let dev = match args.str_or("device", "orin") {
        "orin" => DeviceConfig::orin(),
        "rtx4090" | "4090" => DeviceConfig::rtx4090(),
        other => bail!("unknown device {other:?}"),
    };
    let cfg = PartitionConfig::default();
    let hbp = hbp_spmv::preprocess::build_hbp(&m, cfg);
    let shell = hbp_spmv::preprocess::build_hbp_with(&m, cfg, &IdentityReorder);

    println!("device {} — matrix {name}\n", dev.name);
    let rows = [
        ("csr", simulate_csr(&m, &dev)),
        ("2d", simulate_spmv2d(&shell, &dev)),
        ("hbp", simulate_hbp(&hbp, &dev, 0.25)),
    ];
    println!(
        "{:6} {:>12} {:>12} {:>10} {:>10} {:>14}",
        "engine", "spmv", "combine", "GFLOPS", "mem busy", "throughput"
    );
    for (n, r) in rows {
        println!(
            "{:6} {:>12} {:>12} {:>10.3} {:>9.2}% {:>11.2} GB/s",
            n,
            fmt_duration(r.spmv_secs),
            fmt_duration(r.combine_secs),
            r.gflops(),
            100.0 * r.mem_busy(&dev),
            r.mem_throughput_gbps()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let nthreads = threads(args);
    let scale = Scale::parse(args.str_or("scale", "ci")).context("bad --scale")?;
    let addr = args.str_or("addr", "127.0.0.1:7700").to_string();
    let names = args.str_or("matrices", "m1,m3");

    // fault-tolerance knobs: bounded admission, a default deadline for
    // requests that do not carry their own, and a connection cap
    let bdef = BatcherConfig::default();
    let bcfg = BatcherConfig {
        max_queue: args.usize_or("max-queue", bdef.max_queue),
        default_deadline: match args.get("deadline-ms") {
            Some(ms) => Some(std::time::Duration::from_millis(
                ms.parse().context("--deadline-ms expects milliseconds")?,
            )),
            None => bdef.default_deadline,
        },
        // telemetry knobs: per-shard trace-ring capacity and the
        // slow-request threshold (unset = slow log disabled)
        trace_capacity: args.usize_or("trace-capacity", bdef.trace_capacity),
        slow_threshold: match args.get("slow-ms") {
            Some(ms) => Some(std::time::Duration::from_millis(
                ms.parse().context("--slow-ms expects milliseconds")?,
            )),
            None => bdef.slow_threshold,
        },
        ..bdef
    };
    let sdef = hbp_spmv::coordinator::ServerConfig::default();
    let scfg = hbp_spmv::coordinator::ServerConfig {
        max_conns: args.usize_or("max-conns", sdef.max_conns),
        ..sdef
    };

    let cfg = PartitionConfig::default();
    let mut router = if args.flag("no-cache") {
        Router::new(cfg, nthreads)
    } else {
        Router::with_tuner(cfg, nthreads, Tuner::with_cache(cfg, nthreads, tune_cache_path(args)))
    };
    for id in names.split(',') {
        let (meta, m) =
            matrix_by_id(id.trim(), scale).with_context(|| format!("unknown matrix {id}"))?;
        let nnz = m.nnz();
        router.register(meta.id, m)?;
        let p = router.get(meta.id)?;
        println!(
            "registered {} ({}, {} nnz) — engine {} ({}), built in {}",
            meta.id,
            meta.name,
            nnz,
            p.resolved_kind(),
            if p.tune.cache_hit { "tuning cache hit" } else { "tuned by trial" },
            fmt_duration(p.preprocess_secs)
        );
    }
    let armed = hbp_spmv::sim::faults::arm_from_env();
    if armed > 0 {
        eprintln!("warning: {armed} fault(s) armed via HBP_FAULTS — degradation rehearsal mode");
    }
    // N independent batcher shards over the shared router; connections
    // are assigned round-robin at accept time (--shards 1 is the old
    // single-batcher front)
    let shards = args.usize_or("shards", 1).max(1);
    let coordinator = std::sync::Arc::new(Coordinator::with_shards(router, bcfg, shards));
    if shards > 1 {
        println!("serving with {shards} shards (per-shard admission control)");
    }
    if args.flag("batch-stats") {
        // periodic observability: the telemetry reporter emits one
        // structured JSON stats line to stderr every 10s, and only when
        // the request count moved, so an idle server stays quiet
        hbp_spmv::coordinator::telemetry::spawn_reporter(
            coordinator.metrics.clone(),
            std::time::Duration::from_secs(10),
        );
    }
    hbp_spmv::coordinator::serve(coordinator, &addr, scfg)
}

/// `hbp stats`: one-shot scrape of a running server. `--format json`
/// prints the `stats` reply verbatim (machine-readable snapshot with
/// the per-shard breakdown); `--format prom` prints the Prometheus
/// text exposition carried by the `metrics` op, ready to pipe into a
/// node-exporter textfile or `tools/check_prom.py`.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.get("addr").context("--addr <host:port> is required")?;
    let mut client = Client::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    match args.str_or("format", "json") {
        "json" => {
            let reply = client.call(&obj(&[("op", Json::Str("stats".into()))]))?;
            println!("{reply}");
        }
        "prom" => {
            let reply = client.call(&obj(&[("op", Json::Str("metrics".into()))]))?;
            if reply.get("ok").map(|v| matches!(v, Json::Bool(true))) != Some(true) {
                bail!("metrics op failed: {reply}");
            }
            let text = reply
                .get("prom")
                .and_then(Json::as_str)
                .context("metrics reply carries no \"prom\" text")?;
            // the exposition text ends with a newline already
            print!("{text}");
        }
        other => bail!("unknown --format {other:?} (expected json or prom)"),
    }
    Ok(())
}

//! Request tracing, slow-request logging, and Prometheus exposition.
//!
//! Every request the batcher answers produces one [`Span`]: the
//! per-stage timing decomposition (queue wait → engine execution →
//! reply hand-off) plus the grouping decisions that shaped it (resolved
//! engine, group size, whether `auto` traffic merged in, fused SpMM
//! width). Spans land in a bounded per-shard [`TraceRing`] *before* the
//! reply is handed to the connection writer, so a client that has read
//! its reply is guaranteed to find its span in a subsequent
//! `{"op":"trace"}` drain — which is also what makes the executed
//! protocol-doc examples deterministic.
//!
//! The ring is lock-light by design: the single dispatcher thread that
//! owns a shard is the only pusher, and it only ever `try_lock`s — a
//! collision with a concurrent drain drops the span (counted in
//! `dropped`) instead of stalling the request path. Draining takes the
//! lock for a bounded clone of the newest entries.
//!
//! [`prom_text`] renders the same metrics served by the `stats` op as
//! Prometheus text exposition (counters, gauges, and cumulative
//! histogram `_bucket`/`_sum`/`_count` series, shard-labeled), for the
//! `{"op":"metrics"}` protocol op and `hbp stats --format prom`.

use super::ServiceMetrics;
use crate::util::json::{obj, Json};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One completed request's trace: stage timings plus the batching
/// decisions that shaped it. The three stage durations sum to
/// `total_secs` exactly — they are cut from one monotonic timeline.
#[derive(Clone, Debug)]
pub struct Span {
    /// Monotone global sequence number (shared across shards), assigned
    /// at publish time — merge-sort key for the `trace` op.
    pub seq: u64,
    /// Shard whose dispatcher executed the request.
    pub shard: usize,
    /// The protocol request `id` (pipelined requests), echoed for
    /// correlation; `None` for un-tagged requests.
    pub id: Option<String>,
    /// Target matrix name.
    pub matrix: String,
    /// The *resolved* engine kind that executed the request (never
    /// `auto` for hosted matrices).
    pub engine: String,
    /// Size of the flushed group this request rode in.
    pub group_size: usize,
    /// Whether the group mixed `auto` and explicit arrivals — a merge
    /// that only resolved grouping makes possible.
    pub merged_auto: bool,
    /// Vectors answered by the engine pass that served this request
    /// (`> 1` only on the fused SpMM path).
    pub spmm_width: usize,
    /// Admission → execution-start wait, seconds.
    pub queue_wait_secs: f64,
    /// Engine-call time, seconds (the whole group's pass — every
    /// member of a fused group shares it).
    pub execute_secs: f64,
    /// Reply assembly + hand-off to the connection writer, seconds.
    pub reply_secs: f64,
    /// End-to-end admission → reply-handoff latency, seconds; equals
    /// the sum of the three stages by construction.
    pub total_secs: f64,
    /// Whether the request succeeded (errors, deadline drops, and
    /// recovered panics trace with `ok: false`).
    pub ok: bool,
}

impl Span {
    /// JSON view used by the `trace` op and the slow-request log.
    pub fn to_json(&self) -> Json {
        obj(&[
            ("seq", Json::Num(self.seq as f64)),
            ("shard", Json::Num(self.shard as f64)),
            (
                "id",
                match &self.id {
                    Some(s) => Json::Str(s.clone()),
                    None => Json::Null,
                },
            ),
            ("matrix", Json::Str(self.matrix.clone())),
            ("engine", Json::Str(self.engine.clone())),
            ("group_size", Json::Num(self.group_size as f64)),
            ("merged_auto", Json::Bool(self.merged_auto)),
            ("spmm_width", Json::Num(self.spmm_width as f64)),
            ("queue_wait_secs", Json::Num(self.queue_wait_secs)),
            ("execute_secs", Json::Num(self.execute_secs)),
            ("reply_secs", Json::Num(self.reply_secs)),
            ("total_secs", Json::Num(self.total_secs)),
            ("ok", Json::Bool(self.ok)),
        ])
    }
}

/// Bounded ring of the most recent [`Span`]s, tuned for a single
/// pusher (the shard's dispatcher thread) that must never block: the
/// push side only `try_lock`s and drops the span on contention, so a
/// slow or stuck drainer costs trace completeness, never request
/// latency.
pub struct TraceRing {
    buf: Mutex<VecDeque<Span>>,
    capacity: usize,
    dropped: AtomicU64,
}

impl TraceRing {
    /// Ring holding up to `capacity` spans (at least 1); older spans
    /// are evicted as new ones arrive.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        TraceRing {
            buf: Mutex::new(VecDeque::with_capacity(capacity)),
            capacity,
            dropped: AtomicU64::new(0),
        }
    }

    /// Record a span, evicting the oldest at capacity. Never blocks:
    /// if a drain holds the lock the span is counted in [`dropped`]
    /// and discarded.
    ///
    /// [`dropped`]: TraceRing::dropped
    pub fn push(&self, span: Span) {
        match self.buf.try_lock() {
            Ok(mut buf) => {
                if buf.len() == self.capacity {
                    buf.pop_front();
                }
                buf.push_back(span);
            }
            Err(std::sync::TryLockError::WouldBlock) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            Err(std::sync::TryLockError::Poisoned(e)) => {
                // a drainer panicked mid-clone; the ring contents are
                // still structurally valid spans, so keep recording
                let mut buf = e.into_inner();
                if buf.len() == self.capacity {
                    buf.pop_front();
                }
                buf.push_back(span);
            }
        }
    }

    /// The newest `limit` spans, oldest → newest. Takes the lock (the
    /// pusher side won't wait on it — see [`push`]).
    ///
    /// [`push`]: TraceRing::push
    pub fn recent(&self, limit: usize) -> Vec<Span> {
        let buf = self.buf.lock().unwrap_or_else(|e| e.into_inner());
        let skip = buf.len().saturating_sub(limit);
        buf.iter().skip(skip).cloned().collect()
    }

    /// Spans currently held.
    pub fn len(&self) -> usize {
        self.buf.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Whether the ring holds no spans.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans discarded because a drain held the lock at push time.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Per-shard telemetry bundle handed to the batcher: the trace ring,
/// the shared span sequence counter, and the slow-request threshold.
pub struct Telemetry {
    shard: usize,
    ring: TraceRing,
    slow_secs: Option<f64>,
    seq: Arc<AtomicU64>,
}

impl Telemetry {
    /// Stand-alone telemetry (own sequence counter) — what a bare
    /// `Batcher::start` builds for itself.
    pub fn new(shard: usize, capacity: usize, slow_threshold: Option<Duration>) -> Self {
        Telemetry::with_seq(shard, capacity, slow_threshold, Arc::new(AtomicU64::new(0)))
    }

    /// Telemetry sharing `seq` with sibling shards, so spans merge into
    /// one global order across the coordinator's rings.
    pub fn with_seq(
        shard: usize,
        capacity: usize,
        slow_threshold: Option<Duration>,
        seq: Arc<AtomicU64>,
    ) -> Self {
        Telemetry {
            shard,
            ring: TraceRing::new(capacity),
            slow_secs: slow_threshold.map(|d| d.as_secs_f64()),
            seq,
        }
    }

    /// The shard this bundle traces.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Next global span sequence number.
    pub fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Publish a completed span: emit the slow-request log line when
    /// the span crossed the threshold, then record it in the ring.
    /// Callers invoke this *before* handing the reply to the writer,
    /// so a client that has read its reply will find its span.
    pub fn publish(&self, span: Span) {
        if let Some(slow) = self.slow_secs {
            if span.total_secs >= slow {
                let mut j = span.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("event".to_string(), Json::Str("slow_request".to_string()));
                }
                eprintln!("{j}");
            }
        }
        self.ring.push(span);
    }

    /// The newest `limit` spans from this shard's ring.
    pub fn recent(&self, limit: usize) -> Vec<Span> {
        self.ring.recent(limit)
    }

    /// Spans this shard discarded under push/drain contention.
    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }
}

/// One structured stats line (JSON object with `"event":"stats"`) from
/// a metrics snapshot — the periodic reporter and `--batch-stats` both
/// print exactly this, so log scrapers see a single shape.
pub fn report_line(metrics: &ServiceMetrics) -> String {
    let mut j = metrics.snapshot().to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("event".to_string(), Json::Str("stats".to_string()));
    }
    j.to_string()
}

/// Spawn a detached reporter thread that prints [`report_line`] to
/// stderr every `every` until the process exits (the `--batch-stats`
/// serve flag). Ticks where the request count has not moved are
/// skipped, so an idle server stays quiet.
pub fn spawn_reporter(metrics: Arc<ServiceMetrics>, every: Duration) {
    let builder = std::thread::Builder::new().name("hbp-stats-reporter".to_string());
    let spawned = builder.spawn(move || {
        let mut last_requests = 0u64;
        loop {
            std::thread::sleep(every);
            let requests = metrics.snapshot().requests;
            if requests != last_requests {
                last_requests = requests;
                eprintln!("{}", report_line(&metrics));
            }
        }
    });
    if let Err(e) = spawned {
        eprintln!("hbp-spmv: stats reporter not started: {e}");
    }
}

/// Format an `f64` the way Prometheus exposition expects: `+Inf` for
/// the open top bucket, plain decimal otherwise.
fn prom_num(x: f64) -> String {
    if x.is_infinite() {
        if x > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if x.is_nan() {
        "NaN".to_string()
    } else {
        format!("{x}")
    }
}

/// Escape a label value per the exposition format (`\\`, `\"`, `\n`).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Append one `# HELP` + `# TYPE` header pair.
fn prom_header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Append one histogram family: cumulative `_bucket{le=...}` series
/// ending at `le="+Inf"`, then `_sum` and `_count`.
///
/// Bucket semantics note: [`crate::util::stats::Histogram`] buckets are
/// upper-exclusive (`x < bound`) while Prometheus `le` is inclusive —
/// for continuous latencies the boundary mass is negligible and the
/// exposition treats the bound as the bucket's `le`.
fn prom_histogram(out: &mut String, name: &str, labels: &str, h: &crate::util::stats::Histogram) {
    for (bound, cum) in h.cumulative() {
        let le = prom_num(bound);
        if labels.is_empty() {
            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
        } else {
            out.push_str(&format!("{name}_bucket{{{labels},le=\"{le}\"}} {cum}\n"));
        }
    }
    let lb = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
    out.push_str(&format!("{name}_sum{lb} {}\n", prom_num(h.sum())));
    out.push_str(&format!("{name}_count{lb} {}\n", h.total()));
}

/// Render the service metrics as Prometheus text exposition
/// (version 0.0.4): global counters/gauges and histograms from the
/// root metrics, plus per-shard series labeled `shard="<i>"` under
/// `hbp_shard_*` names so global families and their per-shard
/// decomposition never collide in one family.
pub fn prom_text(root: &ServiceMetrics, shards: &[Arc<ServiceMetrics>]) -> String {
    let s = root.snapshot();
    let mut out = String::new();

    // global counters
    let counters: [(&str, u64, &str); 15] = [
        ("hbp_requests_total", s.requests, "SpMV requests answered successfully."),
        ("hbp_errors_total", s.errors, "Failed requests (SpMV or update)."),
        ("hbp_shed_total", s.shed, "Requests shed by admission control."),
        ("hbp_deadline_drops_total", s.deadline_drops, "Requests dropped past their deadline."),
        (
            "hbp_panics_recovered_total",
            s.panics_recovered,
            "Panics caught and converted into per-request errors.",
        ),
        ("hbp_accept_errors_total", s.accept_errors, "Transient accept-loop errors survived."),
        ("hbp_updates_total", s.updates, "Matrix deltas applied."),
        ("hbp_full_rebuilds_total", s.full_rebuilds, "Updates that forced a full HBP rebuild."),
        ("hbp_tunes_total", s.tunes, "Tuner invocations."),
        ("hbp_tune_cache_hits_total", s.tune_cache_hits, "Tunes short-circuited by the cache."),
        ("hbp_tune_trials_total", s.tune_trials, "Candidates measured by competitive trials."),
        ("hbp_batch_groups_total", s.batch_groups, "SpMV batch groups flushed."),
        (
            "hbp_batch_merged_auto_total",
            s.batch_merged_auto,
            "Auto arrivals merged into explicit groups by resolved grouping.",
        ),
        (
            "hbp_spmm_fused_vectors_total",
            s.spmm_fused_vectors,
            "Vectors answered by fused multi-vector SpMM passes.",
        ),
        ("hbp_builds_total", s.builds, "Preprocessing builds profiled at registration."),
    ];
    for (name, v, help) in counters {
        prom_header(&mut out, name, "counter", help);
        out.push_str(&format!("{name} {v}\n"));
    }

    // global gauges (point-in-time or derived values)
    let gauges: [(&str, f64, &str); 7] = [
        ("hbp_uptime_seconds", s.uptime_secs, "Seconds since the metrics were created."),
        ("hbp_queue_depth", s.queue_depth as f64, "Requests sitting in the batcher queues."),
        (
            "hbp_inflight_pipeline",
            s.inflight_pipeline as f64,
            "Pipelined id-tagged requests currently in flight.",
        ),
        ("hbp_requests_per_sec", s.requests_per_sec, "Successful requests per second of uptime."),
        ("hbp_gflops", s.gflops, "2*nnz per second across answered requests, in GFLOPS."),
        (
            "hbp_mean_group_size",
            s.mean_group_size,
            "Mean requests per flushed batch group.",
        ),
        (
            "hbp_mean_build_total_seconds",
            s.mean_build_plan_secs + s.mean_build_fill_secs,
            "Mean plan+fill seconds per profiled preprocessing build.",
        ),
    ];
    for (name, v, help) in gauges {
        prom_header(&mut out, name, "gauge", help);
        out.push_str(&format!("{name} {}\n", prom_num(v)));
    }

    // global histograms (end-to-end latency + the stage decomposition)
    for (name, h) in root.histograms() {
        let family = format!("hbp_{name}");
        prom_header(&mut out, &family, "histogram", "Cumulative request-stage histogram.");
        prom_histogram(&mut out, &family, "", &h);
    }

    // per-shard decomposition under hbp_shard_* names
    let per: Vec<_> = shards.iter().map(|m| (m.snapshot(), m.histograms())).collect();
    let shard_counters: [(&str, fn(&super::MetricsSnapshot) -> u64, &str); 6] = [
        ("hbp_shard_requests_total", |p| p.requests, "Per-shard answered requests."),
        ("hbp_shard_errors_total", |p| p.errors, "Per-shard failed requests."),
        ("hbp_shard_shed_total", |p| p.shed, "Per-shard shed requests."),
        ("hbp_shard_deadline_drops_total", |p| p.deadline_drops, "Per-shard deadline drops."),
        (
            "hbp_shard_panics_recovered_total",
            |p| p.panics_recovered,
            "Per-shard recovered panics.",
        ),
        ("hbp_shard_batch_groups_total", |p| p.batch_groups, "Per-shard flushed groups."),
    ];
    for (name, pick, help) in shard_counters {
        prom_header(&mut out, name, "counter", help);
        for (i, (snap, _)) in per.iter().enumerate() {
            let shard = escape_label(&i.to_string());
            out.push_str(&format!("{name}{{shard=\"{shard}\"}} {}\n", pick(snap)));
        }
    }
    let shard_gauges: [(&str, fn(&super::MetricsSnapshot) -> f64, &str); 2] = [
        ("hbp_shard_queue_depth", |p| p.queue_depth as f64, "Per-shard batcher queue depth."),
        (
            "hbp_shard_inflight_pipeline",
            |p| p.inflight_pipeline as f64,
            "Per-shard pipelined requests in flight.",
        ),
    ];
    for (name, pick, help) in shard_gauges {
        prom_header(&mut out, name, "gauge", help);
        for (i, (snap, _)) in per.iter().enumerate() {
            out.push_str(&format!("{name}{{shard=\"{i}\"}} {}\n", prom_num(pick(snap))));
        }
    }
    // per-shard stage histograms — every family emitted once with one
    // series set per shard, shard-labeled
    for (hist_idx, short) in
        ["request_latency_seconds", "queue_wait_seconds", "execute_seconds", "reply_seconds"]
            .iter()
            .enumerate()
    {
        let family = format!("hbp_shard_{short}");
        prom_header(&mut out, &family, "histogram", "Per-shard request-stage histogram.");
        for (i, (_, hists)) in per.iter().enumerate() {
            let labels = format!("shard=\"{i}\"");
            prom_histogram(&mut out, &family, &labels, &hists[hist_idx].1);
        }
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn span(seq: u64) -> Span {
        Span {
            seq,
            shard: 0,
            id: Some(format!("req-{seq}")),
            matrix: "m".to_string(),
            engine: "hbp".to_string(),
            group_size: 1,
            merged_auto: false,
            spmm_width: 1,
            queue_wait_secs: 1e-5,
            execute_secs: 2e-5,
            reply_secs: 3e-6,
            total_secs: 3.3e-5,
            ok: true,
        }
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest() {
        let ring = TraceRing::new(4);
        assert!(ring.is_empty());
        for i in 0..10 {
            ring.push(span(i));
        }
        assert_eq!(ring.len(), 4);
        let got = ring.recent(100);
        let seqs: Vec<u64> = got.iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "oldest evicted, order preserved");
        // a tighter limit returns the newest suffix
        let seqs: Vec<u64> = ring.recent(2).iter().map(|s| s.seq).collect();
        assert_eq!(seqs, vec![8, 9]);
        assert_eq!(ring.dropped(), 0, "uncontended pushes never drop");
    }

    #[test]
    fn ring_survives_concurrent_push_and_drain() {
        let ring = Arc::new(TraceRing::new(64));
        let pusher = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                for i in 0..5000 {
                    ring.push(span(i));
                }
            })
        };
        let mut drained_any = false;
        for _ in 0..200 {
            let got = ring.recent(64);
            drained_any |= !got.is_empty();
            // drained spans are always internally ordered by seq
            for w in got.windows(2) {
                assert!(w[0].seq < w[1].seq);
            }
        }
        pusher.join().unwrap();
        assert!(drained_any);
        // every push either landed or was counted as dropped
        let final_len = ring.len() as u64;
        assert!(final_len <= 64);
        assert!(ring.dropped() + final_len <= 5000);
        // with the pusher joined, this push is uncontended by
        // construction and must land as the newest span
        ring.push(span(5000));
        assert_eq!(ring.recent(1)[0].seq, 5000);
    }

    #[test]
    fn telemetry_sequences_and_publishes() {
        let tele = Telemetry::new(3, 8, None);
        assert_eq!(tele.shard(), 3);
        let a = tele.next_seq();
        let b = tele.next_seq();
        assert!(b > a, "sequence numbers are strictly increasing");
        tele.publish(span(a));
        tele.publish(span(b));
        let got = tele.recent(10);
        assert_eq!(got.len(), 2);
        assert_eq!(got[1].seq, b);
        assert_eq!(tele.dropped(), 0);
    }

    #[test]
    fn shared_seq_interleaves_across_shards() {
        let seq = Arc::new(AtomicU64::new(0));
        let t0 = Telemetry::with_seq(0, 8, None, seq.clone());
        let t1 = Telemetry::with_seq(1, 8, None, seq);
        let mut seen = Vec::new();
        for _ in 0..3 {
            seen.push(t0.next_seq());
            seen.push(t1.next_seq());
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), seen.len(), "shared counter never repeats across shards");
    }

    #[test]
    fn span_json_has_the_wire_shape() {
        let mut s = span(7);
        s.id = None;
        let j = s.to_json();
        assert_eq!(j.get("seq").and_then(|v| v.as_usize()), Some(7));
        assert_eq!(j.get("id"), Some(&Json::Null));
        assert_eq!(j.get("engine").and_then(|v| v.as_str()), Some("hbp"));
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        // stages sum to the total (the invariant the stats histograms
        // inherit)
        let qw = j.get("queue_wait_secs").unwrap().as_f64().unwrap();
        let ex = j.get("execute_secs").unwrap().as_f64().unwrap();
        let rp = j.get("reply_secs").unwrap().as_f64().unwrap();
        let total = j.get("total_secs").unwrap().as_f64().unwrap();
        assert!((qw + ex + rp - total).abs() < 1e-12);
        // round-trips through the parser
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn report_line_is_one_parseable_stats_event() {
        let m = ServiceMetrics::new();
        m.record_request(1e-5, 100);
        let line = report_line(&m);
        assert!(!line.contains('\n'));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("event").and_then(|v| v.as_str()), Some("stats"));
        assert_eq!(j.get("requests").and_then(|v| v.as_usize()), Some(1));
    }

    #[test]
    fn prom_text_exposes_counters_and_cumulative_histograms() {
        let root = Arc::new(ServiceMetrics::new());
        let shard = Arc::new(ServiceMetrics::shard_of(root.clone()));
        shard.record_request(1e-4, 1000);
        shard.record_stages(2e-5, 7e-5, 1e-5);
        shard.record_error();
        shard.gauge_queue_depth(2);
        let text = prom_text(&root, &[shard]);
        assert!(text.contains("# TYPE hbp_requests_total counter"));
        assert!(text.contains("\nhbp_requests_total 1\n"));
        assert!(text.contains("\nhbp_errors_total 1\n"));
        assert!(text.contains("hbp_shard_requests_total{shard=\"0\"} 1\n"));
        assert!(text.contains("hbp_shard_queue_depth{shard=\"0\"} 2\n"));
        // histogram series: buckets end at +Inf with the total count
        assert!(text.contains("hbp_request_latency_seconds_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("hbp_request_latency_seconds_count 1\n"));
        assert!(text.contains("hbp_queue_wait_seconds_count 1\n"));
        assert!(text.contains("hbp_shard_execute_seconds_bucket{shard=\"0\",le=\"+Inf\"} 1\n"));
        // _sum carries the recorded mass
        assert!(text.contains("hbp_execute_seconds_sum 0.00007"));
        // every non-comment line is `name{labels} value`
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').unwrap();
            assert!(!name.is_empty());
            assert!(
                value == "+Inf" || value.parse::<f64>().is_ok(),
                "unparseable sample value in {line:?}"
            );
        }
        // buckets are monotone non-decreasing per series set
        let bucket_counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("hbp_request_latency_seconds_bucket"))
            .map(|l| l.rsplit_once(' ').unwrap().1.parse().unwrap())
            .collect();
        assert!(bucket_counts.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(bucket_counts.last(), Some(&1));
    }

    #[test]
    fn prom_label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(prom_num(f64::INFINITY), "+Inf");
        assert_eq!(prom_num(0.25), "0.25");
    }
}

//! The protocol's stable error taxonomy.
//!
//! Every `{"ok":false}` reply carries a machine-readable `"code"`
//! alongside the human-readable `"error"` message, so clients can
//! branch on *why* a request failed (retry an `overloaded` shed, fix a
//! `bad_request`, re-register after `unknown_matrix`) without parsing
//! prose. Inside the coordinator the code travels as a [`ServiceError`]
//! payload on `anyhow::Error` — it survives any number of
//! `.context(..)` layers and is recovered at the serialization boundary
//! by [`error_reply`] via `downcast_ref`. Errors without a tagged
//! payload default to [`ErrorCode::BadRequest`]: on the request path an
//! untagged error is a validation failure (parse error, unknown op,
//! dimension mismatch, invalid delta); anything the *service* caused is
//! tagged [`ErrorCode::Internal`] explicitly where it is caught.

use crate::util::json::{obj, Json};
use std::fmt;

/// Machine-readable failure categories carried in the `"code"` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request itself is malformed: parse error, unknown op,
    /// missing field, dimension mismatch, invalid delta, over-long line.
    BadRequest,
    /// The named matrix is not registered with the router.
    UnknownMatrix,
    /// Admission control shed the request (queue full or connection
    /// limit reached); the reply carries `retry_after_ms`.
    Overloaded,
    /// The request's deadline passed before (or while) it was served.
    DeadlineExceeded,
    /// The service is tearing down and no longer admits work; unlike
    /// `overloaded` there is no point retrying against this instance.
    ShuttingDown,
    /// The service failed on a well-formed request — typically a
    /// recovered panic in an engine or pool worker.
    Internal,
}

impl ErrorCode {
    /// The wire spelling (`"bad_request"`, `"overloaded"`, ...).
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownMatrix => "unknown_matrix",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }

    /// Parse the wire spelling back (client side).
    pub fn parse(s: &str) -> Option<ErrorCode> {
        match s {
            "bad_request" => Some(ErrorCode::BadRequest),
            "unknown_matrix" => Some(ErrorCode::UnknownMatrix),
            "overloaded" => Some(ErrorCode::Overloaded),
            "deadline_exceeded" => Some(ErrorCode::DeadlineExceeded),
            "shutting_down" => Some(ErrorCode::ShuttingDown),
            "internal" => Some(ErrorCode::Internal),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A typed service failure: an [`ErrorCode`], a message, and (for
/// `overloaded` sheds) a client back-off hint.
///
/// Implements `std::error::Error`, so `?` and `anyhow::Error::new` keep
/// the value downcastable wherever the error surfaces — the server
/// boundary ([`error_reply`]) and the [`Client`](super::server::Client)
/// both recover it.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceError {
    /// Stable protocol code.
    pub code: ErrorCode,
    /// Human-readable message (the reply's `"error"` field text).
    pub message: String,
    /// How long the client should back off before retrying, present on
    /// [`ErrorCode::Overloaded`] replies.
    pub retry_after_ms: Option<u64>,
}

impl ServiceError {
    /// A typed error with no retry hint.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ServiceError {
        ServiceError { code, message: message.into(), retry_after_ms: None }
    }

    /// `bad_request` — the caller sent something malformed.
    pub fn bad_request(message: impl Into<String>) -> ServiceError {
        ServiceError::new(ErrorCode::BadRequest, message)
    }

    /// `unknown_matrix` — message matches the router's historical text.
    pub fn unknown_matrix(name: &str) -> ServiceError {
        ServiceError::new(ErrorCode::UnknownMatrix, format!("matrix {name:?} not registered"))
    }

    /// `overloaded` — shed by admission control; retry after the hint.
    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> ServiceError {
        ServiceError {
            code: ErrorCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// `deadline_exceeded` — the work was dropped, not executed.
    pub fn deadline_exceeded(message: impl Into<String>) -> ServiceError {
        ServiceError::new(ErrorCode::DeadlineExceeded, message)
    }

    /// `shutting_down` — the service is tearing down; the request was
    /// refused, never executed.
    pub fn shutting_down(message: impl Into<String>) -> ServiceError {
        ServiceError::new(ErrorCode::ShuttingDown, message)
    }

    /// `internal` — the service, not the request, is at fault.
    pub fn internal(message: impl Into<String>) -> ServiceError {
        ServiceError::new(ErrorCode::Internal, message)
    }

    /// Client side: rebuild the typed error from an `{"ok":false}` reply.
    pub fn from_reply(resp: &Json) -> Option<ServiceError> {
        let code = ErrorCode::parse(resp.get("code")?.as_str()?)?;
        let message = resp
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("server error")
            .to_string();
        let retry_after_ms =
            resp.get("retry_after_ms").and_then(Json::as_f64).map(|n| n as u64);
        Some(ServiceError { code, message, retry_after_ms })
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for ServiceError {}

/// Serialize an error into the protocol's failure reply:
/// `{"ok":false,"code":...,"error":...}` plus `retry_after_ms` when the
/// shed carries a back-off hint. The code comes from the
/// [`ServiceError`] payload if one is attached, else `bad_request`.
pub fn error_reply(e: &anyhow::Error) -> Json {
    let (code, retry) = match e.downcast_ref::<ServiceError>() {
        Some(se) => (se.code, se.retry_after_ms),
        None => (ErrorCode::BadRequest, None),
    };
    let mut fields = vec![
        ("ok", Json::Bool(false)),
        ("code", Json::Str(code.as_str().to_string())),
        ("error", Json::Str(format!("{e:#}"))),
    ];
    if let Some(ms) = retry {
        fields.push(("retry_after_ms", Json::Num(ms as f64)));
    }
    obj(&fields)
}

/// Client side: turn an `{"ok":false}` reply into an `anyhow::Error`
/// that downcasts to [`ServiceError`] (when the reply carries a valid
/// code — older or foreign servers fall back to an untyped message).
pub fn reply_error(resp: &Json) -> anyhow::Error {
    match ServiceError::from_reply(resp) {
        Some(se) => anyhow::Error::new(se),
        None => anyhow::anyhow!("server error: {resp}"),
    }
}

/// Render a `catch_unwind` payload as text (panics carry `&str` or
/// `String` in practice; anything else gets a placeholder).
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn codes_round_trip_the_wire_spelling() {
        for code in [
            ErrorCode::BadRequest,
            ErrorCode::UnknownMatrix,
            ErrorCode::Overloaded,
            ErrorCode::DeadlineExceeded,
            ErrorCode::ShuttingDown,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::parse(code.as_str()), Some(code));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }

    #[test]
    fn error_reply_carries_code_and_retry_hint() {
        let e = anyhow::Error::new(ServiceError::overloaded("queue full", 25));
        let r = error_reply(&e);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(r.get("retry_after_ms").unwrap().as_f64(), Some(25.0));

        // untagged errors default to bad_request, with no retry hint
        let e = anyhow::anyhow!("missing field");
        let r = error_reply(&e);
        assert_eq!(r.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(r.get("retry_after_ms").is_none());
    }

    #[test]
    fn code_survives_context_layers() {
        let e = anyhow::Error::new(ServiceError::unknown_matrix("ghost"))
            .context("handling spmv");
        let r = error_reply(&e);
        assert_eq!(r.get("code").unwrap().as_str(), Some("unknown_matrix"));
        let msg = r.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("handling spmv") && msg.contains("ghost"));
    }

    #[test]
    fn client_reply_round_trip() {
        let e = anyhow::Error::new(ServiceError::overloaded("queue full", 50));
        let resp = error_reply(&e);
        let back = reply_error(&resp);
        let se = back.downcast_ref::<ServiceError>().unwrap();
        assert_eq!(se.code, ErrorCode::Overloaded);
        assert_eq!(se.retry_after_ms, Some(50));

        // replies without a code still become a printable error
        let legacy = Json::parse(r#"{"ok":false,"error":"old server"}"#).unwrap();
        assert!(format!("{:#}", reply_error(&legacy)).contains("old server"));
    }

    #[test]
    fn panic_messages_render() {
        let p: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(p), "boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(format!("boom {}", 2));
        assert_eq!(panic_message(p), "boom 2");
        let p: Box<dyn std::any::Any + Send> = Box::new(17_u32);
        assert_eq!(panic_message(p), "non-string panic payload");
    }
}

//! Service metrics: request counts, latency histogram, throughput,
//! update/tune/batching counters.
//!
//! Everything here is observable through the protocol's `stats` op and
//! `hbp serve --batch-stats`; the batching counters
//! (`batch_groups`, `batch_merged_auto`, `mean_group_size`) are the
//! evidence that resolved grouping merges `auto` and explicit traffic.
//!
//! Metrics compose into a one-level tree for the sharded serving front:
//! [`ServiceMetrics::shard_of`] creates per-shard metrics that forward
//! every recording to a shared parent, so the global totals the `stats`
//! op reports equal the sum of the per-shard counters *by construction*
//! (the `shards` breakdown in the same reply is each shard's own view).

use crate::util::stats::{Histogram, Welford};
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

struct Inner {
    requests: u64,
    errors: u64,
    latency: Histogram,
    latency_stats: Welford,
    // per-stage decomposition of the end-to-end latency: admission →
    // execution start (queue_wait), the engine call (execute), and
    // reply assembly + hand-off to the connection writer (reply). For
    // every traced request the three stage samples sum to the latency
    // sample — they are cut from the same monotonic timeline.
    queue_wait: Histogram,
    execute: Histogram,
    reply: Histogram,
    nnz_processed: f64,
    started: Instant,
    // preprocessing phase times (BuildProfile) from served registrations
    builds: u64,
    build_plan_secs: f64,
    build_reorder_secs: f64,
    build_fill_secs: f64,
    // matrix-update traffic (the incremental-rebuild path)
    updates: u64,
    full_rebuilds: u64,
    update_blocks_touched: u64,
    update_blocks_total: u64,
    update_secs: Welford,
    // autotuning (registration-time engine selection)
    tunes: u64,
    tune_cache_hits: u64,
    tune_trials: u64,
    tune_secs: Welford,
    // resolved batching (grouping by tuned decision, not requested kind)
    batch_groups: u64,
    batch_merged_auto: u64,
    group_size: Welford,
    // fused SpMM (multi-vector groups executed in one engine pass)
    spmm_fused_vectors: u64,
    spmm_width: Welford,
    // fault tolerance (degradations that kept the service up)
    shed: u64,
    deadline_drops: u64,
    panics_recovered: u64,
    accept_errors: u64,
}

/// Thread-safe service metrics, optionally rolling up into a parent.
pub struct ServiceMetrics {
    inner: Mutex<Inner>,
    /// Saturation gauges live outside the mutex: they are touched on
    /// every admission and every pipelined in-flight change, and a
    /// relaxed atomic keeps that off the lock entirely. Signed so a
    /// momentary inc/dec race can dip below zero without wrapping; the
    /// snapshot clamps at zero.
    queue_depth: AtomicI64,
    inflight_pipeline: AtomicI64,
    /// When set (per-shard metrics), every recording is applied to the
    /// parent too — one level only, which is all the coordinator builds.
    parent: Option<Arc<ServiceMetrics>>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    /// Fresh, all-zero metrics; the uptime clock starts now.
    pub fn new() -> Self {
        Self::build(None)
    }

    /// Fresh per-shard metrics that forward every recording to
    /// `parent`, so the parent's totals are the sum of its shards by
    /// construction. One level only: passing an already-parented
    /// metrics as `parent` would double-count nothing here (forwarding
    /// is not chained), so the coordinator always hands in the root.
    pub fn shard_of(parent: Arc<ServiceMetrics>) -> Self {
        Self::build(Some(parent))
    }

    fn build(parent: Option<Arc<ServiceMetrics>>) -> Self {
        ServiceMetrics {
            parent,
            queue_depth: AtomicI64::new(0),
            inflight_pipeline: AtomicI64::new(0),
            inner: Mutex::new(Inner {
                requests: 0,
                errors: 0,
                // 1µs .. ~1s exponential buckets
                latency: Histogram::exponential(1e-6, 21),
                latency_stats: Welford::new(),
                queue_wait: Histogram::exponential(1e-6, 21),
                execute: Histogram::exponential(1e-6, 21),
                reply: Histogram::exponential(1e-6, 21),
                nnz_processed: 0.0,
                started: Instant::now(),
                builds: 0,
                build_plan_secs: 0.0,
                build_reorder_secs: 0.0,
                build_fill_secs: 0.0,
                updates: 0,
                full_rebuilds: 0,
                update_blocks_touched: 0,
                update_blocks_total: 0,
                update_secs: Welford::new(),
                tunes: 0,
                tune_cache_hits: 0,
                tune_trials: 0,
                tune_secs: Welford::new(),
                batch_groups: 0,
                batch_merged_auto: 0,
                group_size: Welford::new(),
                spmm_fused_vectors: 0,
                spmm_width: Welford::new(),
                shed: 0,
                deadline_drops: 0,
                panics_recovered: 0,
                accept_errors: 0,
            }),
        }
    }

    /// Poison-recovering lock: a panic while a recorder held the mutex
    /// (all recorders are short straight-line sections, but the batcher
    /// records from inside `catch_unwind` scopes) must not wedge every
    /// later `stats` call — counters stay valid, so take the guard back.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Apply one recording to this metrics object and (for per-shard
    /// metrics) to the parent. The two locks are taken one after the
    /// other, never nested, so shards cannot deadlock against each
    /// other or against a concurrent `snapshot` on the root.
    fn record(&self, f: impl Fn(&mut Inner)) {
        f(&mut self.lock());
        if let Some(p) = &self.parent {
            f(&mut p.lock());
        }
    }

    /// Record one answered SpMV request: its latency and the nonzeros
    /// it processed (feeds the GFLOPS estimate).
    pub fn record_request(&self, latency_secs: f64, nnz: usize) {
        self.record(|m| {
            m.requests += 1;
            m.latency.record(latency_secs);
            m.latency_stats.push(latency_secs);
            m.nnz_processed += nnz as f64;
        });
    }

    /// Record the per-stage decomposition of one traced request:
    /// admission→execution-start wait, engine-call time, and reply
    /// assembly/hand-off. Recorded alongside [`record_request`], whose
    /// latency sample is the sum of these three by construction.
    ///
    /// [`record_request`]: ServiceMetrics::record_request
    pub fn record_stages(&self, queue_wait_secs: f64, execute_secs: f64, reply_secs: f64) {
        self.record(|m| {
            m.queue_wait.record(queue_wait_secs);
            m.execute.record(execute_secs);
            m.reply.record(reply_secs);
        });
    }

    /// Record one preprocessing build profile (plan/reorder/fill phase
    /// wall-times) from a served registration.
    pub fn record_build(&self, profile: &crate::preprocess::BuildProfile) {
        let p = *profile;
        self.record(move |m| {
            m.builds += 1;
            m.build_plan_secs += p.plan_secs;
            m.build_reorder_secs += p.reorder_secs;
            m.build_fill_secs += p.fill_secs;
        });
    }

    /// Adjust the queue-occupancy gauge (batcher admissions minus
    /// dispatcher drains). Lock-free; forwards to the parent like every
    /// recorder so the global gauge is the shard sum.
    pub fn gauge_queue_depth(&self, delta: i64) {
        self.queue_depth.fetch_add(delta, Ordering::Relaxed);
        if let Some(p) = &self.parent {
            p.queue_depth.fetch_add(delta, Ordering::Relaxed);
        }
    }

    /// Adjust the pipelined in-flight gauge (id-tagged requests with a
    /// live waiter). Lock-free; forwards to the parent.
    pub fn gauge_inflight_pipeline(&self, delta: i64) {
        self.inflight_pipeline.fetch_add(delta, Ordering::Relaxed);
        if let Some(p) = &self.parent {
            p.inflight_pipeline.fetch_add(delta, Ordering::Relaxed);
        }
    }

    /// Clones of the latency and per-stage histograms, for renderers
    /// that need raw buckets (the Prometheus exposition) rather than
    /// the snapshot's point quantiles. Order: end-to-end latency,
    /// queue_wait, execute, reply.
    pub fn histograms(&self) -> [(&'static str, Histogram); 4] {
        let m = self.lock();
        [
            ("request_latency_seconds", m.latency.clone()),
            ("queue_wait_seconds", m.queue_wait.clone()),
            ("execute_seconds", m.execute.clone()),
            ("reply_seconds", m.reply.clone()),
        ]
    }

    /// Record one failed request (SpMV or update).
    pub fn record_error(&self) {
        self.record(|m| m.errors += 1);
    }

    /// Record one request shed by admission control (bounded queue full
    /// or connection limit reached). Shed work never executed, so it
    /// does not count toward `errors`.
    pub fn record_shed(&self) {
        self.record(|m| m.shed += 1);
    }

    /// Record one request dropped because its deadline passed (at
    /// admission or at flush). Dropped work never executed, so it does
    /// not count toward `errors`.
    pub fn record_deadline_drop(&self) {
        self.record(|m| m.deadline_drops += 1);
    }

    /// Record one panic caught and converted into per-request
    /// `internal` errors (engine execution, pool worker, or handler).
    pub fn record_panic_recovered(&self) {
        self.record(|m| m.panics_recovered += 1);
    }

    /// Record one transient accept-loop error that was logged and
    /// survived instead of killing the listener.
    pub fn record_accept_error(&self) {
        self.record(|m| m.accept_errors += 1);
    }

    /// Record one flushed SpMV batch group: its size and how many of
    /// its requests arrived as `auto` vs an explicit engine kind. An
    /// `auto` arrival counts toward `batch_merged_auto` only when the
    /// group also holds explicit requests — those are exactly the
    /// merges that resolving *before* grouping made possible (under
    /// requested-kind grouping they would have flushed separately).
    pub fn record_group(&self, size: usize, auto_requests: usize, explicit_requests: usize) {
        self.record(|m| {
            m.batch_groups += 1;
            m.group_size.push(size as f64);
            if auto_requests > 0 && explicit_requests > 0 {
                m.batch_merged_auto += auto_requests as u64;
            }
        });
    }

    /// Record one fused SpMM execution: `width` vectors answered by a
    /// single engine pass (the group sizes that actually took the fused
    /// path, as opposed to `mean_group_size` which counts every flushed
    /// group including singletons and fallbacks).
    pub fn record_spmm(&self, width: usize) {
        self.record(|m| {
            m.spmm_fused_vectors += width as u64;
            m.spmm_width.push(width as f64);
        });
    }

    /// Record one applied matrix delta: its latency and how much of the
    /// HBP it had to re-fill (the blocks-touched vs blocks-total ratio
    /// is the incremental path's whole value proposition).
    pub fn record_update(&self, secs: f64, report: &crate::preprocess::UpdateReport) {
        self.record(|m| {
            m.updates += 1;
            if report.full_rebuild {
                m.full_rebuilds += 1;
            }
            m.update_blocks_touched += report.blocks_touched as u64;
            m.update_blocks_total += report.blocks_total as u64;
            m.update_secs.push(secs);
        });
    }

    /// Record one tuner outcome: whether the cache short-circuited it,
    /// how many candidates were trialed, and the end-to-end tune cost.
    pub fn record_tune(&self, outcome: &crate::tune::TuneOutcome) {
        self.record(|m| {
            m.tunes += 1;
            if outcome.cache_hit {
                m.tune_cache_hits += 1;
            }
            m.tune_trials += outcome.report.as_ref().map(|r| r.trials.len()).unwrap_or(0) as u64;
            m.tune_secs.push(outcome.tune_secs);
        });
    }

    /// Snapshot for the `stats` endpoint.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.lock();
        let elapsed = m.started.elapsed().as_secs_f64();
        let builds = m.builds.max(1) as f64;
        MetricsSnapshot {
            requests: m.requests,
            errors: m.errors,
            mean_latency_secs: m.latency_stats.mean(),
            p50_latency_secs: m.latency.quantile(0.5),
            p99_latency_secs: m.latency.quantile(0.99),
            p50_queue_wait_secs: m.queue_wait.quantile(0.5),
            p99_queue_wait_secs: m.queue_wait.quantile(0.99),
            p50_execute_secs: m.execute.quantile(0.5),
            p99_execute_secs: m.execute.quantile(0.99),
            p50_reply_secs: m.reply.quantile(0.5),
            p99_reply_secs: m.reply.quantile(0.99),
            requests_per_sec: m.requests as f64 / elapsed.max(1e-9),
            gflops: 2.0 * m.nnz_processed / elapsed.max(1e-9) / 1e9,
            uptime_secs: elapsed,
            queue_depth: self.queue_depth.load(Ordering::Relaxed).max(0) as u64,
            inflight_pipeline: self.inflight_pipeline.load(Ordering::Relaxed).max(0) as u64,
            builds: m.builds,
            // means guard the zero-build case to 0.0 (matching the
            // other mean_* fields), keeping the JSON type stable
            mean_build_plan_secs: m.build_plan_secs / builds,
            mean_build_reorder_secs: m.build_reorder_secs / builds,
            mean_build_fill_secs: m.build_fill_secs / builds,
            updates: m.updates,
            full_rebuilds: m.full_rebuilds,
            update_blocks_touched: m.update_blocks_touched,
            update_blocks_total: m.update_blocks_total,
            mean_update_secs: m.update_secs.mean(),
            tunes: m.tunes,
            tune_cache_hits: m.tune_cache_hits,
            tune_trials: m.tune_trials,
            mean_tune_secs: m.tune_secs.mean(),
            batch_groups: m.batch_groups,
            batch_merged_auto: m.batch_merged_auto,
            mean_group_size: m.group_size.mean(),
            spmm_fused_vectors: m.spmm_fused_vectors,
            mean_spmm_width: m.spmm_width.mean(),
            shed: m.shed,
            deadline_drops: m.deadline_drops,
            panics_recovered: m.panics_recovered,
            accept_errors: m.accept_errors,
        }
    }
}

/// A point-in-time metrics snapshot.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    /// SpMV requests answered successfully.
    pub requests: u64,
    /// Failed requests (SpMV or update).
    pub errors: u64,
    /// Mean per-request latency in seconds.
    pub mean_latency_secs: f64,
    /// Median per-request latency (histogram estimate). `NaN` when no
    /// request has been recorded yet — serialized as JSON `null`.
    pub p50_latency_secs: f64,
    /// 99th-percentile per-request latency (histogram estimate). `NaN`
    /// when empty, `+inf` when the quantile falls in the open top
    /// bucket — both serialized as JSON `null`.
    pub p99_latency_secs: f64,
    /// Median admission→execution-start wait (histogram estimate;
    /// non-finite when no traced request exists, JSON `null`).
    pub p50_queue_wait_secs: f64,
    /// 99th-percentile queue wait (histogram estimate; nullable).
    pub p99_queue_wait_secs: f64,
    /// Median engine-call time (histogram estimate; nullable).
    pub p50_execute_secs: f64,
    /// 99th-percentile engine-call time (histogram estimate; nullable).
    pub p99_execute_secs: f64,
    /// Median reply assembly/hand-off time (histogram estimate;
    /// nullable).
    pub p50_reply_secs: f64,
    /// 99th-percentile reply assembly/hand-off time (histogram
    /// estimate; nullable).
    pub p99_reply_secs: f64,
    /// Successful requests per wall-clock second since startup.
    pub requests_per_sec: f64,
    /// `2 * nnz` per second across all answered requests, in GFLOPS.
    pub gflops: f64,
    /// Seconds since these metrics were created.
    pub uptime_secs: f64,
    /// Requests currently sitting in the batcher queue(s) — admissions
    /// minus dispatcher drains, sampled at snapshot time.
    pub queue_depth: u64,
    /// Pipelined (id-tagged) requests currently in flight — waiter
    /// threads alive across all connections, sampled at snapshot time.
    pub inflight_pipeline: u64,
    /// Preprocessing builds profiled at registration time.
    pub builds: u64,
    /// Mean planning-pass seconds per profiled build (0 when none).
    pub mean_build_plan_secs: f64,
    /// Mean in-fill reorder seconds per profiled build (0 when none).
    pub mean_build_reorder_secs: f64,
    /// Mean fill-pass seconds per profiled build (0 when none).
    pub mean_build_fill_secs: f64,
    /// Matrix deltas applied.
    pub updates: u64,
    /// Updates that fell back to a full HBP rebuild (pattern change).
    pub full_rebuilds: u64,
    /// Cumulative blocks re-filled across all updates.
    pub update_blocks_touched: u64,
    /// Cumulative pre-update block counts across all updates.
    pub update_blocks_total: u64,
    /// Mean seconds per applied delta.
    pub mean_update_secs: f64,
    /// Tuner invocations recorded (registrations + post-update
    /// re-resolves).
    pub tunes: u64,
    /// How many of those were content-hash cache hits (no trial run).
    pub tune_cache_hits: u64,
    /// Cumulative candidates measured by competitive trials.
    pub tune_trials: u64,
    /// Mean seconds per tuner invocation.
    pub mean_tune_secs: f64,
    /// SpMV batch groups flushed against hosted matrices (grouped by
    /// *resolved* engine kind; unknown-matrix groups execute nothing
    /// and are not counted).
    pub batch_groups: u64,
    /// `auto` arrivals that shared a flushed group with explicit
    /// requests — merges that only resolved grouping makes possible.
    pub batch_merged_auto: u64,
    /// Mean requests per flushed group.
    pub mean_group_size: f64,
    /// Vectors answered by fused multi-vector SpMM passes (each matrix
    /// traversal amortized across the whole group).
    pub spmm_fused_vectors: u64,
    /// Mean vectors per fused SpMM execution.
    pub mean_spmm_width: f64,
    /// Requests shed by admission control (bounded queue full or
    /// connection limit); shed work never executed, so it is not in
    /// `errors`.
    pub shed: u64,
    /// Requests dropped because their deadline passed at admission or
    /// at flush; likewise not in `errors`.
    pub deadline_drops: u64,
    /// Panics caught (engine, pool worker, or handler) and converted
    /// into per-request `internal` errors instead of a dead service.
    pub panics_recovered: u64,
    /// Transient accept-loop errors survived without dropping the
    /// listener.
    pub accept_errors: u64,
}

impl MetricsSnapshot {
    /// JSON view served by the protocol's `stats` op. Histogram
    /// quantiles are `null` until a sample exists (and for a p99 that
    /// falls in the open top bucket) — never a bare `NaN`/`inf` token,
    /// which would make the whole reply unparseable.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num_or_null, obj, Json};
        obj(&[
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("mean_latency_secs", Json::Num(self.mean_latency_secs)),
            ("p50_latency_secs", num_or_null(self.p50_latency_secs)),
            ("p99_latency_secs", num_or_null(self.p99_latency_secs)),
            ("p50_queue_wait_secs", num_or_null(self.p50_queue_wait_secs)),
            ("p99_queue_wait_secs", num_or_null(self.p99_queue_wait_secs)),
            ("p50_execute_secs", num_or_null(self.p50_execute_secs)),
            ("p99_execute_secs", num_or_null(self.p99_execute_secs)),
            ("p50_reply_secs", num_or_null(self.p50_reply_secs)),
            ("p99_reply_secs", num_or_null(self.p99_reply_secs)),
            ("requests_per_sec", Json::Num(self.requests_per_sec)),
            ("gflops", Json::Num(self.gflops)),
            ("uptime_secs", Json::Num(self.uptime_secs)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("inflight_pipeline", Json::Num(self.inflight_pipeline as f64)),
            ("builds", Json::Num(self.builds as f64)),
            ("mean_build_plan_secs", Json::Num(self.mean_build_plan_secs)),
            ("mean_build_reorder_secs", Json::Num(self.mean_build_reorder_secs)),
            ("mean_build_fill_secs", Json::Num(self.mean_build_fill_secs)),
            ("updates", Json::Num(self.updates as f64)),
            ("full_rebuilds", Json::Num(self.full_rebuilds as f64)),
            ("update_blocks_touched", Json::Num(self.update_blocks_touched as f64)),
            ("update_blocks_total", Json::Num(self.update_blocks_total as f64)),
            ("mean_update_secs", Json::Num(self.mean_update_secs)),
            ("tunes", Json::Num(self.tunes as f64)),
            ("tune_cache_hits", Json::Num(self.tune_cache_hits as f64)),
            ("tune_trials", Json::Num(self.tune_trials as f64)),
            ("mean_tune_secs", Json::Num(self.mean_tune_secs)),
            ("batch_groups", Json::Num(self.batch_groups as f64)),
            ("batch_merged_auto", Json::Num(self.batch_merged_auto as f64)),
            ("mean_group_size", Json::Num(self.mean_group_size)),
            ("spmm_fused_vectors", Json::Num(self.spmm_fused_vectors as f64)),
            ("mean_spmm_width", Json::Num(self.mean_spmm_width)),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_drops", Json::Num(self.deadline_drops as f64)),
            ("panics_recovered", Json::Num(self.panics_recovered as f64)),
            ("accept_errors", Json::Num(self.accept_errors as f64)),
        ])
    }

    /// Compact per-shard view for the `stats` reply's `shards` array.
    /// Counter fields list only what is recorded exclusively through
    /// shard metrics (never directly on the root), so summing any of
    /// them across the breakdown reproduces the global total; the
    /// saturation gauges and per-stage quantiles decompose the global
    /// picture per shard (quantiles are nullable like the global ones).
    pub fn shard_json(&self, shard: usize) -> crate::util::json::Json {
        use crate::util::json::{num_or_null, obj, Json};
        obj(&[
            ("shard", Json::Num(shard as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("deadline_drops", Json::Num(self.deadline_drops as f64)),
            ("panics_recovered", Json::Num(self.panics_recovered as f64)),
            ("batch_groups", Json::Num(self.batch_groups as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("inflight_pipeline", Json::Num(self.inflight_pipeline as f64)),
            ("p50_queue_wait_secs", num_or_null(self.p50_queue_wait_secs)),
            ("p99_queue_wait_secs", num_or_null(self.p99_queue_wait_secs)),
            ("p50_execute_secs", num_or_null(self.p50_execute_secs)),
            ("p99_execute_secs", num_or_null(self.p99_execute_secs)),
            ("p50_reply_secs", num_or_null(self.p50_reply_secs)),
            ("p99_reply_secs", num_or_null(self.p99_reply_secs)),
        ])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn records_fault_tolerance_counters() {
        let m = ServiceMetrics::new();
        m.record_shed();
        m.record_shed();
        m.record_deadline_drop();
        m.record_panic_recovered();
        m.record_accept_error();
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.deadline_drops, 1);
        assert_eq!(s.panics_recovered, 1);
        assert_eq!(s.accept_errors, 1);
        assert_eq!(s.errors, 0, "sheds and drops are not execution errors");
        let j = s.to_json();
        assert_eq!(j.get("shed").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("deadline_drops").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("panics_recovered").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("accept_errors").and_then(|v| v.as_usize()), Some(1));
    }

    #[test]
    fn shard_metrics_roll_up_into_the_parent() {
        let root = std::sync::Arc::new(ServiceMetrics::new());
        let shards: Vec<ServiceMetrics> =
            (0..3).map(|_| ServiceMetrics::shard_of(root.clone())).collect();
        shards[0].record_request(1e-5, 100);
        shards[0].record_request(2e-5, 100);
        shards[1].record_error();
        shards[1].record_shed();
        shards[2].record_deadline_drop();
        shards[2].record_panic_recovered();
        shards[2].record_group(2, 1, 1);
        shards[2].record_spmm(2);

        // every shard recording is visible in the parent totals...
        let s = root.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.errors, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.deadline_drops, 1);
        assert_eq!(s.panics_recovered, 1);
        assert_eq!(s.batch_groups, 1);
        assert_eq!(s.batch_merged_auto, 1);
        assert_eq!(s.spmm_fused_vectors, 2);
        // ...and derived means aggregate over the union of shards
        assert!((s.mean_latency_secs - 1.5e-5).abs() < 1e-12);

        // each shard keeps its own view; sums reproduce the totals
        let per: Vec<MetricsSnapshot> = shards.iter().map(|m| m.snapshot()).collect();
        assert_eq!(per.iter().map(|p| p.requests).sum::<u64>(), s.requests);
        assert_eq!(per.iter().map(|p| p.errors).sum::<u64>(), s.errors);
        assert_eq!(per.iter().map(|p| p.shed).sum::<u64>(), s.shed);
        assert_eq!(per[0].requests, 2);
        assert_eq!(per[1].requests, 0);

        // recordings on the root do NOT propagate down
        root.record_accept_error();
        assert_eq!(shards[0].snapshot().accept_errors, 0);

        // the shard json view carries exactly the roll-up counters
        let j = per[2].shard_json(2);
        assert_eq!(j.get("shard").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("deadline_drops").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("panics_recovered").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("batch_groups").and_then(|v| v.as_usize()), Some(1));
        assert!(j.get("accept_errors").is_none(), "front-level counters stay global");
    }

    #[test]
    fn survives_a_panic_while_recording() {
        let m = std::sync::Arc::new(ServiceMetrics::new());
        let m2 = m.clone();
        // poison the mutex by panicking while a guard is held
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _guard = m2.lock();
            panic!("injected");
        }));
        // recording and snapshotting still work afterwards
        m.record_request(1e-6, 10);
        assert_eq!(m.snapshot().requests, 1);
    }

    #[test]
    fn records_and_snapshots() {
        let m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record_request(i as f64 * 1e-5, 1000);
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.errors, 1);
        assert!(s.mean_latency_secs > 0.0);
        assert!(s.p99_latency_secs >= s.p50_latency_secs);
        assert!(s.gflops > 0.0);
    }

    #[test]
    fn records_updates() {
        use crate::preprocess::UpdateReport;
        let m = ServiceMetrics::new();
        let partial = UpdateReport {
            rows_touched: 2,
            blocks_touched: 3,
            blocks_total: 10,
            full_rebuild: false,
        };
        let full = UpdateReport {
            rows_touched: 9,
            blocks_touched: 10,
            blocks_total: 10,
            full_rebuild: true,
        };
        m.record_update(1e-4, &partial);
        m.record_update(2e-3, &full);
        let s = m.snapshot();
        assert_eq!(s.updates, 2);
        assert_eq!(s.full_rebuilds, 1);
        assert_eq!(s.update_blocks_touched, 13);
        assert_eq!(s.update_blocks_total, 20);
        assert!(s.mean_update_secs > 0.0);
        // the json view carries the update fields
        let j = s.to_json();
        assert_eq!(j.get("updates").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("full_rebuilds").and_then(|v| v.as_usize()), Some(1));
    }

    #[test]
    fn records_tunes() {
        use crate::gen::random;
        use crate::partition::PartitionConfig;
        use crate::tune::{TrialConfig, Tuner};
        let mut tuner = Tuner::new(PartitionConfig::test_small(), 1);
        tuner.trial = TrialConfig { top_k: 2, warmup: 0, iters: 1, seed: 1 };
        let m = random::uniform(20, 20, 0.3, 4);
        let metrics = ServiceMetrics::new();
        metrics.record_tune(&tuner.tune(&m)); // cold: trials run
        metrics.record_tune(&tuner.tune(&m)); // warm: cache hit
        let s = metrics.snapshot();
        assert_eq!(s.tunes, 2);
        assert_eq!(s.tune_cache_hits, 1);
        assert_eq!(s.tune_trials, 2, "only the cold tune measures candidates");
        assert!(s.mean_tune_secs >= 0.0);
        let j = s.to_json();
        assert_eq!(j.get("tunes").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("tune_cache_hits").and_then(|v| v.as_usize()), Some(1));
    }

    #[test]
    fn records_batch_groups_and_auto_merges() {
        let m = ServiceMetrics::new();
        // mixed group: 2 auto + 1 explicit → both autos count as merged
        m.record_group(3, 2, 1);
        // pure groups: nothing to merge, whatever the arrival kind
        m.record_group(4, 4, 0);
        m.record_group(1, 0, 1);
        let s = m.snapshot();
        assert_eq!(s.batch_groups, 3);
        assert_eq!(s.batch_merged_auto, 2);
        assert!((s.mean_group_size - (3.0 + 4.0 + 1.0) / 3.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("batch_groups").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(j.get("batch_merged_auto").and_then(|v| v.as_usize()), Some(2));
        assert!(j.get("mean_group_size").is_some());
    }

    #[test]
    fn records_fused_spmm_widths() {
        let m = ServiceMetrics::new();
        m.record_spmm(4);
        m.record_spmm(2);
        let s = m.snapshot();
        assert_eq!(s.spmm_fused_vectors, 6);
        assert!((s.mean_spmm_width - 3.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("spmm_fused_vectors").and_then(|v| v.as_usize()), Some(6));
        assert!(j.get("mean_spmm_width").is_some());
    }

    #[test]
    fn zero_request_snapshot_serializes_to_valid_json() {
        // regression: empty-histogram quantiles are NaN and used to be
        // written verbatim, making a fresh server's stats reply
        // unparseable. They must serialize as null and round-trip.
        let s = ServiceMetrics::new().snapshot();
        assert!(s.p50_latency_secs.is_nan());
        assert!(s.p99_queue_wait_secs.is_nan());
        let j = s.to_json();
        let text = j.to_string();
        let back = crate::util::json::Json::parse(&text)
            .expect("zero-request stats must be valid JSON");
        use crate::util::json::Json;
        for key in [
            "p50_latency_secs",
            "p99_latency_secs",
            "p50_queue_wait_secs",
            "p99_queue_wait_secs",
            "p50_execute_secs",
            "p99_execute_secs",
            "p50_reply_secs",
            "p99_reply_secs",
        ] {
            assert_eq!(back.get(key), Some(&Json::Null), "{key} must be null when empty");
        }
        assert_eq!(back.get("requests"), Some(&Json::Num(0.0)));
        // the shard view round-trips too
        let shard_text = s.shard_json(0).to_string();
        assert!(crate::util::json::Json::parse(&shard_text).is_ok());
    }

    #[test]
    fn records_stage_decomposition() {
        let m = ServiceMetrics::new();
        m.record_stages(1e-4, 2e-4, 3e-5);
        m.record_stages(2e-4, 4e-4, 5e-5);
        let s = m.snapshot();
        assert!(s.p50_queue_wait_secs.is_finite());
        assert!(s.p99_execute_secs >= s.p50_execute_secs);
        assert!(s.p50_reply_secs.is_finite());
        // raw histograms expose the same totals for the prom renderer
        let hists = m.histograms();
        assert_eq!(hists[1].0, "queue_wait_seconds");
        assert_eq!(hists[1].1.total(), 2);
        assert!((hists[2].1.sum() - 6e-4).abs() < 1e-12);
    }

    #[test]
    fn gauges_track_depth_and_forward_to_parent() {
        let root = std::sync::Arc::new(ServiceMetrics::new());
        let shard = ServiceMetrics::shard_of(root.clone());
        shard.gauge_queue_depth(1);
        shard.gauge_queue_depth(1);
        shard.gauge_queue_depth(-1);
        shard.gauge_inflight_pipeline(1);
        assert_eq!(shard.snapshot().queue_depth, 1);
        assert_eq!(root.snapshot().queue_depth, 1, "gauges roll up");
        assert_eq!(root.snapshot().inflight_pipeline, 1);
        // a transient negative dip clamps to zero instead of wrapping
        shard.gauge_queue_depth(-5);
        assert_eq!(shard.snapshot().queue_depth, 0);
        let j = root.snapshot().to_json();
        assert!(j.get("queue_depth").is_some());
        assert!(j.get("inflight_pipeline").is_some());
    }

    #[test]
    fn records_build_profiles() {
        use crate::preprocess::BuildProfile;
        let m = ServiceMetrics::new();
        assert_eq!(m.snapshot().builds, 0);
        assert_eq!(m.snapshot().mean_build_plan_secs, 0.0, "zero builds mean 0.0, not NaN");
        m.record_build(&BuildProfile {
            plan_secs: 0.1,
            reorder_secs: 0.02,
            fill_secs: 0.3,
            total_secs: 0.42,
        });
        m.record_build(&BuildProfile {
            plan_secs: 0.3,
            reorder_secs: 0.04,
            fill_secs: 0.5,
            total_secs: 0.9,
        });
        let s = m.snapshot();
        assert_eq!(s.builds, 2);
        assert!((s.mean_build_plan_secs - 0.2).abs() < 1e-12);
        assert!((s.mean_build_reorder_secs - 0.03).abs() < 1e-12);
        assert!((s.mean_build_fill_secs - 0.4).abs() < 1e-12);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(ServiceMetrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.record_request(1e-6, 10);
                    }
                });
            }
        });
        assert_eq!(m.snapshot().requests, 8000);
    }
}

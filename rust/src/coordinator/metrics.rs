//! Service metrics: request counts, latency histogram, throughput.

use crate::util::stats::{Histogram, Welford};
use std::sync::Mutex;
use std::time::Instant;

struct Inner {
    requests: u64,
    errors: u64,
    latency: Histogram,
    latency_stats: Welford,
    nnz_processed: f64,
    started: Instant,
}

/// Thread-safe service metrics.
pub struct ServiceMetrics {
    inner: Mutex<Inner>,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        ServiceMetrics {
            inner: Mutex::new(Inner {
                requests: 0,
                errors: 0,
                // 1µs .. ~1s exponential buckets
                latency: Histogram::exponential(1e-6, 21),
                latency_stats: Welford::new(),
                nnz_processed: 0.0,
                started: Instant::now(),
            }),
        }
    }

    pub fn record_request(&self, latency_secs: f64, nnz: usize) {
        let mut m = self.inner.lock().unwrap();
        m.requests += 1;
        m.latency.record(latency_secs);
        m.latency_stats.push(latency_secs);
        m.nnz_processed += nnz as f64;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Snapshot for the `stats` endpoint.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let elapsed = m.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            requests: m.requests,
            errors: m.errors,
            mean_latency_secs: m.latency_stats.mean(),
            p50_latency_secs: m.latency.quantile(0.5),
            p99_latency_secs: m.latency.quantile(0.99),
            requests_per_sec: m.requests as f64 / elapsed.max(1e-9),
            gflops: 2.0 * m.nnz_processed / elapsed.max(1e-9) / 1e9,
        }
    }
}

/// A point-in-time metrics snapshot.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub errors: u64,
    pub mean_latency_secs: f64,
    pub p50_latency_secs: f64,
    pub p99_latency_secs: f64,
    pub requests_per_sec: f64,
    pub gflops: f64,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(&[
            ("requests", Json::Num(self.requests as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("mean_latency_secs", Json::Num(self.mean_latency_secs)),
            ("p50_latency_secs", Json::Num(self.p50_latency_secs)),
            ("p99_latency_secs", Json::Num(self.p99_latency_secs)),
            ("requests_per_sec", Json::Num(self.requests_per_sec)),
            ("gflops", Json::Num(self.gflops)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record_request(i as f64 * 1e-5, 1000);
        }
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.errors, 1);
        assert!(s.mean_latency_secs > 0.0);
        assert!(s.p99_latency_secs >= s.p50_latency_secs);
        assert!(s.gflops > 0.0);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(ServiceMetrics::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.record_request(1e-6, 10);
                    }
                });
            }
        });
        assert_eq!(m.snapshot().requests, 8000);
    }
}

//! Matrix registry + engine routing.
//!
//! A registered matrix is preprocessed once (the HBP build *is* the
//! paper's cheap preprocessing step) and then serves SpMV requests
//! through whichever engine the request names — the pure-rust HBP
//! engine (default), the CSR/2D baselines, or the PJRT/AOT path.

use crate::exec::{CsrParallel, HbpEngine, SpmvEngine, Spmv2dEngine};
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use crate::preprocess::build_hbp_parallel;
use crate::preprocess::HashReorder;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Which engine executes a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Hbp,
    Csr,
    Plain2d,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "hbp" => Ok(EngineKind::Hbp),
            "csr" => Ok(EngineKind::Csr),
            "2d" => Ok(EngineKind::Plain2d),
            other => bail!("unknown engine {other:?} (expected hbp|csr|2d)"),
        }
    }
}

/// A registered, preprocessed matrix.
pub struct PreparedMatrix {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub preprocess_secs: f64,
    hbp: HbpEngine,
    csr: CsrParallel,
    plain2d: Spmv2dEngine,
}

impl PreparedMatrix {
    pub fn engine(&self, kind: EngineKind) -> &dyn SpmvEngine {
        match kind {
            EngineKind::Hbp => &self.hbp,
            EngineKind::Csr => &self.csr,
            EngineKind::Plain2d => &self.plain2d,
        }
    }

    pub fn hbp(&self) -> &HbpEngine {
        &self.hbp
    }
}

/// The matrix registry.
pub struct Router {
    pub threads: usize,
    pub cfg: PartitionConfig,
    matrices: BTreeMap<String, PreparedMatrix>,
}

impl Router {
    pub fn new(cfg: PartitionConfig, threads: usize) -> Router {
        Router { threads: threads.max(1), cfg, matrices: BTreeMap::new() }
    }

    /// Register a matrix: builds HBP (parallel, hash reorder) and the
    /// baseline engines.
    pub fn register(&mut self, name: &str, m: Csr) -> Result<&PreparedMatrix> {
        let (hbp, preprocess_secs) = crate::util::timer::time(|| {
            build_hbp_parallel(&m, self.cfg, &HashReorder::default(), self.threads)
        });
        let prepared = PreparedMatrix {
            name: name.to_string(),
            rows: m.rows,
            cols: m.cols,
            nnz: m.nnz(),
            preprocess_secs,
            hbp: HbpEngine::new(hbp, self.threads, 0.25),
            csr: CsrParallel::new(m.clone(), self.threads),
            plain2d: Spmv2dEngine::new(m, self.cfg, self.threads),
        };
        self.matrices.insert(name.to_string(), prepared);
        Ok(&self.matrices[name])
    }

    pub fn get(&self, name: &str) -> Result<&PreparedMatrix> {
        self.matrices
            .get(name)
            .with_context(|| format!("matrix {name:?} not registered"))
    }

    pub fn names(&self) -> Vec<&str> {
        self.matrices.keys().map(|s| s.as_str()).collect()
    }

    /// Route one SpMV request.
    pub fn spmv(&self, matrix: &str, kind: EngineKind, x: &[f64]) -> Result<Vec<f64>> {
        let m = self.get(matrix)?;
        anyhow::ensure!(
            x.len() == m.cols,
            "vector length {} != matrix cols {}",
            x.len(),
            m.cols
        );
        let mut y = vec![0.0; m.rows];
        m.engine(kind).spmv(x, &mut y);
        Ok(y)
    }

    /// Route a batch against one (matrix, engine): the engines' SpMM
    /// path reuses each matrix element across the whole batch.
    pub fn spmm(&self, matrix: &str, kind: EngineKind, xs: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        let m = self.get(matrix)?;
        for (i, x) in xs.iter().enumerate() {
            anyhow::ensure!(
                x.len() == m.cols,
                "batch vector {i} length {} != matrix cols {}",
                x.len(),
                m.cols
            );
        }
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; m.rows]).collect();
        m.engine(kind).spmm(&xs, &mut ys);
        Ok(ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    fn router_with(name: &str, m: Csr) -> Router {
        let mut r = Router::new(PartitionConfig::test_small(), 2);
        r.register(name, m).unwrap();
        r
    }

    #[test]
    fn register_and_route_all_engines() {
        let m = random::power_law_rows(100, 80, 2.0, 20, 3);
        let r = router_with("t", m.clone());
        let x = random::vector(80, 1);
        let mut expect = vec![0.0; 100];
        m.spmv(&x, &mut expect);
        for kind in [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?}");
        }
    }

    #[test]
    fn errors_are_clear() {
        let m = random::uniform(10, 10, 0.5, 1);
        let r = router_with("t", m);
        assert!(r.spmv("missing", EngineKind::Hbp, &vec![0.0; 10]).is_err());
        assert!(r.spmv("t", EngineKind::Hbp, &vec![0.0; 5]).is_err());
        assert!(EngineKind::parse("warp").is_err());
        assert_eq!(EngineKind::parse("2d").unwrap(), EngineKind::Plain2d);
    }

    #[test]
    fn registry_lists_names() {
        let mut r = Router::new(PartitionConfig::test_small(), 1);
        r.register("a", random::uniform(5, 5, 0.5, 1)).unwrap();
        r.register("b", random::uniform(5, 5, 0.5, 2)).unwrap();
        assert_eq!(r.names(), vec!["a", "b"]);
        assert!(r.get("a").unwrap().preprocess_secs >= 0.0);
    }
}

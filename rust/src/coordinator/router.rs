//! Matrix registry + engine routing, with autotuned lazy engines.
//!
//! Registering a matrix runs the [`crate::tune::Tuner`] (features →
//! cost model → competitive trials, short-circuited by the context-keyed
//! content-hash cache) and eagerly builds **only the decided engine**;
//! the other engines build lazily on the first request that names them.
//! This replaces the old eager triple-build: a cache-hit registration
//! pays exactly one preprocessing pass, and a cold one pays the trial
//! builds plus one (trial engines are measurement throwaways — the
//! resident HBP is rebuilt in updatable form, which trials don't need).
//!
//! `EngineKind::Auto` requests resolve to the tuned decision per
//! matrix; explicit kinds still force a specific engine. The batcher
//! consults that decision *before* grouping via [`Router::resolve`] — a
//! cheap, non-blocking read of the cached decision (no engine is built,
//! no trial runs) — so an `auto` request and an explicit request naming
//! the same resolved engine land in one batch group. A
//! **pattern-changing** update marks the decision **stale** (a changed
//! sparsity pattern can change the tuned winner; value-only deltas
//! cannot — features and SpMV timings are functions of the pattern, not
//! the values): `resolve` then defers by returning `Auto`, and the
//! flush path calls [`Router::resolve_blocking`], which re-tunes under
//! the matrix's write lock, un-stales the decision, and drops a
//! resident engine built under a superseded grid so the crowned
//! (engine, grid) pair is what `Auto` traffic actually executes on.
//!
//! Each entry sits behind its own `RwLock`: SpMV traffic takes shared
//! read locks, and a [`Router::update`] takes the write lock for just
//! that matrix — an update is atomic with respect to every in-flight
//! request against the same matrix and invisible to all others. Updates
//! repair only the engines that were actually built; the retained
//! source CSR keeps lazily-built engines consistent afterwards.

use crate::exec::{CsrParallel, FlatEngine, HbpEngine, LineEnhanceEngine, SpmvEngine, Spmv2dEngine};
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use crate::preprocess::{apply_to_csr, HashReorder, MatrixDelta, UpdateReport};
use crate::tune::{TuneOutcome, Tuner};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::{OnceLock, RwLock, RwLockReadGuard};

/// Which engine executes a request. `Auto` defers to the per-matrix
/// tuned decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The paper's hash-based-partition engine.
    Hbp,
    /// The row-parallel CSR baseline.
    Csr,
    /// The plain 2D-partitioned baseline (no hash reorder).
    Plain2d,
    /// CSR-native pure nnz-splitting (load/accumulate/reduce phases,
    /// zero conversion cost).
    Flat,
    /// CSR-native mixed row/nnz splitting (short-row bands + whole-row
    /// long tails, zero conversion cost).
    LineEnhance,
    /// Defer to the per-matrix tuned decision.
    Auto,
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        match s {
            "hbp" => Ok(EngineKind::Hbp),
            "csr" => Ok(EngineKind::Csr),
            "2d" => Ok(EngineKind::Plain2d),
            "flat" => Ok(EngineKind::Flat),
            "line-enhance" => Ok(EngineKind::LineEnhance),
            "auto" => Ok(EngineKind::Auto),
            other => bail!(
                "unknown engine {other:?} (expected one of: hbp, csr, 2d, flat, line-enhance, auto)"
            ),
        }
    }
}

/// Round-trips with the `FromStr` impl: `kind.to_string().parse()` is
/// the identity, so CLI and server output feed back in unchanged.
impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineKind::Hbp => "hbp",
            EngineKind::Csr => "csr",
            EngineKind::Plain2d => "2d",
            EngineKind::Flat => "flat",
            EngineKind::LineEnhance => "line-enhance",
            EngineKind::Auto => "auto",
        })
    }
}

/// A registered matrix: tuned decision, retained source, and lazily
/// built engines.
pub struct PreparedMatrix {
    /// Registration name (the protocol's `matrix` field).
    pub name: String,
    /// Row count of the hosted matrix.
    pub rows: usize,
    /// Column count of the hosted matrix.
    pub cols: usize,
    /// Nonzero count of the hosted matrix.
    pub nnz: usize,
    /// Build time of the decided engine (the registration cost).
    pub preprocess_secs: f64,
    /// Deltas applied since registration.
    pub updates_applied: u64,
    /// What the tuner learned at registration — or at the most recent
    /// post-update re-tune (decision, features, trial record, cache
    /// hit) — served by the `tune` protocol op.
    pub tune: TuneOutcome,
    /// Set by a pattern-changing update: the tuned decision was
    /// measured on a different sparsity pattern, so `Auto` resolution
    /// defers until [`Router::resolve_blocking`] re-tunes. Value-only
    /// deltas never set this — they cannot move the winner.
    decision_stale: bool,
    base_cfg: PartitionConfig,
    threads: usize,
    /// Source CSR, kept in lock-step with every built engine so a
    /// lazily built engine always starts from the current values.
    m: Csr,
    /// Blocked-engine slots carry the partition grid they were built
    /// with, so a re-tune can tell a superseded grid from the crowned
    /// one; CSR ignores the grid and carries no pairing.
    hbp: OnceLock<(PartitionConfig, HbpEngine)>,
    csr: OnceLock<CsrParallel>,
    plain2d: OnceLock<(PartitionConfig, Spmv2dEngine)>,
    flat: OnceLock<FlatEngine>,
    line_enhance: OnceLock<LineEnhanceEngine>,
}

impl PreparedMatrix {
    /// Resolve `Auto` to the tuned decision; explicit kinds pass through.
    pub fn resolve(&self, kind: EngineKind) -> EngineKind {
        match kind {
            EngineKind::Auto => self.tune.decision.kind,
            k => k,
        }
    }

    /// The concrete engine kind `Auto` requests execute on.
    pub fn resolved_kind(&self) -> EngineKind {
        self.resolve(EngineKind::Auto)
    }

    /// Whether the tuned decision predates a pattern-changing delta. A
    /// stale decision still serves *correct* values (engines are
    /// repaired in place) — it just may no longer be the fastest, so
    /// batch grouping defers instead of trusting it. Value-only deltas
    /// never stale: matrix features and SpMV trial timings depend on
    /// the sparsity pattern alone, so the measured winner stands.
    pub fn decision_is_stale(&self) -> bool {
        self.decision_stale
    }

    /// Adopt a flush-path re-tune: store the outcome, un-stale, and
    /// drop the resident engine of the newly decided kind **only when
    /// it was built under a different grid** than the one the trials
    /// crowned (the slots record their build grid precisely for this
    /// comparison — rebuilding an identical engine would be pure
    /// waste). The next request then rebuilds with the crowned grid,
    /// so what the trials measured is what `Auto` traffic executes on.
    /// Other kinds keep their engines (an explicit request is
    /// grid-agnostic in meaning), and CSR ignores the grid entirely.
    fn adopt_tune(&mut self, outcome: TuneOutcome) {
        let new = outcome.decision;
        self.tune = outcome;
        self.decision_stale = false;
        match new.kind {
            EngineKind::Hbp => {
                if self.hbp.get().is_some_and(|(cfg, _)| *cfg != new.cfg) {
                    self.hbp = OnceLock::new();
                }
            }
            EngineKind::Plain2d => {
                if self.plain2d.get().is_some_and(|(cfg, _)| *cfg != new.cfg) {
                    self.plain2d = OnceLock::new();
                }
            }
            // the CSR-native kinds ignore the partition grid
            EngineKind::Csr | EngineKind::Flat | EngineKind::LineEnhance => {}
            EngineKind::Auto => unreachable!("decisions are concrete"),
        }
    }

    /// Partition config an engine of `kind` is built with: the tuned
    /// grid when this kind *is* the decision, the base config otherwise.
    fn cfg_for(&self, kind: EngineKind) -> PartitionConfig {
        if self.tune.decision.kind == kind {
            self.tune.decision.cfg
        } else {
            self.base_cfg
        }
    }

    /// The engine serving `kind`, built on first use.
    pub fn engine(&self, kind: EngineKind) -> &dyn SpmvEngine {
        match self.resolve(kind) {
            EngineKind::Hbp => {
                let (_, engine) = self.hbp.get_or_init(|| {
                    let cfg = self.cfg_for(EngineKind::Hbp);
                    let engine = HbpEngine::new_updatable(
                        self.m.clone(),
                        cfg,
                        Box::new(HashReorder::default()),
                        self.threads,
                        0.25,
                    );
                    (cfg, engine)
                });
                engine
            }
            EngineKind::Csr => {
                self.csr.get_or_init(|| CsrParallel::new(self.m.clone(), self.threads))
            }
            EngineKind::Plain2d => {
                let (_, engine) = self.plain2d.get_or_init(|| {
                    let cfg = self.cfg_for(EngineKind::Plain2d);
                    (cfg, Spmv2dEngine::new(self.m.clone(), cfg, self.threads))
                });
                engine
            }
            EngineKind::Flat => {
                self.flat.get_or_init(|| FlatEngine::new(self.m.clone(), self.threads))
            }
            EngineKind::LineEnhance => self
                .line_enhance
                .get_or_init(|| LineEnhanceEngine::new(self.m.clone(), self.threads)),
            EngineKind::Auto => unreachable!("resolve() never returns Auto"),
        }
    }

    /// Whether an engine of this kind has been built (`Auto` asks about
    /// the decided kind). Lazy-construction observability for tests and
    /// the `list` endpoint.
    pub fn is_built(&self, kind: EngineKind) -> bool {
        match self.resolve(kind) {
            EngineKind::Hbp => self.hbp.get().is_some(),
            EngineKind::Csr => self.csr.get().is_some(),
            EngineKind::Plain2d => self.plain2d.get().is_some(),
            EngineKind::Flat => self.flat.get().is_some(),
            EngineKind::LineEnhance => self.line_enhance.get().is_some(),
            EngineKind::Auto => unreachable!("resolve() never returns Auto"),
        }
    }

    /// The resident HBP engine's preprocessing phase profile
    /// (plan/reorder/fill wall-times), `None` when no HBP engine has
    /// been built — only HBP construction is profiled; the CSR and
    /// plain-2D baselines have no plan/fill pipeline to decompose.
    pub fn build_profile(&self) -> Option<crate::preprocess::BuildProfile> {
        self.hbp.get().and_then(|(_, e)| e.build_profile())
    }

    /// Engines currently resident.
    pub fn built_kinds(&self) -> Vec<EngineKind> {
        [
            EngineKind::Hbp,
            EngineKind::Csr,
            EngineKind::Plain2d,
            EngineKind::Flat,
            EngineKind::LineEnhance,
        ]
        .into_iter()
        .filter(|&k| self.is_built(k))
        .collect()
    }

    /// Apply a delta. The retained source validates and applies first —
    /// an invalid delta mutates nothing anywhere — then every engine
    /// that was actually built repairs its resident copy (identical
    /// pre-delta copies, so those repairs cannot fail). Engines not yet
    /// built need no repair: they will build from the updated source.
    ///
    /// The report comes from the most structure-aware engine resident:
    /// HBP (whose blocks-touched metric is the one the paper's format
    /// makes interesting), then the 2D baseline; with neither built no
    /// derived structure exists, so nothing is rebuilt and the report
    /// carries only the source-level change — `full_rebuild` stays
    /// false even for pattern-changing deltas (a rebuild that never ran
    /// must not inflate the `full_rebuilds` service metric).
    pub fn update(&mut self, delta: &MatrixDelta) -> Result<UpdateReport> {
        let change = apply_to_csr(&mut self.m, delta)?;
        let mut report = UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: 0,
            full_rebuild: false,
        };
        if let Some(csr) = self.csr.get_mut() {
            csr.update(delta).expect("csr engine diverged from source");
        }
        if let Some(flat) = self.flat.get_mut() {
            flat.update(delta).expect("flat engine diverged from source");
        }
        if let Some(line) = self.line_enhance.get_mut() {
            line.update(delta).expect("line-enhance engine diverged from source");
        }
        if let Some((_, plain2d)) = self.plain2d.get_mut() {
            report = plain2d.update(delta).expect("2d engine diverged from source");
        }
        if let Some((_, hbp)) = self.hbp.get_mut() {
            report = hbp.update(delta).expect("hbp engine diverged from source");
        }
        self.updates_applied += 1;
        // only a changed sparsity pattern can move the tuned winner:
        // features and trial timings are pattern-functions, so value
        // edits leave the measured decision valid (no re-tune, no
        // trial run on the serving path for the common delta kinds)
        self.decision_stale |= change.pattern_changed;
        Ok(report)
    }
}

/// The matrix registry.
pub struct Router {
    /// Worker threads the engines (and trials) run on.
    pub threads: usize,
    /// Base partition config; the tuner derives grid candidates from it.
    pub cfg: PartitionConfig,
    tuner: Tuner,
    matrices: BTreeMap<String, RwLock<PreparedMatrix>>,
}

impl Router {
    /// Router with an in-memory tuner (decisions cached for the process
    /// lifetime; re-registering identical content skips trials).
    pub fn new(cfg: PartitionConfig, threads: usize) -> Router {
        let threads = threads.max(1);
        Router { threads, cfg, tuner: Tuner::new(cfg, threads), matrices: BTreeMap::new() }
    }

    /// Router with a caller-configured tuner (persistent cache, custom
    /// trial budget).
    pub fn with_tuner(cfg: PartitionConfig, threads: usize, tuner: Tuner) -> Router {
        Router { threads: threads.max(1), cfg, tuner, matrices: BTreeMap::new() }
    }

    /// The tuner this router registers matrices through.
    pub fn tuner(&self) -> &Tuner {
        &self.tuner
    }

    /// Register a matrix: tune it (cache-hit or competitive trials),
    /// then build only the decided engine. Other engines build on the
    /// first request that forces them.
    pub fn register(&mut self, name: &str, m: Csr) -> Result<()> {
        let (rows, cols, nnz) = (m.rows, m.cols, m.nnz());
        let tune = self.tuner.tune(&m);
        let mut prepared = PreparedMatrix {
            name: name.to_string(),
            rows,
            cols,
            nnz,
            preprocess_secs: 0.0,
            updates_applied: 0,
            tune,
            decision_stale: false,
            base_cfg: self.cfg,
            threads: self.threads,
            m,
            hbp: OnceLock::new(),
            csr: OnceLock::new(),
            plain2d: OnceLock::new(),
            flat: OnceLock::new(),
            line_enhance: OnceLock::new(),
        };
        let (_, preprocess_secs) = crate::util::timer::time(|| {
            prepared.engine(EngineKind::Auto);
        });
        prepared.preprocess_secs = preprocess_secs;
        self.matrices.insert(name.to_string(), RwLock::new(prepared));
        Ok(())
    }

    /// Shared read access to a registered matrix (held for the duration
    /// of a request's execution; updates wait for it).
    pub fn get(&self, name: &str) -> Result<RwLockReadGuard<'_, PreparedMatrix>> {
        let lock = self
            .matrices
            .get(name)
            .ok_or_else(|| anyhow::Error::new(super::error::ServiceError::unknown_matrix(name)))?;
        Ok(lock.read().unwrap_or_else(|e| e.into_inner()))
    }

    /// Registered matrix names, in sorted order.
    pub fn names(&self) -> Vec<&str> {
        self.matrices.keys().map(|s| s.as_str()).collect()
    }

    /// Cheap, non-blocking decision lookup for batch grouping: the
    /// concrete engine kind the matrix's `Auto` requests resolve to,
    /// or [`EngineKind::Auto`] when resolution must be deferred — the
    /// matrix is unknown, its entry is write-locked (an update is in
    /// flight), or its decision is stale. Never builds an engine and
    /// never runs a trial, so the batcher can call it on every enqueue.
    ///
    /// # Example
    ///
    /// ```
    /// use hbp_spmv::coordinator::{EngineKind, Router};
    /// use hbp_spmv::partition::PartitionConfig;
    ///
    /// let mut router = Router::new(PartitionConfig::test_small(), 1);
    /// router.register("m", hbp_spmv::gen::random::uniform(8, 8, 0.5, 1)).unwrap();
    /// // registration tuned the matrix, so resolution is concrete…
    /// assert_ne!(router.resolve("m"), EngineKind::Auto);
    /// // …and an unknown matrix defers (the error surfaces at execution)
    /// assert_eq!(router.resolve("ghost"), EngineKind::Auto);
    /// ```
    pub fn resolve(&self, matrix: &str) -> EngineKind {
        let Some(lock) = self.matrices.get(matrix) else {
            return EngineKind::Auto;
        };
        match lock.try_read() {
            Ok(p) if !p.decision_is_stale() => p.resolved_kind(),
            Ok(_) => EngineKind::Auto,
            Err(std::sync::TryLockError::Poisoned(e)) => {
                let p = e.into_inner();
                if p.decision_is_stale() { EngineKind::Auto } else { p.resolved_kind() }
            }
            Err(std::sync::TryLockError::WouldBlock) => EngineKind::Auto,
        }
    }

    /// Resolve a deferred decision, re-tuning if a pattern-changing
    /// delta staled it: the fresh path is a shared read, the stale path
    /// takes the matrix's write lock, re-runs the tuner on the
    /// *current* content, and adopts the outcome (dropping a resident
    /// engine whose grid the new decision superseded). Returns the
    /// concrete kind plus the re-tune outcome when one ran, so the
    /// caller can record it in the service metrics.
    pub fn resolve_blocking(&self, matrix: &str) -> Result<(EngineKind, Option<TuneOutcome>)> {
        let lock = self
            .matrices
            .get(matrix)
            .ok_or_else(|| anyhow::Error::new(super::error::ServiceError::unknown_matrix(matrix)))?;
        {
            let p = lock.read().unwrap_or_else(|e| e.into_inner());
            if !p.decision_is_stale() {
                return Ok((p.resolved_kind(), None));
            }
        }
        let mut p = lock.write().unwrap_or_else(|e| e.into_inner());
        if !p.decision_is_stale() {
            // another flush re-resolved while we waited for the lock
            return Ok((p.resolved_kind(), None));
        }
        let outcome = self.tuner.tune(&p.m);
        p.adopt_tune(outcome.clone());
        Ok((p.resolved_kind(), Some(outcome)))
    }

    /// Apply a delta to a hosted matrix. Exclusive: waits for in-flight
    /// requests on this matrix, blocks new ones until done.
    pub fn update(&self, name: &str, delta: &MatrixDelta) -> Result<UpdateReport> {
        let lock = self
            .matrices
            .get(name)
            .ok_or_else(|| anyhow::Error::new(super::error::ServiceError::unknown_matrix(name)))?;
        lock.write().unwrap_or_else(|e| e.into_inner()).update(delta)
    }

    /// Route one SpMV request.
    pub fn spmv(&self, matrix: &str, kind: EngineKind, x: &[f64]) -> Result<Vec<f64>> {
        let m = self.get(matrix)?;
        anyhow::ensure!(
            x.len() == m.cols,
            "vector length {} != matrix cols {}",
            x.len(),
            m.cols
        );
        let mut y = vec![0.0; m.rows];
        m.engine(kind).spmv(x, &mut y);
        Ok(y)
    }

    /// Route a batch against one (matrix, engine): the engines' SpMM
    /// path reuses each matrix element across the whole batch.
    pub fn spmm(&self, matrix: &str, kind: EngineKind, xs: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        let m = self.get(matrix)?;
        for (i, x) in xs.iter().enumerate() {
            anyhow::ensure!(
                x.len() == m.cols,
                "batch vector {i} length {} != matrix cols {}",
                x.len(),
                m.cols
            );
        }
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; m.rows]).collect();
        m.engine(kind).spmm(&xs, &mut ys);
        Ok(ys)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    fn router_with(name: &str, m: Csr) -> Router {
        let mut r = Router::new(PartitionConfig::test_small(), 2);
        r.register(name, m).unwrap();
        r
    }

    #[test]
    fn register_and_route_all_engines() {
        let m = random::power_law_rows(100, 80, 2.0, 20, 3);
        let r = router_with("t", m.clone());
        let x = random::vector(80, 1);
        let mut expect = vec![0.0; 100];
        m.spmv(&x, &mut expect);
        for kind in [
            EngineKind::Hbp,
            EngineKind::Csr,
            EngineKind::Plain2d,
            EngineKind::Flat,
            EngineKind::LineEnhance,
            EngineKind::Auto,
        ] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?}");
        }
    }

    #[test]
    fn register_builds_only_the_decided_engine() {
        let m = random::power_law_rows(100, 80, 2.0, 20, 5);
        let r = router_with("t", m);
        let p = r.get("t").unwrap();
        let decided = p.resolved_kind();
        assert_ne!(decided, EngineKind::Auto, "decision must be concrete");
        assert_eq!(p.built_kinds(), vec![decided], "only the decision builds eagerly");
        assert!(p.preprocess_secs >= 0.0);
        drop(p);
        // forcing another kind builds it lazily, exactly once
        let other = if decided == EngineKind::Csr { EngineKind::Hbp } else { EngineKind::Csr };
        let x = random::vector(80, 2);
        r.spmv("t", other, &x).unwrap();
        let p = r.get("t").unwrap();
        assert!(p.is_built(other), "forced kind must now be resident");
        assert_eq!(p.built_kinds().len(), 2);
    }

    #[test]
    fn auto_is_bit_identical_to_the_forced_winner() {
        let m = random::power_law_rows(120, 90, 2.0, 25, 7);
        let r = router_with("t", m);
        let p = r.get("t").unwrap();
        let winner = p.resolved_kind();
        drop(p);
        let x = random::vector(90, 3);
        let auto = r.spmv("t", EngineKind::Auto, &x).unwrap();
        let forced = r.spmv("t", winner, &x).unwrap();
        assert_eq!(auto, forced, "Auto must route to the same resident engine");
    }

    #[test]
    fn reregistering_identical_content_hits_the_tune_cache() {
        let m = random::power_law_rows(80, 70, 2.0, 20, 11);
        let mut r = Router::new(PartitionConfig::test_small(), 2);
        r.register("a", m.clone()).unwrap();
        r.register("b", m).unwrap();
        let a = r.get("a").unwrap();
        let b = r.get("b").unwrap();
        assert!(!a.tune.cache_hit, "first registration runs trials");
        assert!(a.tune.report.is_some());
        assert!(b.tune.cache_hit, "identical content must skip trials");
        assert!(b.tune.report.is_none(), "cache hit means no second trial run");
        assert_eq!(a.tune.decision, b.tune.decision);
    }

    #[test]
    fn engine_kind_round_trips_through_display_and_fromstr() {
        for kind in [
            EngineKind::Hbp,
            EngineKind::Csr,
            EngineKind::Plain2d,
            EngineKind::Flat,
            EngineKind::LineEnhance,
            EngineKind::Auto,
        ] {
            let s = kind.to_string();
            assert_eq!(s.parse::<EngineKind>().unwrap(), kind, "{s}");
        }
        let err = "warp".parse::<EngineKind>().unwrap_err();
        let msg = format!("{err:#}");
        for name in ["hbp", "csr", "2d", "flat", "line-enhance", "auto"] {
            assert!(msg.contains(name), "error must list {name}: {msg}");
        }
    }

    #[test]
    fn errors_are_clear() {
        let m = random::uniform(10, 10, 0.5, 1);
        let r = router_with("t", m);
        assert!(r.spmv("missing", EngineKind::Hbp, &vec![0.0; 10]).is_err());
        assert!(r.spmv("t", EngineKind::Hbp, &vec![0.0; 5]).is_err());
        assert!("warp".parse::<EngineKind>().is_err());
        assert_eq!("2d".parse::<EngineKind>().unwrap(), EngineKind::Plain2d);
    }

    #[test]
    fn registry_lists_names() {
        let mut r = Router::new(PartitionConfig::test_small(), 1);
        r.register("a", random::uniform(5, 5, 0.5, 1)).unwrap();
        r.register("b", random::uniform(5, 5, 0.5, 2)).unwrap();
        assert_eq!(r.names(), vec!["a", "b"]);
        assert!(r.get("a").unwrap().preprocess_secs >= 0.0);
    }

    /// A delta that rewrites one row's columns (same nonzero count,
    /// different pattern) — the kind of change that CAN move the tuned
    /// winner.
    fn pattern_changing_delta(m: &Csr) -> MatrixDelta {
        let row = (0..m.rows).find(|&i| m.row_nnz(i) >= 1).unwrap();
        let (cols, vals) = m.row(row);
        let unused = (0..m.cols as u32).find(|c| cols.binary_search(c).is_err()).unwrap();
        let mut new_cols = cols.to_vec();
        new_cols[0] = unused;
        new_cols.sort_unstable();
        MatrixDelta::new().replace_row(row, new_cols, vals.to_vec())
    }

    #[test]
    fn resolve_is_concrete_when_fresh_and_defers_when_stale() {
        let m = random::power_law_rows(70, 60, 2.0, 15, 23);
        let r = router_with("t", m.clone());
        let decided = r.get("t").unwrap().resolved_kind();
        assert_eq!(r.resolve("t"), decided, "fresh decision resolves concretely");
        assert_eq!(r.resolve("ghost"), EngineKind::Auto, "unknown matrix defers");

        let delta = pattern_changing_delta(&m);
        r.update("t", &delta).unwrap();
        assert!(r.get("t").unwrap().decision_is_stale(), "pattern change stales");
        assert_eq!(r.resolve("t"), EngineKind::Auto, "stale decision defers");

        // blocking resolution re-tunes the changed content and un-stales
        let (kind, outcome) = r.resolve_blocking("t").unwrap();
        assert_ne!(kind, EngineKind::Auto);
        let outcome = outcome.expect("stale decision must re-tune");
        assert!(!outcome.cache_hit, "changed content must re-measure");
        assert!(!r.get("t").unwrap().decision_is_stale());
        assert_eq!(r.resolve("t"), kind, "resolution is concrete again");
        // a second blocking resolve is the fresh fast path
        let (again, none) = r.resolve_blocking("t").unwrap();
        assert_eq!(again, kind);
        assert!(none.is_none(), "fresh decision must not re-tune");

        // whatever the re-tune decided (possibly dropping a resident
        // engine built under a superseded grid), Auto serves the
        // mutated matrix exactly
        let mut mutated = m.clone();
        apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(60, 17);
        let mut expect = vec![0.0; 70];
        mutated.spmv(&x, &mut expect);
        let y = r.spmv("t", EngineKind::Auto, &x).unwrap();
        assert!(allclose(&y, &expect, 1e-10, 1e-12), "re-tuned Auto serves post-delta values");
    }

    #[test]
    fn value_only_deltas_keep_the_decision_fresh() {
        let m = random::power_law_rows(60, 50, 2.0, 12, 29);
        let r = router_with("t", m.clone());
        let before = r.get("t").unwrap().tune.decision;
        let row = (0..60).find(|&i| m.row_nnz(i) >= 1).unwrap();
        // values move, pattern doesn't: the measured winner still stands,
        // so the serving path must not pay a re-tune for this
        let delta = MatrixDelta::new().scale_row(row, 2.0).zero_row(59.min(row + 1));
        r.update("t", &delta).unwrap();
        assert!(!r.get("t").unwrap().decision_is_stale(), "value edits must not stale");
        assert_eq!(r.resolve("t"), before.kind, "resolution stays concrete");
        let (kind, outcome) = r.resolve_blocking("t").unwrap();
        assert_eq!(kind, before.kind);
        assert!(outcome.is_none(), "no re-tune for a value-only delta");
    }

    #[test]
    fn resolve_blocking_errors_on_unknown_matrix() {
        let r = router_with("t", random::uniform(10, 10, 0.4, 6));
        assert!(r.resolve_blocking("ghost").is_err());
    }

    #[test]
    fn update_keeps_every_engine_coherent() {
        let m = random::power_law_rows(90, 70, 2.0, 20, 7);
        let r = router_with("t", m.clone());
        let row = (0..90).find(|&i| m.row_nnz(i) >= 1).unwrap();
        let delta = MatrixDelta::new().scale_row(row, 2.0).zero_row(89.min(row + 1));
        let report = r.update("t", &delta).unwrap();
        assert!(report.blocks_touched <= report.blocks_total);
        assert_eq!(r.get("t").unwrap().updates_applied, 1);
        // all engines — including those built only after the update —
        // agree on the mutated matrix
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(70, 5);
        let mut expect = vec![0.0; 90];
        mutated.spmv(&x, &mut expect);
        for kind in [
            EngineKind::Hbp,
            EngineKind::Csr,
            EngineKind::Plain2d,
            EngineKind::Flat,
            EngineKind::LineEnhance,
        ] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?} after update");
        }
    }

    #[test]
    fn update_repairs_only_built_engines_lazily_built_ones_catch_up() {
        let m = random::power_law_rows(80, 60, 2.0, 15, 19);
        let r = router_with("t", m.clone());
        let built_before = r.get("t").unwrap().built_kinds();
        assert_eq!(built_before.len(), 1, "register builds one engine");

        let row = (0..80).find(|&i| m.row_nnz(i) >= 1).unwrap();
        r.update("t", &MatrixDelta::new().scale_row(row, -3.0)).unwrap();
        assert_eq!(
            r.get("t").unwrap().built_kinds(),
            built_before,
            "an update must not force unbuilt engines into existence"
        );

        // a kind first built *after* the update serves the updated values
        let unbuilt = [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d]
            .into_iter()
            .find(|k| !built_before.contains(k))
            .unwrap();
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &MatrixDelta::new().scale_row(row, -3.0))
            .unwrap();
        let x = random::vector(60, 9);
        let mut expect = vec![0.0; 80];
        mutated.spmv(&x, &mut expect);
        let y = r.spmv("t", unbuilt, &x).unwrap();
        assert!(allclose(&y, &expect, 1e-10, 1e-12), "{unbuilt:?} built from stale source");
    }

    #[test]
    fn update_errors_leave_registry_serving() {
        let m = random::uniform(10, 10, 0.5, 2);
        let r = router_with("t", m.clone());
        assert!(r.update("missing", &MatrixDelta::new().zero_row(0)).is_err());
        assert!(r.update("t", &MatrixDelta::new().zero_row(10)).is_err());
        assert_eq!(r.get("t").unwrap().updates_applied, 0);
        let x = random::vector(10, 1);
        let mut expect = vec![0.0; 10];
        m.spmv(&x, &mut expect);
        let y = r.spmv("t", EngineKind::Hbp, &x).unwrap();
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
    }

    #[test]
    fn concurrent_updates_and_reads_stay_consistent() {
        let m = random::power_law_rows(60, 60, 2.0, 15, 9);
        let r = std::sync::Arc::new(router_with("t", m.clone()));
        let row = (0..60).find(|&i| m.row_nnz(i) >= 1).unwrap();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let r = r.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        // factor 1.0: idempotent, so readers always see a
                        // matrix equal to the original
                        r.update("t", &MatrixDelta::new().scale_row(row, 1.0)).unwrap();
                    }
                });
            }
            for t in 0..3 {
                let r = r.clone();
                let m = &m;
                s.spawn(move || {
                    let x = random::vector(60, t);
                    let mut expect = vec![0.0; 60];
                    m.spmv(&x, &mut expect);
                    for _ in 0..10 {
                        let y = r.spmv("t", EngineKind::Hbp, &x).unwrap();
                        assert!(allclose(&y, &expect, 1e-10, 1e-12));
                    }
                });
            }
        });
        assert_eq!(r.get("t").unwrap().updates_applied, 20);
    }
}

//! Matrix registry + engine routing.
//!
//! A registered matrix is preprocessed once (the HBP build *is* the
//! paper's cheap preprocessing step) and then serves SpMV requests
//! through whichever engine the request names — the pure-rust HBP
//! engine (default), the CSR/2D baselines, or the PJRT/AOT path.
//!
//! Each entry sits behind its own `RwLock`: SpMV traffic takes shared
//! read locks, and a [`Router::update`] takes the write lock for just
//! that matrix — an update is atomic with respect to every in-flight
//! request against the same matrix and invisible to all others.

use crate::exec::{CsrParallel, HbpEngine, SpmvEngine, Spmv2dEngine};
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use crate::preprocess::{HashReorder, MatrixDelta, UpdateReport};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{RwLock, RwLockReadGuard};

/// Which engine executes a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Hbp,
    Csr,
    Plain2d,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "hbp" => Ok(EngineKind::Hbp),
            "csr" => Ok(EngineKind::Csr),
            "2d" => Ok(EngineKind::Plain2d),
            other => bail!("unknown engine {other:?} (expected hbp|csr|2d)"),
        }
    }
}

/// A registered, preprocessed matrix.
pub struct PreparedMatrix {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub preprocess_secs: f64,
    /// Deltas applied since registration.
    pub updates_applied: u64,
    hbp: HbpEngine,
    csr: CsrParallel,
    plain2d: Spmv2dEngine,
}

impl PreparedMatrix {
    pub fn engine(&self, kind: EngineKind) -> &dyn SpmvEngine {
        match kind {
            EngineKind::Hbp => &self.hbp,
            EngineKind::Csr => &self.csr,
            EngineKind::Plain2d => &self.plain2d,
        }
    }

    pub fn hbp(&self) -> &HbpEngine {
        &self.hbp
    }

    /// Apply a delta to **every** engine's resident copy, so whichever
    /// engine a later request names serves the updated values. The HBP
    /// engine's incremental repair supplies the report (its
    /// blocks-touched metric is the one the paper's format makes
    /// interesting); the CSR/2D copies apply the same value writes.
    pub fn update(&mut self, delta: &MatrixDelta) -> Result<UpdateReport> {
        let report = self.hbp.update(delta)?;
        // identical pre-delta copies: the same validated delta cannot
        // fail on the baselines
        self.csr
            .update(delta)
            .expect("csr engine diverged from hbp source");
        self.plain2d
            .update(delta)
            .expect("2d engine diverged from hbp source");
        self.updates_applied += 1;
        Ok(report)
    }
}

/// The matrix registry.
pub struct Router {
    pub threads: usize,
    pub cfg: PartitionConfig,
    matrices: BTreeMap<String, RwLock<PreparedMatrix>>,
}

impl Router {
    pub fn new(cfg: PartitionConfig, threads: usize) -> Router {
        Router { threads: threads.max(1), cfg, matrices: BTreeMap::new() }
    }

    /// Register a matrix: builds the updatable HBP engine (parallel,
    /// hash reorder) and the baseline engines.
    pub fn register(&mut self, name: &str, m: Csr) -> Result<()> {
        let (rows, cols, nnz) = (m.rows, m.cols, m.nnz());
        let csr = CsrParallel::new(m.clone(), self.threads);
        let plain2d = Spmv2dEngine::new(m.clone(), self.cfg, self.threads);
        let (hbp, preprocess_secs) = crate::util::timer::time(|| {
            HbpEngine::new_updatable(
                m,
                self.cfg,
                Box::new(HashReorder::default()),
                self.threads,
                0.25,
            )
        });
        let prepared = PreparedMatrix {
            name: name.to_string(),
            rows,
            cols,
            nnz,
            preprocess_secs,
            updates_applied: 0,
            hbp,
            csr,
            plain2d,
        };
        self.matrices.insert(name.to_string(), RwLock::new(prepared));
        Ok(())
    }

    /// Shared read access to a registered matrix (held for the duration
    /// of a request's execution; updates wait for it).
    pub fn get(&self, name: &str) -> Result<RwLockReadGuard<'_, PreparedMatrix>> {
        let lock = self
            .matrices
            .get(name)
            .with_context(|| format!("matrix {name:?} not registered"))?;
        Ok(lock.read().unwrap_or_else(|e| e.into_inner()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.matrices.keys().map(|s| s.as_str()).collect()
    }

    /// Apply a delta to a hosted matrix. Exclusive: waits for in-flight
    /// requests on this matrix, blocks new ones until done.
    pub fn update(&self, name: &str, delta: &MatrixDelta) -> Result<UpdateReport> {
        let lock = self
            .matrices
            .get(name)
            .with_context(|| format!("matrix {name:?} not registered"))?;
        lock.write().unwrap_or_else(|e| e.into_inner()).update(delta)
    }

    /// Route one SpMV request.
    pub fn spmv(&self, matrix: &str, kind: EngineKind, x: &[f64]) -> Result<Vec<f64>> {
        let m = self.get(matrix)?;
        anyhow::ensure!(
            x.len() == m.cols,
            "vector length {} != matrix cols {}",
            x.len(),
            m.cols
        );
        let mut y = vec![0.0; m.rows];
        m.engine(kind).spmv(x, &mut y);
        Ok(y)
    }

    /// Route a batch against one (matrix, engine): the engines' SpMM
    /// path reuses each matrix element across the whole batch.
    pub fn spmm(&self, matrix: &str, kind: EngineKind, xs: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        let m = self.get(matrix)?;
        for (i, x) in xs.iter().enumerate() {
            anyhow::ensure!(
                x.len() == m.cols,
                "batch vector {i} length {} != matrix cols {}",
                x.len(),
                m.cols
            );
        }
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; m.rows]).collect();
        m.engine(kind).spmm(&xs, &mut ys);
        Ok(ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    fn router_with(name: &str, m: Csr) -> Router {
        let mut r = Router::new(PartitionConfig::test_small(), 2);
        r.register(name, m).unwrap();
        r
    }

    #[test]
    fn register_and_route_all_engines() {
        let m = random::power_law_rows(100, 80, 2.0, 20, 3);
        let r = router_with("t", m.clone());
        let x = random::vector(80, 1);
        let mut expect = vec![0.0; 100];
        m.spmv(&x, &mut expect);
        for kind in [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?}");
        }
    }

    #[test]
    fn errors_are_clear() {
        let m = random::uniform(10, 10, 0.5, 1);
        let r = router_with("t", m);
        assert!(r.spmv("missing", EngineKind::Hbp, &vec![0.0; 10]).is_err());
        assert!(r.spmv("t", EngineKind::Hbp, &vec![0.0; 5]).is_err());
        assert!(EngineKind::parse("warp").is_err());
        assert_eq!(EngineKind::parse("2d").unwrap(), EngineKind::Plain2d);
    }

    #[test]
    fn registry_lists_names() {
        let mut r = Router::new(PartitionConfig::test_small(), 1);
        r.register("a", random::uniform(5, 5, 0.5, 1)).unwrap();
        r.register("b", random::uniform(5, 5, 0.5, 2)).unwrap();
        assert_eq!(r.names(), vec!["a", "b"]);
        assert!(r.get("a").unwrap().preprocess_secs >= 0.0);
    }

    #[test]
    fn update_keeps_every_engine_coherent() {
        let m = random::power_law_rows(90, 70, 2.0, 20, 7);
        let r = router_with("t", m.clone());
        let row = (0..90).find(|&i| m.row_nnz(i) >= 1).unwrap();
        let delta = MatrixDelta::new().scale_row(row, 2.0).zero_row(89.min(row + 1));
        let report = r.update("t", &delta).unwrap();
        assert!(report.blocks_touched <= report.blocks_total);
        assert_eq!(r.get("t").unwrap().updates_applied, 1);
        // all three engines agree on the mutated matrix
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(70, 5);
        let mut expect = vec![0.0; 90];
        mutated.spmv(&x, &mut expect);
        for kind in [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?} after update");
        }
    }

    #[test]
    fn update_errors_leave_registry_serving() {
        let m = random::uniform(10, 10, 0.5, 2);
        let r = router_with("t", m.clone());
        assert!(r.update("missing", &MatrixDelta::new().zero_row(0)).is_err());
        assert!(r.update("t", &MatrixDelta::new().zero_row(10)).is_err());
        assert_eq!(r.get("t").unwrap().updates_applied, 0);
        let x = random::vector(10, 1);
        let mut expect = vec![0.0; 10];
        m.spmv(&x, &mut expect);
        let y = r.spmv("t", EngineKind::Hbp, &x).unwrap();
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
    }

    #[test]
    fn concurrent_updates_and_reads_stay_consistent() {
        let m = random::power_law_rows(60, 60, 2.0, 15, 9);
        let r = std::sync::Arc::new(router_with("t", m.clone()));
        let row = (0..60).find(|&i| m.row_nnz(i) >= 1).unwrap();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let r = r.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        // factor 1.0: idempotent, so readers always see a
                        // matrix equal to the original
                        r.update("t", &MatrixDelta::new().scale_row(row, 1.0)).unwrap();
                    }
                });
            }
            for t in 0..3 {
                let r = r.clone();
                let m = &m;
                s.spawn(move || {
                    let x = random::vector(60, t);
                    let mut expect = vec![0.0; 60];
                    m.spmv(&x, &mut expect);
                    for _ in 0..10 {
                        let y = r.spmv("t", EngineKind::Hbp, &x).unwrap();
                        assert!(allclose(&y, &expect, 1e-10, 1e-12));
                    }
                });
            }
        });
        assert_eq!(r.get("t").unwrap().updates_applied, 20);
    }
}

//! Matrix registry + engine routing, with autotuned lazy engines.
//!
//! Registering a matrix runs the [`crate::tune::Tuner`] (features →
//! cost model → competitive trials, short-circuited by the context-keyed
//! content-hash cache) and eagerly builds **only the decided engine**;
//! the other engines build lazily on the first request that names them.
//! This replaces the old eager triple-build: a cache-hit registration
//! pays exactly one preprocessing pass, and a cold one pays the trial
//! builds plus one (trial engines are measurement throwaways — the
//! resident HBP is rebuilt in updatable form, which trials don't need).
//!
//! `EngineKind::Auto` requests resolve to the tuned decision per
//! matrix; explicit kinds still force a specific engine.
//!
//! Each entry sits behind its own `RwLock`: SpMV traffic takes shared
//! read locks, and a [`Router::update`] takes the write lock for just
//! that matrix — an update is atomic with respect to every in-flight
//! request against the same matrix and invisible to all others. Updates
//! repair only the engines that were actually built; the retained
//! source CSR keeps lazily-built engines consistent afterwards.

use crate::exec::{CsrParallel, HbpEngine, SpmvEngine, Spmv2dEngine};
use crate::formats::Csr;
use crate::partition::PartitionConfig;
use crate::preprocess::{apply_to_csr, HashReorder, MatrixDelta, UpdateReport};
use crate::tune::{TuneOutcome, Tuner};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::{OnceLock, RwLock, RwLockReadGuard};

/// Which engine executes a request. `Auto` defers to the per-matrix
/// tuned decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Hbp,
    Csr,
    Plain2d,
    Auto,
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<EngineKind> {
        match s {
            "hbp" => Ok(EngineKind::Hbp),
            "csr" => Ok(EngineKind::Csr),
            "2d" => Ok(EngineKind::Plain2d),
            "auto" => Ok(EngineKind::Auto),
            other => bail!("unknown engine {other:?} (expected one of: hbp, csr, 2d, auto)"),
        }
    }
}

/// Round-trips with the `FromStr` impl: `kind.to_string().parse()` is
/// the identity, so CLI and server output feed back in unchanged.
impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineKind::Hbp => "hbp",
            EngineKind::Csr => "csr",
            EngineKind::Plain2d => "2d",
            EngineKind::Auto => "auto",
        })
    }
}

/// A registered matrix: tuned decision, retained source, and lazily
/// built engines.
pub struct PreparedMatrix {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Build time of the decided engine (the registration cost).
    pub preprocess_secs: f64,
    /// Deltas applied since registration.
    pub updates_applied: u64,
    /// What the tuner learned at registration (decision, features,
    /// trial record, cache hit) — served by the `tune` protocol op.
    pub tune: TuneOutcome,
    base_cfg: PartitionConfig,
    threads: usize,
    /// Source CSR, kept in lock-step with every built engine so a
    /// lazily built engine always starts from the current values.
    m: Csr,
    hbp: OnceLock<HbpEngine>,
    csr: OnceLock<CsrParallel>,
    plain2d: OnceLock<Spmv2dEngine>,
}

impl PreparedMatrix {
    /// Resolve `Auto` to the tuned decision; explicit kinds pass through.
    pub fn resolve(&self, kind: EngineKind) -> EngineKind {
        match kind {
            EngineKind::Auto => self.tune.decision.kind,
            k => k,
        }
    }

    /// The concrete engine kind `Auto` requests execute on.
    pub fn resolved_kind(&self) -> EngineKind {
        self.resolve(EngineKind::Auto)
    }

    /// Partition config an engine of `kind` is built with: the tuned
    /// grid when this kind *is* the decision, the base config otherwise.
    fn cfg_for(&self, kind: EngineKind) -> PartitionConfig {
        if self.tune.decision.kind == kind {
            self.tune.decision.cfg
        } else {
            self.base_cfg
        }
    }

    /// The engine serving `kind`, built on first use.
    pub fn engine(&self, kind: EngineKind) -> &dyn SpmvEngine {
        match self.resolve(kind) {
            EngineKind::Hbp => self.hbp.get_or_init(|| {
                HbpEngine::new_updatable(
                    self.m.clone(),
                    self.cfg_for(EngineKind::Hbp),
                    Box::new(HashReorder::default()),
                    self.threads,
                    0.25,
                )
            }),
            EngineKind::Csr => {
                self.csr.get_or_init(|| CsrParallel::new(self.m.clone(), self.threads))
            }
            EngineKind::Plain2d => self.plain2d.get_or_init(|| {
                Spmv2dEngine::new(self.m.clone(), self.cfg_for(EngineKind::Plain2d), self.threads)
            }),
            EngineKind::Auto => unreachable!("resolve() never returns Auto"),
        }
    }

    /// Whether an engine of this kind has been built (`Auto` asks about
    /// the decided kind). Lazy-construction observability for tests and
    /// the `list` endpoint.
    pub fn is_built(&self, kind: EngineKind) -> bool {
        match self.resolve(kind) {
            EngineKind::Hbp => self.hbp.get().is_some(),
            EngineKind::Csr => self.csr.get().is_some(),
            EngineKind::Plain2d => self.plain2d.get().is_some(),
            EngineKind::Auto => unreachable!("resolve() never returns Auto"),
        }
    }

    /// Engines currently resident.
    pub fn built_kinds(&self) -> Vec<EngineKind> {
        [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d]
            .into_iter()
            .filter(|&k| self.is_built(k))
            .collect()
    }

    /// Apply a delta. The retained source validates and applies first —
    /// an invalid delta mutates nothing anywhere — then every engine
    /// that was actually built repairs its resident copy (identical
    /// pre-delta copies, so those repairs cannot fail). Engines not yet
    /// built need no repair: they will build from the updated source.
    ///
    /// The report comes from the most structure-aware engine resident:
    /// HBP (whose blocks-touched metric is the one the paper's format
    /// makes interesting), then the 2D baseline; with neither built no
    /// derived structure exists, so nothing is rebuilt and the report
    /// carries only the source-level change — `full_rebuild` stays
    /// false even for pattern-changing deltas (a rebuild that never ran
    /// must not inflate the `full_rebuilds` service metric).
    pub fn update(&mut self, delta: &MatrixDelta) -> Result<UpdateReport> {
        let change = apply_to_csr(&mut self.m, delta)?;
        let mut report = UpdateReport {
            rows_touched: change.touched_rows.len(),
            blocks_touched: 0,
            blocks_total: 0,
            full_rebuild: false,
        };
        if let Some(csr) = self.csr.get_mut() {
            csr.update(delta).expect("csr engine diverged from source");
        }
        if let Some(plain2d) = self.plain2d.get_mut() {
            report = plain2d.update(delta).expect("2d engine diverged from source");
        }
        if let Some(hbp) = self.hbp.get_mut() {
            report = hbp.update(delta).expect("hbp engine diverged from source");
        }
        self.updates_applied += 1;
        Ok(report)
    }
}

/// The matrix registry.
pub struct Router {
    pub threads: usize,
    pub cfg: PartitionConfig,
    tuner: Tuner,
    matrices: BTreeMap<String, RwLock<PreparedMatrix>>,
}

impl Router {
    /// Router with an in-memory tuner (decisions cached for the process
    /// lifetime; re-registering identical content skips trials).
    pub fn new(cfg: PartitionConfig, threads: usize) -> Router {
        let threads = threads.max(1);
        Router { threads, cfg, tuner: Tuner::new(cfg, threads), matrices: BTreeMap::new() }
    }

    /// Router with a caller-configured tuner (persistent cache, custom
    /// trial budget).
    pub fn with_tuner(cfg: PartitionConfig, threads: usize, tuner: Tuner) -> Router {
        Router { threads: threads.max(1), cfg, tuner, matrices: BTreeMap::new() }
    }

    pub fn tuner(&self) -> &Tuner {
        &self.tuner
    }

    /// Register a matrix: tune it (cache-hit or competitive trials),
    /// then build only the decided engine. Other engines build on the
    /// first request that forces them.
    pub fn register(&mut self, name: &str, m: Csr) -> Result<()> {
        let (rows, cols, nnz) = (m.rows, m.cols, m.nnz());
        let tune = self.tuner.tune(&m);
        let mut prepared = PreparedMatrix {
            name: name.to_string(),
            rows,
            cols,
            nnz,
            preprocess_secs: 0.0,
            updates_applied: 0,
            tune,
            base_cfg: self.cfg,
            threads: self.threads,
            m,
            hbp: OnceLock::new(),
            csr: OnceLock::new(),
            plain2d: OnceLock::new(),
        };
        let (_, preprocess_secs) = crate::util::timer::time(|| {
            prepared.engine(EngineKind::Auto);
        });
        prepared.preprocess_secs = preprocess_secs;
        self.matrices.insert(name.to_string(), RwLock::new(prepared));
        Ok(())
    }

    /// Shared read access to a registered matrix (held for the duration
    /// of a request's execution; updates wait for it).
    pub fn get(&self, name: &str) -> Result<RwLockReadGuard<'_, PreparedMatrix>> {
        let lock = self
            .matrices
            .get(name)
            .with_context(|| format!("matrix {name:?} not registered"))?;
        Ok(lock.read().unwrap_or_else(|e| e.into_inner()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.matrices.keys().map(|s| s.as_str()).collect()
    }

    /// Apply a delta to a hosted matrix. Exclusive: waits for in-flight
    /// requests on this matrix, blocks new ones until done.
    pub fn update(&self, name: &str, delta: &MatrixDelta) -> Result<UpdateReport> {
        let lock = self
            .matrices
            .get(name)
            .with_context(|| format!("matrix {name:?} not registered"))?;
        lock.write().unwrap_or_else(|e| e.into_inner()).update(delta)
    }

    /// Route one SpMV request.
    pub fn spmv(&self, matrix: &str, kind: EngineKind, x: &[f64]) -> Result<Vec<f64>> {
        let m = self.get(matrix)?;
        anyhow::ensure!(
            x.len() == m.cols,
            "vector length {} != matrix cols {}",
            x.len(),
            m.cols
        );
        let mut y = vec![0.0; m.rows];
        m.engine(kind).spmv(x, &mut y);
        Ok(y)
    }

    /// Route a batch against one (matrix, engine): the engines' SpMM
    /// path reuses each matrix element across the whole batch.
    pub fn spmm(&self, matrix: &str, kind: EngineKind, xs: Vec<Vec<f64>>) -> Result<Vec<Vec<f64>>> {
        let m = self.get(matrix)?;
        for (i, x) in xs.iter().enumerate() {
            anyhow::ensure!(
                x.len() == m.cols,
                "batch vector {i} length {} != matrix cols {}",
                x.len(),
                m.cols
            );
        }
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; m.rows]).collect();
        m.engine(kind).spmm(&xs, &mut ys);
        Ok(ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::dense::allclose;
    use crate::gen::random;

    fn router_with(name: &str, m: Csr) -> Router {
        let mut r = Router::new(PartitionConfig::test_small(), 2);
        r.register(name, m).unwrap();
        r
    }

    #[test]
    fn register_and_route_all_engines() {
        let m = random::power_law_rows(100, 80, 2.0, 20, 3);
        let r = router_with("t", m.clone());
        let x = random::vector(80, 1);
        let mut expect = vec![0.0; 100];
        m.spmv(&x, &mut expect);
        for kind in [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d, EngineKind::Auto] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?}");
        }
    }

    #[test]
    fn register_builds_only_the_decided_engine() {
        let m = random::power_law_rows(100, 80, 2.0, 20, 5);
        let r = router_with("t", m);
        let p = r.get("t").unwrap();
        let decided = p.resolved_kind();
        assert_ne!(decided, EngineKind::Auto, "decision must be concrete");
        assert_eq!(p.built_kinds(), vec![decided], "only the decision builds eagerly");
        assert!(p.preprocess_secs >= 0.0);
        drop(p);
        // forcing another kind builds it lazily, exactly once
        let other = if decided == EngineKind::Csr { EngineKind::Hbp } else { EngineKind::Csr };
        let x = random::vector(80, 2);
        r.spmv("t", other, &x).unwrap();
        let p = r.get("t").unwrap();
        assert!(p.is_built(other), "forced kind must now be resident");
        assert_eq!(p.built_kinds().len(), 2);
    }

    #[test]
    fn auto_is_bit_identical_to_the_forced_winner() {
        let m = random::power_law_rows(120, 90, 2.0, 25, 7);
        let r = router_with("t", m);
        let p = r.get("t").unwrap();
        let winner = p.resolved_kind();
        drop(p);
        let x = random::vector(90, 3);
        let auto = r.spmv("t", EngineKind::Auto, &x).unwrap();
        let forced = r.spmv("t", winner, &x).unwrap();
        assert_eq!(auto, forced, "Auto must route to the same resident engine");
    }

    #[test]
    fn reregistering_identical_content_hits_the_tune_cache() {
        let m = random::power_law_rows(80, 70, 2.0, 20, 11);
        let mut r = Router::new(PartitionConfig::test_small(), 2);
        r.register("a", m.clone()).unwrap();
        r.register("b", m).unwrap();
        let a = r.get("a").unwrap();
        let b = r.get("b").unwrap();
        assert!(!a.tune.cache_hit, "first registration runs trials");
        assert!(a.tune.report.is_some());
        assert!(b.tune.cache_hit, "identical content must skip trials");
        assert!(b.tune.report.is_none(), "cache hit means no second trial run");
        assert_eq!(a.tune.decision, b.tune.decision);
    }

    #[test]
    fn engine_kind_round_trips_through_display_and_fromstr() {
        for kind in [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d, EngineKind::Auto] {
            let s = kind.to_string();
            assert_eq!(s.parse::<EngineKind>().unwrap(), kind, "{s}");
        }
        let err = "warp".parse::<EngineKind>().unwrap_err();
        let msg = format!("{err:#}");
        for name in ["hbp", "csr", "2d", "auto"] {
            assert!(msg.contains(name), "error must list {name}: {msg}");
        }
    }

    #[test]
    fn errors_are_clear() {
        let m = random::uniform(10, 10, 0.5, 1);
        let r = router_with("t", m);
        assert!(r.spmv("missing", EngineKind::Hbp, &vec![0.0; 10]).is_err());
        assert!(r.spmv("t", EngineKind::Hbp, &vec![0.0; 5]).is_err());
        assert!("warp".parse::<EngineKind>().is_err());
        assert_eq!("2d".parse::<EngineKind>().unwrap(), EngineKind::Plain2d);
    }

    #[test]
    fn registry_lists_names() {
        let mut r = Router::new(PartitionConfig::test_small(), 1);
        r.register("a", random::uniform(5, 5, 0.5, 1)).unwrap();
        r.register("b", random::uniform(5, 5, 0.5, 2)).unwrap();
        assert_eq!(r.names(), vec!["a", "b"]);
        assert!(r.get("a").unwrap().preprocess_secs >= 0.0);
    }

    #[test]
    fn update_keeps_every_engine_coherent() {
        let m = random::power_law_rows(90, 70, 2.0, 20, 7);
        let r = router_with("t", m.clone());
        let row = (0..90).find(|&i| m.row_nnz(i) >= 1).unwrap();
        let delta = MatrixDelta::new().scale_row(row, 2.0).zero_row(89.min(row + 1));
        let report = r.update("t", &delta).unwrap();
        assert!(report.blocks_touched <= report.blocks_total);
        assert_eq!(r.get("t").unwrap().updates_applied, 1);
        // all engines — including those built only after the update —
        // agree on the mutated matrix
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let x = random::vector(70, 5);
        let mut expect = vec![0.0; 90];
        mutated.spmv(&x, &mut expect);
        for kind in [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d] {
            let y = r.spmv("t", kind, &x).unwrap();
            assert!(allclose(&y, &expect, 1e-10, 1e-12), "{kind:?} after update");
        }
    }

    #[test]
    fn update_repairs_only_built_engines_lazily_built_ones_catch_up() {
        let m = random::power_law_rows(80, 60, 2.0, 15, 19);
        let r = router_with("t", m.clone());
        let built_before = r.get("t").unwrap().built_kinds();
        assert_eq!(built_before.len(), 1, "register builds one engine");

        let row = (0..80).find(|&i| m.row_nnz(i) >= 1).unwrap();
        r.update("t", &MatrixDelta::new().scale_row(row, -3.0)).unwrap();
        assert_eq!(
            r.get("t").unwrap().built_kinds(),
            built_before,
            "an update must not force unbuilt engines into existence"
        );

        // a kind first built *after* the update serves the updated values
        let unbuilt = [EngineKind::Hbp, EngineKind::Csr, EngineKind::Plain2d]
            .into_iter()
            .find(|k| !built_before.contains(k))
            .unwrap();
        let mut mutated = m.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &MatrixDelta::new().scale_row(row, -3.0))
            .unwrap();
        let x = random::vector(60, 9);
        let mut expect = vec![0.0; 80];
        mutated.spmv(&x, &mut expect);
        let y = r.spmv("t", unbuilt, &x).unwrap();
        assert!(allclose(&y, &expect, 1e-10, 1e-12), "{unbuilt:?} built from stale source");
    }

    #[test]
    fn update_errors_leave_registry_serving() {
        let m = random::uniform(10, 10, 0.5, 2);
        let r = router_with("t", m.clone());
        assert!(r.update("missing", &MatrixDelta::new().zero_row(0)).is_err());
        assert!(r.update("t", &MatrixDelta::new().zero_row(10)).is_err());
        assert_eq!(r.get("t").unwrap().updates_applied, 0);
        let x = random::vector(10, 1);
        let mut expect = vec![0.0; 10];
        m.spmv(&x, &mut expect);
        let y = r.spmv("t", EngineKind::Hbp, &x).unwrap();
        assert!(allclose(&y, &expect, 1e-10, 1e-12));
    }

    #[test]
    fn concurrent_updates_and_reads_stay_consistent() {
        let m = random::power_law_rows(60, 60, 2.0, 15, 9);
        let r = std::sync::Arc::new(router_with("t", m.clone()));
        let row = (0..60).find(|&i| m.row_nnz(i) >= 1).unwrap();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let r = r.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        // factor 1.0: idempotent, so readers always see a
                        // matrix equal to the original
                        r.update("t", &MatrixDelta::new().scale_row(row, 1.0)).unwrap();
                    }
                });
            }
            for t in 0..3 {
                let r = r.clone();
                let m = &m;
                s.spawn(move || {
                    let x = random::vector(60, t);
                    let mut expect = vec![0.0; 60];
                    m.spmv(&x, &mut expect);
                    for _ in 0..10 {
                        let y = r.spmv("t", EngineKind::Hbp, &x).unwrap();
                        assert!(allclose(&y, &expect, 1e-10, 1e-12));
                    }
                });
            }
        });
        assert_eq!(r.get("t").unwrap().updates_applied, 20);
    }
}

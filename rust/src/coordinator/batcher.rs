//! Dynamic request batching.
//!
//! Requests queue on a channel; a dispatcher thread drains up to
//! `max_batch` of them (waiting at most `max_wait` for stragglers),
//! groups them by matrix, and executes each group — the standard
//! serving-system batching discipline (vLLM-style), applied to SpMV.
//! Batching matters here because requests against the same matrix share
//! the preprocessed HBP structure and its cache residency.
//!
//! Matrix **updates** ride the same queue as SpMV requests, so a client
//! that submits `spmv, update, spmv` observes them in that order: the
//! dispatcher flushes the SpMV groups accumulated so far before applying
//! an update, then keeps batching. The update itself goes through
//! [`Router::update`]'s per-matrix write lock, so it is atomic against
//! requests from other connections too.

use super::router::{EngineKind, Router};
use crate::coordinator::metrics::ServiceMetrics;
use crate::preprocess::{MatrixDelta, UpdateReport};
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// What a queued request asks for.
pub enum Payload {
    Spmv {
        engine: EngineKind,
        x: Vec<f64>,
        reply: mpsc::Sender<Result<Vec<f64>>>,
    },
    Update {
        delta: MatrixDelta,
        reply: mpsc::Sender<Result<UpdateReport>>,
    },
}

/// One queued request.
pub struct Request {
    pub matrix: String,
    pub payload: Payload,
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<Request>,
}

impl BatcherHandle {
    /// Submit and wait for the result (client-side synchronous API).
    pub fn spmv(&self, matrix: &str, engine: EngineKind, x: Vec<f64>) -> Result<Vec<f64>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request {
                matrix: matrix.to_string(),
                payload: Payload::Spmv { engine, x, reply },
            })
            .map_err(|_| anyhow::anyhow!("batcher shut down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("batcher dropped request"))?
    }

    /// Submit a matrix delta and wait for its report. Ordered with this
    /// handle's SpMV submissions.
    pub fn update(&self, matrix: &str, delta: MatrixDelta) -> Result<UpdateReport> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request {
                matrix: matrix.to_string(),
                payload: Payload::Update { delta, reply },
            })
            .map_err(|_| anyhow::anyhow!("batcher shut down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("batcher dropped request"))?
    }
}

/// The dispatcher. Owns the router; runs until all handles drop.
pub struct Batcher {
    handle: BatcherHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn start(router: Arc<Router>, metrics: Arc<ServiceMetrics>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = mpsc::channel::<Request>();
        let thread = std::thread::spawn(move || dispatcher(router, metrics, cfg, rx));
        Batcher { handle: BatcherHandle { tx }, thread: Some(thread) }
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Replace our own sender with a dummy so the dispatcher's receiver
        // disconnects once all external handles are gone, then join.
        // NOTE: if external handles still exist the join waits for them —
        // drop handles before the Batcher.
        self.handle = BatcherHandle { tx: mpsc::channel().0 };
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// A drained SpMV awaiting group execution.
struct PendingSpmv {
    matrix: String,
    engine: EngineKind,
    x: Vec<f64>,
    reply: mpsc::Sender<Result<Vec<f64>>>,
}

fn dispatcher(
    router: Arc<Router>,
    metrics: Arc<ServiceMetrics>,
    cfg: BatcherConfig,
    rx: mpsc::Receiver<Request>,
) {
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Process in arrival order: SpMVs accumulate and execute as
        // (matrix, engine) groups; an update flushes what came before
        // it, then applies, so order is preserved around mutation.
        let mut pending: Vec<PendingSpmv> = Vec::new();
        for r in batch {
            match r.payload {
                Payload::Spmv { engine, x, reply } => {
                    pending.push(PendingSpmv { matrix: r.matrix, engine, x, reply });
                }
                Payload::Update { delta, reply } => {
                    flush_spmvs(&router, &metrics, std::mem::take(&mut pending));
                    let t = crate::util::Timer::start();
                    let result = router.update(&r.matrix, &delta);
                    match &result {
                        Ok(report) => metrics.record_update(t.elapsed_secs(), report),
                        Err(_) => metrics.record_error(),
                    }
                    let _ = reply.send(result);
                }
            }
        }
        flush_spmvs(&router, &metrics, pending);
    }
}

/// Execute a drained run of SpMV requests: group by (matrix, engine),
/// run same-matrix groups as SpMM (element reuse across the batch),
/// fall back to per-request on validation errors.
fn flush_spmvs(router: &Router, metrics: &ServiceMetrics, batch: Vec<PendingSpmv>) {
    let mut groups: BTreeMap<(String, String), Vec<PendingSpmv>> = BTreeMap::new();
    for r in batch {
        groups
            .entry((r.matrix.clone(), format!("{:?}", r.engine)))
            .or_default()
            .push(r);
    }
    for ((_, _), reqs) in groups {
        if reqs.len() > 1 {
            let matrix = reqs[0].matrix.clone();
            let engine = reqs[0].engine;
            let dims_ok = router
                .get(&matrix)
                .map(|m| reqs.iter().all(|r| r.x.len() == m.cols))
                .unwrap_or(false);
            if dims_ok {
                let t = crate::util::Timer::start();
                let xs: Vec<Vec<f64>> = reqs.iter().map(|r| r.x.clone()).collect();
                match router.spmm(&matrix, engine, xs) {
                    Ok(ys) => {
                        let secs = t.elapsed_secs() / reqs.len() as f64;
                        let nnz = router.get(&matrix).map(|m| m.nnz).unwrap_or(0);
                        for (req, y) in reqs.into_iter().zip(ys) {
                            metrics.record_request(secs, nnz);
                            let _ = req.reply.send(Ok(y));
                        }
                        continue;
                    }
                    Err(_) => { /* fall through to per-request path */ }
                }
            }
        }
        for req in reqs {
            let t = crate::util::Timer::start();
            let result = router.spmv(&req.matrix, req.engine, &req.x);
            match &result {
                Ok(_) => {
                    let nnz = router.get(&req.matrix).map(|m| m.nnz).unwrap_or(0);
                    metrics.record_request(t.elapsed_secs(), nnz);
                }
                Err(_) => metrics.record_error(),
            }
            let _ = req.reply.send(result);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::partition::PartitionConfig;

    fn setup() -> (Arc<Router>, Arc<ServiceMetrics>) {
        let mut router = Router::new(PartitionConfig::test_small(), 2);
        router.register("m", random::power_law_rows(60, 50, 2.0, 15, 3)).unwrap();
        (Arc::new(router), Arc::new(ServiceMetrics::new()))
    }

    #[test]
    fn batched_requests_all_answered() {
        let (router, metrics) = setup();
        let m = router.get("m").unwrap();
        let (rows, cols) = (m.rows, m.cols);
        drop(m);
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        let results: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16)
                .map(|i| {
                    let h = h.clone();
                    s.spawn(move || h.spmv("m", EngineKind::Hbp, random::vector(cols, i)).unwrap())
                })
                .collect();
            handles.into_iter().map(|t| t.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 16);
        assert!(results.iter().all(|y| y.len() == rows));
        assert_eq!(metrics.snapshot().requests, 16);
    }

    #[test]
    fn errors_propagate_to_caller() {
        let (router, metrics) = setup();
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let err = batcher.handle().spmv("nope", EngineKind::Csr, vec![0.0; 50]);
        assert!(err.is_err());
        assert_eq!(metrics.snapshot().errors, 1);
    }

    #[test]
    fn updates_interleave_with_spmv_traffic() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();

        let x = random::vector(cols, 4);
        let before = h.spmv("m", EngineKind::Hbp, x.clone()).unwrap();
        let report = h.update("m", MatrixDelta::new().scale_row(0, 2.0)).unwrap();
        assert!(report.blocks_touched <= report.blocks_total);
        let after = h.spmv("m", EngineKind::Hbp, x.clone()).unwrap();
        // row 0 scaled by an exact power of two: y[0] doubles exactly
        assert_eq!(after[0], 2.0 * before[0]);
        for r in 1..before.len() {
            assert_eq!(after[r], before[r], "row {r} must be unchanged");
        }

        // failed update: error surfaces, traffic continues
        assert!(h.update("m", MatrixDelta::new().zero_row(999)).is_err());
        assert!(h.spmv("m", EngineKind::Hbp, x).is_ok());

        let snap = metrics.snapshot();
        assert_eq!(snap.updates, 1);
        assert_eq!(snap.errors, 1);
        assert!(snap.mean_update_secs >= 0.0);
    }

    #[test]
    fn concurrent_updates_and_spmvs_all_answered() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        std::thread::scope(|s| {
            for i in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    let y = h.spmv("m", EngineKind::Hbp, random::vector(cols, i)).unwrap();
                    assert_eq!(y.len(), 60);
                });
            }
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    // factor 1.0 keeps values stable under any ordering
                    h.update("m", MatrixDelta::new().scale_row(1, 1.0)).unwrap();
                });
            }
        });
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 8);
        assert_eq!(snap.updates, 4);
        assert_eq!(snap.errors, 0);
    }
}

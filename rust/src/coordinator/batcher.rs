//! Dynamic request batching over *resolved* engine decisions.
//!
//! Requests queue on a channel; a dispatcher thread drains up to
//! `max_batch` of them (waiting at most `max_wait` for stragglers),
//! groups them, and executes each group — the standard serving-system
//! batching discipline (vLLM-style), applied to SpMV. Batching matters
//! here because requests against the same matrix share the preprocessed
//! HBP structure and its cache residency.
//!
//! Groups are keyed by `(matrix, resolved kind)`, **not** the requested
//! kind: at admission the dispatcher asks [`Router::resolve`] (a cheap,
//! non-blocking read of the cached tuned decision) what an `auto`
//! request will execute on, so an `"engine":"auto"` request and an
//! explicit request naming the same resolved engine merge into one
//! group and flush as one SpMV batch. When resolution must be deferred
//! (unknown matrix, write-locked entry, or a decision staled by an
//! update), the request is admitted under `Auto` and the *flush* path
//! re-resolves it via [`Router::resolve_blocking`] — re-tuning never
//! blocks admission. Per-group provenance (how many requests arrived as
//! `auto` vs explicit) lands in [`ServiceMetrics`] as `batch_groups`,
//! `batch_merged_auto`, and `mean_group_size`.
//!
//! Matrix **updates** ride the same queue as SpMV requests, so a client
//! that submits `spmv, update, spmv` observes them in that order: the
//! dispatcher flushes the SpMV groups accumulated so far before applying
//! an update, then keeps batching. A pattern-changing delta stales the
//! matrix's tuned decision, so requests admitted after it defer and
//! re-resolve on flush — a changed pattern can change the tuned winner
//! (value-only deltas cannot, and stay on the fresh fast path). The
//! update itself goes through [`Router::update`]'s per-matrix write
//! lock, so it is atomic against requests from other connections too.
//!
//! The queue is **bounded** (`max_queue`): when it fills, new arrivals
//! are shed at admission with a typed `overloaded` error carrying a
//! `retry_after_ms` back-off hint, instead of blocking the submitting
//! thread. Requests may carry a **deadline** (per-request `deadline_ms`
//! or the config's `default_deadline`), checked at admission and again
//! at flush — stale work is dropped with `deadline_exceeded`, not
//! executed. Engine execution and delta application run under
//! `catch_unwind`: a panicking engine answers its requests with typed
//! `internal` errors and the dispatcher keeps serving (the router's
//! locks all recover from poisoning). Sheds, drops, and recovered
//! panics land in [`ServiceMetrics`] (`shed`, `deadline_drops`,
//! `panics_recovered`).
//!
//! Every request is **traced**: the dispatcher cuts one monotonic
//! timeline per request — admission → execution start (queue wait),
//! the engine call (execute), reply assembly/hand-off (reply) — and
//! publishes a [`Span`] carrying the timings plus the grouping
//! decisions (resolved engine, group size, merged-auto provenance,
//! fused SpMM width) into the shard's [`Telemetry`] ring *before*
//! sending the reply. Successful spans also feed the per-stage
//! histograms in [`ServiceMetrics`], and the end-to-end latency sample
//! is the sum of the three stages by construction
//! (`docs/ARCHITECTURE.md` § Observability).
//!
//! Teardown is typed too: once [`Batcher::begin_shutdown`] runs (the
//! `Drop` impl calls it before severing the channel), every further
//! send through any handle is refused with a `shutting_down`
//! [`ServiceError`] — a sender racing the teardown never sees a bare
//! channel-disconnect error.

use super::error::ServiceError;
use super::router::{EngineKind, Router};
use super::telemetry::{Span, Telemetry};
use crate::coordinator::metrics::ServiceMetrics;
use crate::preprocess::{MatrixDelta, UpdateReport};
use crate::sim::faults;
use anyhow::Result;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batcher tuning.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Most requests drained into one batch.
    pub max_batch: usize,
    /// Longest the dispatcher waits for stragglers after the first
    /// request of a batch arrives.
    pub max_wait: Duration,
    /// Admission-control bound: most requests queued ahead of the
    /// dispatcher. A full queue sheds new arrivals with an `overloaded`
    /// reply instead of blocking the submitting connection thread.
    pub max_queue: usize,
    /// Deadline applied to SpMV requests that name no `deadline_ms` of
    /// their own (`None`: such requests never expire). Updates carry no
    /// deadline — silently dropping a mutation would change state
    /// semantics.
    pub default_deadline: Option<Duration>,
    /// Back-off hint (milliseconds) carried in `overloaded` replies.
    pub retry_after_ms: u64,
    /// Capacity of the shard's span ring (`{"op":"trace"}` depth);
    /// the `--trace-capacity` serve flag.
    pub trace_capacity: usize,
    /// Requests whose end-to-end latency crosses this threshold log
    /// their span as one structured JSON line to stderr (`None`
    /// disables the slow log); the `--slow-ms` serve flag.
    pub slow_threshold: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_queue: 1024,
            default_deadline: None,
            retry_after_ms: 50,
            trace_capacity: 1024,
            slow_threshold: None,
        }
    }
}

/// A completed SpMV: the product plus the concrete engine that ran it
/// (`auto` requests observe what their tuned decision resolved to).
#[derive(Clone, Debug)]
pub struct SpmvReply {
    /// The matrix–vector product.
    pub y: Vec<f64>,
    /// The concrete engine kind the request executed on — never
    /// [`EngineKind::Auto`] on a successful reply.
    pub resolved: EngineKind,
}

/// What a queued request asks for.
pub enum Payload {
    /// One matrix–vector product.
    Spmv {
        /// Requested engine kind (`Auto` defers to the tuned decision).
        engine: EngineKind,
        /// The input vector.
        x: Vec<f64>,
        /// Where the product (and the resolved kind) is delivered.
        reply: mpsc::Sender<Result<SpmvReply>>,
    },
    /// One matrix delta.
    Update {
        /// The delta to apply.
        delta: MatrixDelta,
        /// Where the update report is delivered.
        reply: mpsc::Sender<Result<UpdateReport>>,
    },
}

/// One queued request.
pub struct Request {
    /// Name of the registered matrix the payload targets.
    pub matrix: String,
    /// Absolute expiry: work not *started* by this point is dropped
    /// with a `deadline_exceeded` reply (`None`: never expires).
    pub deadline: Option<Instant>,
    /// Admission timestamp — the origin of the request's trace span
    /// (its `queue_wait` stage measures from here).
    pub admitted: Instant,
    /// Protocol request `id` carried for trace correlation; the span
    /// echoes it so pipelined clients can match spans to replies.
    pub trace_id: Option<String>,
    /// What to do with it.
    pub payload: Payload,
}

/// Handle for submitting requests.
///
/// # Example
///
/// ```
/// use hbp_spmv::coordinator::{Batcher, BatcherConfig, EngineKind, Router, ServiceMetrics};
/// use hbp_spmv::partition::PartitionConfig;
/// use std::sync::Arc;
///
/// let mut router = Router::new(PartitionConfig::test_small(), 1);
/// router.register("m", hbp_spmv::gen::random::uniform(8, 8, 0.5, 1)).unwrap();
/// let batcher =
///     Batcher::start(Arc::new(router), Arc::new(ServiceMetrics::new()), BatcherConfig::default());
/// let handle = batcher.handle();
/// // `auto` resolves to the tuned decision before grouping…
/// let reply = handle.spmv_resolved("m", EngineKind::Auto, vec![1.0; 8]).unwrap();
/// assert_eq!(reply.y.len(), 8);
/// // …and the reply reports the concrete engine that ran
/// assert_ne!(reply.resolved, EngineKind::Auto);
/// ```
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::SyncSender<Request>,
    metrics: Arc<ServiceMetrics>,
    max_queue: usize,
    default_deadline: Option<Duration>,
    retry_after_ms: u64,
    /// Set by [`Batcher::begin_shutdown`] (and by `Batcher`'s `Drop`,
    /// before it severs the channel), so a sender racing a teardown
    /// gets a typed `shutting_down` refusal instead of a bare
    /// disconnect error.
    shutting_down: Arc<AtomicBool>,
}

impl BatcherHandle {
    /// Submit and wait for the result (client-side synchronous API).
    pub fn spmv(&self, matrix: &str, engine: EngineKind, x: Vec<f64>) -> Result<Vec<f64>> {
        self.spmv_resolved(matrix, engine, x).map(|r| r.y)
    }

    /// Like [`BatcherHandle::spmv`], but the reply also names the
    /// concrete engine the request executed on — how a client observes
    /// what its `auto` request resolved to (and therefore merged with).
    pub fn spmv_resolved(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
    ) -> Result<SpmvReply> {
        self.spmv_deadline(matrix, engine, x, None)
    }

    /// [`BatcherHandle::spmv_resolved`] with an explicit per-request
    /// deadline budget in milliseconds (`None` falls back to the
    /// config's `default_deadline`). An already-expired budget (`0`) is
    /// rejected at admission; a budget that runs out while the request
    /// is queued drops it at flush — either way the typed error is
    /// `deadline_exceeded` and the work never executes.
    pub fn spmv_deadline(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
        deadline_ms: Option<u64>,
    ) -> Result<SpmvReply> {
        let rx = self.submit_spmv(matrix, engine, x, deadline_ms)?;
        rx.recv().map_err(|_| self.dropped_error())?
    }

    /// Enqueue an SpMV without blocking on its reply, returning the
    /// channel the reply will arrive on. Admission control happens
    /// here: a full queue sheds with `overloaded` (+`retry_after_ms`),
    /// an expired deadline rejects with `deadline_exceeded`. This is
    /// the primitive the synchronous calls wrap, public so load tests
    /// and the fault harness can stuff the queue deterministically.
    pub fn submit_spmv(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
        deadline_ms: Option<u64>,
    ) -> Result<mpsc::Receiver<Result<SpmvReply>>> {
        self.submit_spmv_traced(matrix, engine, x, deadline_ms, None)
    }

    /// [`BatcherHandle::submit_spmv`] carrying a protocol request `id`
    /// for trace correlation: the request's span echoes `trace_id`, so
    /// a pipelined client can match `{"op":"trace"}` output to the
    /// replies it received.
    pub fn submit_spmv_traced(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
        deadline_ms: Option<u64>,
        trace_id: Option<String>,
    ) -> Result<mpsc::Receiver<Result<SpmvReply>>> {
        let deadline = self.admission_deadline(deadline_ms)?;
        let (reply, rx) = mpsc::channel();
        self.try_send(Request {
            matrix: matrix.to_string(),
            deadline,
            admitted: Instant::now(),
            trace_id,
            payload: Payload::Spmv { engine, x, reply },
        })?;
        Ok(rx)
    }

    /// Submit a matrix delta and wait for its report. Ordered with this
    /// handle's SpMV submissions. Updates are subject to admission
    /// control (a full queue sheds them) but carry no deadline: once
    /// admitted, a mutation is applied, never silently dropped.
    pub fn update(&self, matrix: &str, delta: MatrixDelta) -> Result<UpdateReport> {
        let (reply, rx) = mpsc::channel();
        self.try_send(Request {
            matrix: matrix.to_string(),
            deadline: None,
            admitted: Instant::now(),
            trace_id: None,
            payload: Payload::Update { delta, reply },
        })?;
        rx.recv().map_err(|_| self.dropped_error())?
    }

    /// The typed error for a reply channel that died before answering:
    /// the dispatcher only drops reply senders on teardown, so the
    /// caller sees `shutting_down` rather than a bare channel error.
    fn dropped_error(&self) -> anyhow::Error {
        anyhow::Error::new(ServiceError::shutting_down(
            "batcher shut down before answering the request",
        ))
    }

    /// Resolve the effective deadline for a new request; reject (and
    /// count) budgets that are already spent.
    fn admission_deadline(&self, deadline_ms: Option<u64>) -> Result<Option<Instant>> {
        let now = Instant::now();
        let deadline = match deadline_ms {
            Some(ms) => Some(now + Duration::from_millis(ms)),
            None => self.default_deadline.map(|d| now + d),
        };
        if let Some(d) = deadline {
            if d <= now {
                self.metrics.record_deadline_drop();
                return Err(anyhow::Error::new(ServiceError::deadline_exceeded(
                    "deadline expired at admission",
                )));
            }
        }
        Ok(deadline)
    }

    /// Non-blocking enqueue: shed (typed, counted) instead of blocking
    /// when the bounded queue is full, and refuse (typed) once the
    /// batcher has begun shutting down — a racing sender must never see
    /// a bare channel-disconnect error.
    fn try_send(&self, request: Request) -> Result<()> {
        if self.shutting_down.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServiceError::shutting_down(
                "batcher is shutting down; request refused",
            )));
        }
        match self.tx.try_send(request) {
            Ok(()) => {
                // occupancy gauge: +1 at admission, -1 when the
                // dispatcher drains it (lock-free, rolls up to the root)
                self.metrics.gauge_queue_depth(1);
                Ok(())
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_shed();
                Err(anyhow::Error::new(ServiceError::overloaded(
                    format!("queue full ({} requests queued)", self.max_queue),
                    self.retry_after_ms,
                )))
            }
            // the flag is set before the Drop severs the channel, but a
            // sender that read the flag just before it flipped can still
            // observe the disconnect — give it the same typed refusal
            Err(mpsc::TrySendError::Disconnected(_)) => Err(anyhow::Error::new(
                ServiceError::shutting_down("batcher is shutting down; request refused"),
            )),
        }
    }
}

/// The dispatcher. Owns the router; runs until all handles drop.
pub struct Batcher {
    handle: BatcherHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Start the dispatcher thread with a stand-alone telemetry bundle
    /// (shard 0, ring and slow-log settings from `cfg`).
    pub fn start(router: Arc<Router>, metrics: Arc<ServiceMetrics>, cfg: BatcherConfig) -> Batcher {
        let telemetry = Arc::new(Telemetry::new(0, cfg.trace_capacity, cfg.slow_threshold));
        Batcher::start_with_telemetry(router, metrics, cfg, telemetry)
    }

    /// [`Batcher::start`] with a caller-provided telemetry bundle — the
    /// coordinator hands each shard one that shares a global span
    /// sequence counter, so per-shard rings merge into one order.
    pub fn start_with_telemetry(
        router: Arc<Router>,
        metrics: Arc<ServiceMetrics>,
        cfg: BatcherConfig,
        telemetry: Arc<Telemetry>,
    ) -> Batcher {
        let max_queue = cfg.max_queue.max(1);
        let (tx, rx) = mpsc::sync_channel::<Request>(max_queue);
        let handle = BatcherHandle {
            tx,
            metrics: metrics.clone(),
            max_queue,
            default_deadline: cfg.default_deadline,
            retry_after_ms: cfg.retry_after_ms,
            shutting_down: Arc::new(AtomicBool::new(false)),
        };
        let thread = std::thread::spawn(move || dispatcher(router, metrics, telemetry, cfg, rx));
        Batcher { handle, thread: Some(thread) }
    }

    /// A new submission handle (cheaply cloneable).
    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    /// Stop admitting work: every subsequent send through any handle
    /// (cloned before or after this call) gets a typed `shutting_down`
    /// refusal. Requests already queued are still drained and answered.
    /// Idempotent; `Drop` calls it too, so tests can stage the
    /// teardown race deterministically.
    pub fn begin_shutdown(&self) {
        self.handle.shutting_down.store(true, Ordering::SeqCst);
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Flip the refusal flag BEFORE severing the channel: a sender
        // racing this drop gets a typed `shutting_down` error instead of
        // a confusing disconnect. Then replace our own sender with a
        // dummy so the dispatcher's receiver disconnects once all
        // external handles are gone, and join.
        // NOTE: if external handles still exist the join waits for them —
        // drop handles before the Batcher.
        self.begin_shutdown();
        self.handle.tx = mpsc::sync_channel(1).0;
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// A drained SpMV awaiting group execution.
struct PendingSpmv {
    matrix: String,
    /// What the client asked for — kept for provenance accounting.
    requested: EngineKind,
    /// The admission-time resolution: a concrete kind, or `Auto` when
    /// resolution was deferred to flush time.
    resolved: EngineKind,
    /// Carried from [`Request::deadline`]; re-checked at flush.
    deadline: Option<Instant>,
    /// Carried from [`Request::admitted`]; origin of the span timeline.
    admitted: Instant,
    /// Carried from [`Request::trace_id`]; echoed by the span.
    trace_id: Option<String>,
    x: Vec<f64>,
    reply: mpsc::Sender<Result<SpmvReply>>,
}

fn dispatcher(
    router: Arc<Router>,
    metrics: Arc<ServiceMetrics>,
    telemetry: Arc<Telemetry>,
    cfg: BatcherConfig,
    rx: mpsc::Receiver<Request>,
) {
    loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone
        };
        metrics.gauge_queue_depth(-1);
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    metrics.gauge_queue_depth(-1);
                    batch.push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Process in arrival order: SpMVs are admitted with their
        // resolution (cheap, non-blocking — Auto means deferred) and
        // accumulate; an update flushes what came before it, then
        // applies, so order is preserved around mutation. Requests
        // admitted after the update see its staled decision and defer.
        let mut pending: Vec<PendingSpmv> = Vec::new();
        for r in batch {
            match r.payload {
                Payload::Spmv { engine, x, reply } => {
                    let resolved = match engine {
                        EngineKind::Auto => router.resolve(&r.matrix),
                        explicit => explicit,
                    };
                    pending.push(PendingSpmv {
                        matrix: r.matrix,
                        requested: engine,
                        resolved,
                        deadline: r.deadline,
                        admitted: r.admitted,
                        trace_id: r.trace_id,
                        x,
                        reply,
                    });
                }
                Payload::Update { delta, reply } => {
                    flush_spmvs(&router, &metrics, &telemetry, std::mem::take(&mut pending));
                    let t = crate::util::Timer::start();
                    // a panicking delta application must not kill the
                    // dispatcher: the router's locks recover from
                    // poisoning, so convert the panic into a typed
                    // per-request error and keep serving
                    let result =
                        catch_unwind(AssertUnwindSafe(|| router.update(&r.matrix, &delta)));
                    let result = match result {
                        Ok(res) => res,
                        Err(p) => {
                            metrics.record_panic_recovered();
                            Err(anyhow::Error::new(ServiceError::internal(format!(
                                "update panicked (recovered): {}",
                                super::error::panic_message(p)
                            ))))
                        }
                    };
                    match &result {
                        Ok(report) => metrics.record_update(t.elapsed_secs(), report),
                        Err(_) => metrics.record_error(),
                    }
                    let _ = reply.send(result);
                }
            }
        }
        flush_spmvs(&router, &metrics, &telemetry, pending);
    }
}

/// Per-group span context: everything a request's [`Span`] needs that
/// is decided at the group level rather than per request.
struct SpanCtx<'a> {
    telemetry: &'a Telemetry,
    metrics: &'a ServiceMetrics,
    matrix: &'a str,
    engine: EngineKind,
    group_size: usize,
    merged_auto: bool,
}

impl SpanCtx<'_> {
    /// Publish one request's span — **before** the reply send, so a
    /// client that has read its reply will find the span in the ring —
    /// and return the span's end-to-end total. The three stage
    /// durations are cut from one monotonic timeline
    /// (admitted → exec_start → exec_end → now), so they sum to the
    /// total exactly; successful requests also feed the per-stage
    /// histograms, keeping the stats decomposition consistent with the
    /// latency histogram [`ServiceMetrics::record_request`] fills.
    fn emit(
        &self,
        admitted: Instant,
        exec_start: Instant,
        exec_end: Instant,
        trace_id: Option<String>,
        spmm_width: usize,
        ok: bool,
    ) -> f64 {
        let now = Instant::now();
        let queue_wait = exec_start.saturating_duration_since(admitted).as_secs_f64();
        let execute = exec_end.saturating_duration_since(exec_start).as_secs_f64();
        let reply = now.saturating_duration_since(exec_end).as_secs_f64();
        let total = queue_wait + execute + reply;
        if ok {
            self.metrics.record_stages(queue_wait, execute, reply);
        }
        self.telemetry.publish(Span {
            seq: self.telemetry.next_seq(),
            shard: self.telemetry.shard(),
            id: trace_id,
            matrix: self.matrix.to_string(),
            engine: self.engine.to_string(),
            group_size: self.group_size,
            merged_auto: self.merged_auto,
            spmm_width,
            queue_wait_secs: queue_wait,
            execute_secs: execute,
            reply_secs: reply,
            total_secs: total,
            ok,
        });
        total
    }
}

/// Execute a drained run of SpMV requests: finish deferred resolutions
/// (one blocking re-resolve per matrix — this is where a staled
/// decision re-tunes), group by `(matrix, resolved kind)`, run
/// same-group requests as one fused SpMM (element reuse across the
/// batch; `spmm_fused_vectors` / `mean_spmm_width` record the widths).
/// A mis-sized request is answered with its own error and never demotes
/// the rest of its group to the looped path. Per group, requests whose
/// deadline expired while queued are dropped before execution, and the
/// engine call itself runs under `catch_unwind` so a panic answers the
/// group with typed `internal` errors instead of killing the
/// dispatcher. Every request — answered, errored, or dropped — emits
/// one trace [`Span`] into `telemetry` *before* its reply is sent.
fn flush_spmvs(
    router: &Router,
    metrics: &ServiceMetrics,
    telemetry: &Telemetry,
    mut batch: Vec<PendingSpmv>,
) {
    if batch.is_empty() {
        return;
    }
    let mut deferred: BTreeMap<String, EngineKind> = BTreeMap::new();
    for r in batch.iter_mut() {
        if r.resolved == EngineKind::Auto {
            let kind = match deferred.get(&r.matrix).copied() {
                Some(k) => k,
                None => {
                    let k = match router.resolve_blocking(&r.matrix) {
                        Ok((kind, outcome)) => {
                            if let Some(o) = &outcome {
                                metrics.record_tune(o);
                            }
                            kind
                        }
                        // unregistered matrix: stay Auto, the error
                        // surfaces on the execution path below
                        Err(_) => EngineKind::Auto,
                    };
                    deferred.insert(r.matrix.clone(), k);
                    k
                }
            };
            r.resolved = kind;
        }
    }

    let mut groups: BTreeMap<(String, String), Vec<PendingSpmv>> = BTreeMap::new();
    for r in batch {
        groups
            .entry((r.matrix.clone(), r.resolved.to_string()))
            .or_default()
            .push(r);
    }
    for ((matrix, _), reqs) in groups {
        // fault probe: an armed slow-flush stalls here, upstream of the
        // deadline check, so tests can expire a deadline mid-queue
        // deterministically
        faults::slow_flush(&matrix);
        // group-level span context: every member shares the resolved
        // engine (it is the group key), the arrival-set size, and the
        // merged-auto provenance flag
        let auto_arrived = reqs.iter().filter(|r| r.requested == EngineKind::Auto).count();
        let ctx = SpanCtx {
            telemetry,
            metrics,
            matrix: &matrix,
            engine: reqs[0].resolved,
            group_size: reqs.len(),
            merged_auto: auto_arrived > 0 && auto_arrived < reqs.len(),
        };
        // flush-time deadline check: time spent queued counts against
        // the request's budget — stale work is dropped, not executed
        let now = Instant::now();
        let is_live = |r: &PendingSpmv| match r.deadline {
            None => true,
            Some(d) => d > now,
        };
        let (reqs, expired): (Vec<PendingSpmv>, Vec<PendingSpmv>) =
            reqs.into_iter().partition(is_live);
        for req in expired {
            metrics.record_deadline_drop();
            // dropped work traces too: zero execute, ok=false
            let dropped_at = Instant::now();
            ctx.emit(req.admitted, dropped_at, dropped_at, req.trace_id.clone(), 0, false);
            let _ = req.reply.send(Err(anyhow::Error::new(
                ServiceError::deadline_exceeded("deadline expired while queued"),
            )));
        }
        if reqs.is_empty() {
            continue;
        }
        // provenance counts only groups that target a hosted matrix —
        // an unknown-matrix group executes nothing and would skew the
        // merge evidence the resolved-batching metrics exist to give
        let cols = router.get(&matrix).ok().map(|m| m.cols);
        if cols.is_some() {
            let auto_arrivals = reqs.iter().filter(|r| r.requested == EngineKind::Auto).count();
            metrics.record_group(reqs.len(), auto_arrivals, reqs.len() - auto_arrivals);
        }
        let engine = reqs[0].resolved;
        // a mis-sized input must not poison the flush: only the bad
        // request falls to the per-request path (answering with its own
        // dimension error) while the well-formed rest still fuses
        let (good, bad): (Vec<PendingSpmv>, Vec<PendingSpmv>) = match cols {
            Some(cols) => reqs.into_iter().partition(|r| r.x.len() == cols),
            None => (Vec::new(), reqs), // unknown matrix: all error below
        };
        if good.len() > 1 {
            // the inputs move into the batch call (no per-request
            // clone on the hot path), so a batch failure answers
            // every caller directly instead of falling back; the trace
            // meta (sender, admission time, id) rides alongside
            let (metas, xs): (Vec<_>, Vec<_>) =
                good.into_iter().map(|r| ((r.reply, r.admitted, r.trace_id), r.x)).unzip();
            let width = metas.len();
            // panic isolation: a panicking engine answers every caller
            // with a typed `internal` error instead of unwinding the
            // dispatcher (which would orphan every queued request)
            let exec_start = Instant::now();
            let result = catch_unwind(AssertUnwindSafe(|| {
                faults::spmv_probe(&matrix);
                router.spmm(&matrix, engine, xs)
            }));
            let exec_end = Instant::now();
            match result {
                Ok(Ok(ys)) => {
                    metrics.record_spmm(width);
                    let nnz = router.get(&matrix).map(|m| m.nnz).unwrap_or(0);
                    for ((reply, admitted, trace_id), y) in metas.into_iter().zip(ys) {
                        // every member of a fused group shares the one
                        // engine pass, so its span (and latency sample)
                        // carries the full pass time, not an amortized
                        // share — the batching trade-off is visible
                        let total = ctx.emit(admitted, exec_start, exec_end, trace_id, width, true);
                        metrics.record_request(total, nnz);
                        let _ = reply.send(Ok(SpmvReply { y, resolved: engine }));
                    }
                }
                // unreachable in practice: the matrix exists and dims
                // were pre-validated above — so a failure here is the
                // service's fault, not the request's
                Ok(Err(e)) => {
                    let msg = format!("{e:#}");
                    for (reply, admitted, trace_id) in metas {
                        metrics.record_error();
                        ctx.emit(admitted, exec_start, exec_end, trace_id, width, false);
                        let _ = reply.send(Err(anyhow::Error::new(ServiceError::internal(
                            format!("batched spmv: {msg}"),
                        ))));
                    }
                }
                Err(p) => {
                    metrics.record_panic_recovered();
                    let msg = super::error::panic_message(p);
                    for (reply, admitted, trace_id) in metas {
                        metrics.record_error();
                        ctx.emit(admitted, exec_start, exec_end, trace_id, width, false);
                        let _ = reply.send(Err(anyhow::Error::new(ServiceError::internal(
                            format!("engine panicked (recovered): {msg}"),
                        ))));
                    }
                }
            }
        } else {
            for req in good {
                let exec_start = Instant::now();
                let result = catch_unwind(AssertUnwindSafe(|| {
                    faults::spmv_probe(&req.matrix);
                    router.spmv(&req.matrix, engine, &req.x)
                }));
                let exec_end = Instant::now();
                let result = match result {
                    Ok(res) => res,
                    Err(p) => {
                        metrics.record_panic_recovered();
                        Err(anyhow::Error::new(ServiceError::internal(format!(
                            "engine panicked (recovered): {}",
                            super::error::panic_message(p)
                        ))))
                    }
                };
                let ok = result.is_ok();
                let total =
                    ctx.emit(req.admitted, exec_start, exec_end, req.trace_id.clone(), 1, ok);
                match &result {
                    Ok(_) => {
                        let nnz = router.get(&req.matrix).map(|m| m.nnz).unwrap_or(0);
                        metrics.record_request(total, nnz);
                    }
                    Err(_) => metrics.record_error(),
                }
                let _ = req.reply.send(result.map(|y| SpmvReply { y, resolved: engine }));
            }
        }
        for req in bad {
            // Router::spmv re-validates and produces the canonical
            // dimension (or unknown-matrix) error for this request —
            // by construction it cannot succeed here
            let exec_start = Instant::now();
            let result = router.spmv(&req.matrix, engine, &req.x);
            let exec_end = Instant::now();
            metrics.record_error();
            ctx.emit(req.admitted, exec_start, exec_end, req.trace_id.clone(), 1, false);
            let _ = req.reply.send(result.map(|y| SpmvReply { y, resolved: engine }));
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::coordinator::error::ErrorCode;
    use crate::gen::random;
    use crate::partition::PartitionConfig;
    use crate::sim::faults::Fault;

    /// Register one 60×50 matrix under `name`. Fault-injection tests
    /// pick unique names because the fault registry is process-global
    /// and keyed by matrix name — arming `"m"` would leak probes into
    /// the other tests running concurrently in this binary.
    fn setup_named(name: &str) -> (Arc<Router>, Arc<ServiceMetrics>) {
        let mut router = Router::new(PartitionConfig::test_small(), 2);
        router.register(name, random::power_law_rows(60, 50, 2.0, 15, 3)).unwrap();
        (Arc::new(router), Arc::new(ServiceMetrics::new()))
    }

    fn setup() -> (Arc<Router>, Arc<ServiceMetrics>) {
        setup_named("m")
    }

    /// Config that reliably drains back-to-back submissions into one
    /// batch: a long straggler window, so the second submission lands
    /// before the first flushes.
    fn merge_cfg() -> BatcherConfig {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(500),
            ..Default::default()
        }
    }

    /// Enqueue an SpMV without blocking on its reply — the tests' way
    /// of getting two requests into ONE dispatcher batch
    /// deterministically (two sequential sends are microseconds apart,
    /// far inside `merge_cfg`'s straggler window; spawning threads that
    /// each block on a reply would race dispatcher wakeups instead).
    fn send_spmv(
        h: &BatcherHandle,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
    ) -> mpsc::Receiver<Result<SpmvReply>> {
        h.submit_spmv(matrix, engine, x, None).unwrap()
    }

    #[test]
    fn batched_requests_all_answered() {
        let (router, metrics) = setup();
        let m = router.get("m").unwrap();
        let (rows, cols) = (m.rows, m.cols);
        drop(m);
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        let results: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..16)
                .map(|i| {
                    let h = h.clone();
                    s.spawn(move || h.spmv("m", EngineKind::Hbp, random::vector(cols, i)).unwrap())
                })
                .collect();
            handles.into_iter().map(|t| t.join().unwrap()).collect()
        });
        assert_eq!(results.len(), 16);
        assert!(results.iter().all(|y| y.len() == rows));
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 16);
        assert!(snap.batch_groups >= 1, "flushes must be counted as groups");
        assert!(snap.mean_group_size >= 1.0);
        assert_eq!(snap.batch_merged_auto, 0, "all-explicit traffic merges nothing");
    }

    #[test]
    fn errors_propagate_to_caller() {
        let (router, metrics) = setup();
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let err = batcher.handle().spmv("nope", EngineKind::Csr, vec![0.0; 50]);
        assert!(err.is_err());
        let snap = metrics.snapshot();
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.batch_groups, 0, "unknown-matrix groups execute nothing — not counted");
    }

    #[test]
    fn auto_and_explicit_resolving_identically_flush_as_one_group() {
        let (router, metrics) = setup();
        let p = router.get("m").unwrap();
        let (cols, decided) = (p.cols, p.resolved_kind());
        drop(p);
        let batcher = Batcher::start(router.clone(), metrics.clone(), merge_cfg());
        let h = batcher.handle();
        let rx_auto = send_spmv(&h, "m", EngineKind::Auto, random::vector(cols, 1));
        let rx_explicit = send_spmv(&h, "m", decided, random::vector(cols, 2));
        let auto_reply = rx_auto.recv().unwrap().unwrap();
        let explicit_reply = rx_explicit.recv().unwrap().unwrap();
        assert_eq!(auto_reply.resolved, decided, "auto reports the tuned decision");
        assert_eq!(explicit_reply.resolved, decided);
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.batch_groups, 1, "identical resolution must merge into ONE group");
        assert_eq!(snap.batch_merged_auto, 1, "the auto arrival is a counted merge");
        assert!((snap.mean_group_size - 2.0).abs() < 1e-12);
    }

    #[test]
    fn auto_and_explicit_resolving_differently_stay_separate_groups() {
        let (router, metrics) = setup();
        let p = router.get("m").unwrap();
        let (cols, decided) = (p.cols, p.resolved_kind());
        drop(p);
        // an explicit kind that is NOT the tuned decision
        let other = if decided == EngineKind::Csr { EngineKind::Hbp } else { EngineKind::Csr };
        let batcher = Batcher::start(router.clone(), metrics.clone(), merge_cfg());
        let h = batcher.handle();
        let rx_auto = send_spmv(&h, "m", EngineKind::Auto, random::vector(cols, 3));
        let rx_other = send_spmv(&h, "m", other, random::vector(cols, 4));
        rx_auto.recv().unwrap().unwrap();
        rx_other.recv().unwrap().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.batch_groups, 2, "different resolutions must not merge");
        assert_eq!(snap.batch_merged_auto, 0);
        assert!((snap.mean_group_size - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fused_group_records_spmm_width() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), merge_cfg());
        let h = batcher.handle();
        let rxs: Vec<_> = (0..3)
            .map(|i| send_spmv(&h, "m", EngineKind::Hbp, random::vector(cols, i)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 3);
        assert_eq!(snap.batch_groups, 1);
        assert_eq!(snap.spmm_fused_vectors, 3, "the whole group took the fused path");
        assert!((snap.mean_spmm_width - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mis_sized_request_errors_alone_without_demoting_the_group() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), merge_cfg());
        let h = batcher.handle();
        // two well-formed requests + one with a short vector, same group
        let rx_a = send_spmv(&h, "m", EngineKind::Hbp, random::vector(cols, 1));
        let rx_bad = send_spmv(&h, "m", EngineKind::Hbp, random::vector(cols - 1, 2));
        let rx_b = send_spmv(&h, "m", EngineKind::Hbp, random::vector(cols, 3));
        let a = rx_a.recv().unwrap();
        let bad = rx_bad.recv().unwrap();
        let b = rx_b.recv().unwrap();
        assert!(a.is_ok() && b.is_ok(), "well-formed requests must still be answered");
        let err = format!("{:#}", bad.unwrap_err());
        assert!(err.contains("cols"), "dimension error must name the mismatch: {err}");
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.errors, 1);
        assert_eq!(
            snap.spmm_fused_vectors, 2,
            "the two good requests must still fuse instead of falling back"
        );
    }

    #[test]
    fn pattern_changing_update_stales_and_auto_reresolves_on_flush() {
        let (router, metrics) = setup();
        let m_src = random::power_law_rows(60, 50, 2.0, 15, 3);
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();

        // rewrite one row's columns (same nonzero count, new pattern) —
        // the kind of delta that can actually move the tuned winner
        let row = (0..60).find(|&i| m_src.row_nnz(i) >= 1).unwrap();
        let (old_cols, vals) = m_src.row(row);
        let unused = (0..50u32).find(|c| old_cols.binary_search(c).is_err()).unwrap();
        let mut new_cols = old_cols.to_vec();
        new_cols[0] = unused;
        new_cols.sort_unstable();
        let delta = MatrixDelta::new().replace_row(row, new_cols, vals.to_vec());
        h.update("m", delta.clone()).unwrap();
        assert!(
            router.get("m").unwrap().decision_is_stale(),
            "a pattern-changing delta stales the decision"
        );

        // the next auto request defers at admission and re-resolves on
        // flush — and still serves the mutated matrix exactly
        let x = random::vector(cols, 8);
        let reply = h.spmv_resolved("m", EngineKind::Auto, x.clone()).unwrap();
        assert_ne!(reply.resolved, EngineKind::Auto);
        let mut mutated = m_src.clone();
        crate::preprocess::apply_to_csr(&mut mutated, &delta).unwrap();
        let mut expect = vec![0.0; 60];
        mutated.spmv(&x, &mut expect);
        assert!(
            crate::formats::dense::allclose(&reply.y, &expect, 1e-10, 1e-12),
            "re-resolved request must serve post-delta values"
        );

        assert!(!router.get("m").unwrap().decision_is_stale(), "flush re-resolve un-stales");
        let snap = metrics.snapshot();
        assert_eq!(snap.tunes, 1, "the flush-time re-tune is recorded");
        assert_eq!(router.resolve("m"), reply.resolved, "admission resolution is concrete again");
    }

    #[test]
    fn updates_interleave_with_spmv_traffic() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();

        let x = random::vector(cols, 4);
        let before = h.spmv("m", EngineKind::Hbp, x.clone()).unwrap();
        let report = h.update("m", MatrixDelta::new().scale_row(0, 2.0)).unwrap();
        assert!(report.blocks_touched <= report.blocks_total);
        let after = h.spmv("m", EngineKind::Hbp, x.clone()).unwrap();
        // row 0 scaled by an exact power of two: y[0] doubles exactly
        assert_eq!(after[0], 2.0 * before[0]);
        for r in 1..before.len() {
            assert_eq!(after[r], before[r], "row {r} must be unchanged");
        }

        // failed update: error surfaces, traffic continues
        assert!(h.update("m", MatrixDelta::new().zero_row(999)).is_err());
        assert!(h.spmv("m", EngineKind::Hbp, x).is_ok());

        let snap = metrics.snapshot();
        assert_eq!(snap.updates, 1);
        assert_eq!(snap.errors, 1);
        assert!(snap.mean_update_secs >= 0.0);
    }

    #[test]
    fn full_queue_sheds_with_typed_retry_hint() {
        let (router, metrics) = setup_named("fb_shed");
        let cols = router.get("fb_shed").unwrap().cols;
        let cfg = BatcherConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            max_queue: 2,
            retry_after_ms: 7,
            ..Default::default()
        };
        // stall every flush so the 2-slot queue actually fills
        crate::sim::faults::arm("fb_shed", Fault::SlowFlush { millis: 150 });
        let batcher = Batcher::start(router, metrics.clone(), cfg);
        let h = batcher.handle();
        let mut rxs = Vec::new();
        let mut sheds = 0_u64;
        for i in 0..20 {
            match h.submit_spmv("fb_shed", EngineKind::Hbp, random::vector(cols, i), None) {
                Ok(rx) => rxs.push(rx),
                Err(e) => {
                    let se = e.downcast_ref::<ServiceError>().expect("typed shed error");
                    assert_eq!(se.code, ErrorCode::Overloaded);
                    assert_eq!(se.retry_after_ms, Some(7));
                    sheds += 1;
                }
            }
        }
        crate::sim::faults::disarm("fb_shed");
        assert!(sheds > 0, "20 rapid submissions against a 2-slot queue must shed");
        // every ADMITTED request is still answered once flushes unblock
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(20)).unwrap().unwrap();
        }
        assert_eq!(metrics.snapshot().shed, sheds);
    }

    #[test]
    fn zero_deadline_rejected_at_admission() {
        let (router, metrics) = setup();
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let err = batcher
            .handle()
            .spmv_deadline("m", EngineKind::Hbp, vec![0.0; 50], Some(0))
            .unwrap_err();
        let se = err.downcast_ref::<ServiceError>().expect("typed deadline error");
        assert_eq!(se.code, ErrorCode::DeadlineExceeded);
        let snap = metrics.snapshot();
        assert_eq!(snap.deadline_drops, 1);
        assert_eq!(snap.requests, 0, "expired work never executes");
    }

    #[test]
    fn deadline_expires_while_queued() {
        let (router, metrics) = setup_named("fb_deadline");
        let cols = router.get("fb_deadline").unwrap().cols;
        // every flush sleeps 120ms before the deadline check, so a
        // 30ms budget reliably expires while its request waits
        crate::sim::faults::arm("fb_deadline", Fault::SlowFlush { millis: 120 });
        let cfg = BatcherConfig { max_batch: 1, max_wait: Duration::ZERO, ..Default::default() };
        let batcher = Batcher::start(router, metrics.clone(), cfg);
        let h = batcher.handle();
        let rx_a =
            h.submit_spmv("fb_deadline", EngineKind::Hbp, random::vector(cols, 1), None).unwrap();
        let rx_b = h
            .submit_spmv("fb_deadline", EngineKind::Hbp, random::vector(cols, 2), Some(30))
            .unwrap();
        let a = rx_a.recv_timeout(Duration::from_secs(20)).unwrap();
        let b = rx_b.recv_timeout(Duration::from_secs(20)).unwrap();
        crate::sim::faults::disarm("fb_deadline");
        assert!(a.is_ok(), "the undeadlined request is served");
        let e = b.unwrap_err();
        let se = e.downcast_ref::<ServiceError>().expect("typed deadline error");
        assert_eq!(se.code, ErrorCode::DeadlineExceeded);
        let snap = metrics.snapshot();
        assert_eq!(snap.deadline_drops, 1);
        assert_eq!(snap.requests, 1, "only the live request executed");
    }

    #[test]
    fn engine_panic_recovered_and_matrix_keeps_serving() {
        let (router, metrics) = setup_named("fb_panic");
        let cols = router.get("fb_panic").unwrap().cols;
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        crate::sim::faults::arm("fb_panic", Fault::PanicOnSpmv { nth: 1 });
        let err = h.spmv("fb_panic", EngineKind::Hbp, random::vector(cols, 1)).unwrap_err();
        let se = err.downcast_ref::<ServiceError>().expect("typed internal error");
        assert_eq!(se.code, ErrorCode::Internal);
        // the one-shot fault disarmed itself; the SAME matrix entry
        // serves the very next request — no poisoned lock, no wedge
        let y = h.spmv("fb_panic", EngineKind::Hbp, random::vector(cols, 2)).unwrap();
        assert_eq!(y.len(), 60);
        let snap = metrics.snapshot();
        assert_eq!(snap.panics_recovered, 1);
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.requests, 1);
    }

    #[test]
    fn pool_worker_panic_recovered() {
        let (router, metrics) = setup_named("fb_worker");
        let cols = router.get("fb_worker").unwrap().cols;
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        // the probe panics inside a shared-pool worker; the pool
        // contains it, re-raises on the dispatcher, and the batcher's
        // catch_unwind converts it into a typed reply
        crate::sim::faults::arm("fb_worker", Fault::PanicInWorker { nth: 1 });
        let err = h.spmv("fb_worker", EngineKind::Hbp, random::vector(cols, 1)).unwrap_err();
        let se = err.downcast_ref::<ServiceError>().expect("typed internal error");
        assert_eq!(se.code, ErrorCode::Internal);
        let y = h.spmv("fb_worker", EngineKind::Hbp, random::vector(cols, 2)).unwrap();
        assert_eq!(y.len(), 60);
        assert_eq!(metrics.snapshot().panics_recovered, 1);
    }

    #[test]
    fn post_shutdown_sends_are_typed_refusals_not_disconnects() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        // before shutdown: served normally
        assert!(h.spmv("m", EngineKind::Hbp, random::vector(cols, 1)).is_ok());

        batcher.begin_shutdown();
        // every submission path now gets the typed shutting_down code —
        // spmv, the non-blocking submit primitive, and update alike
        let err = h.spmv("m", EngineKind::Hbp, random::vector(cols, 2)).unwrap_err();
        let se = err.downcast_ref::<ServiceError>().expect("typed shutdown error");
        assert_eq!(se.code, ErrorCode::ShuttingDown);
        assert!(se.retry_after_ms.is_none(), "shutdown is not a back-off-and-retry");
        let err = h.submit_spmv("m", EngineKind::Hbp, random::vector(cols, 3), None).unwrap_err();
        let se = err.downcast_ref::<ServiceError>().expect("typed shutdown error");
        assert_eq!(se.code, ErrorCode::ShuttingDown);
        let err = h.update("m", MatrixDelta::new().scale_row(0, 2.0)).unwrap_err();
        let se = err.downcast_ref::<ServiceError>().expect("typed shutdown error");
        assert_eq!(se.code, ErrorCode::ShuttingDown);
        // handles cloned after the fact refuse identically (the flag is
        // shared, not copied)
        let late = batcher.handle();
        let err = late.spmv("m", EngineKind::Hbp, random::vector(cols, 4)).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ServiceError>().expect("typed").code,
            ErrorCode::ShuttingDown
        );
        // refusals are not sheds and not execution errors
        let snap = metrics.snapshot();
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.requests, 1, "only the pre-shutdown request executed");
        drop(h);
        drop(late);
    }

    #[test]
    fn concurrent_updates_and_spmvs_all_answered() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router.clone(), metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        std::thread::scope(|s| {
            for i in 0..8 {
                let h = h.clone();
                s.spawn(move || {
                    let y = h.spmv("m", EngineKind::Hbp, random::vector(cols, i)).unwrap();
                    assert_eq!(y.len(), 60);
                });
            }
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    // factor 1.0 keeps values stable under any ordering
                    h.update("m", MatrixDelta::new().scale_row(1, 1.0)).unwrap();
                });
            }
        });
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 8);
        assert_eq!(snap.updates, 4);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn every_request_publishes_a_span_before_its_reply() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let tele = Arc::new(Telemetry::new(0, 64, None));
        let batcher = Batcher::start_with_telemetry(
            router.clone(),
            metrics.clone(),
            merge_cfg(),
            tele.clone(),
        );
        let h = batcher.handle();
        // two requests drained into one batch: auto + explicit resolve
        // to the same engine and fuse into one group
        let rx1 = h
            .submit_spmv_traced(
                "m",
                EngineKind::Auto,
                random::vector(cols, 1),
                None,
                Some("a".into()),
            )
            .unwrap();
        let rx2 =
            send_spmv(&h, "m", router.resolve_blocking("m").unwrap().0, random::vector(cols, 2));
        rx1.recv().unwrap().unwrap();
        rx2.recv().unwrap().unwrap();
        // replies were read, so the spans are already in the ring
        let spans = tele.recent(16);
        assert_eq!(spans.len(), 2);
        let tagged = spans.iter().find(|s| s.id.as_deref() == Some("a")).unwrap();
        assert!(tagged.ok);
        assert_eq!(tagged.group_size, 2);
        assert_eq!(tagged.spmm_width, 2, "two good requests take the fused path");
        assert!(tagged.merged_auto, "auto rode with an explicit request");
        assert_ne!(tagged.engine, "auto", "spans carry the resolved kind");
        for s in &spans {
            // the span invariant: stages sum to the total exactly
            let sum = s.queue_wait_secs + s.execute_secs + s.reply_secs;
            assert!((sum - s.total_secs).abs() < 1e-12);
            assert!(s.queue_wait_secs >= 0.0 && s.execute_secs > 0.0);
        }
        // the stage histograms saw the same two requests, and the
        // latency samples are the span totals
        let snap = metrics.snapshot();
        assert_eq!(snap.requests, 2);
        assert!(snap.p50_queue_wait_secs.is_finite());
        assert!(snap.p50_execute_secs.is_finite());
        assert!(snap.p50_reply_secs.is_finite());
    }

    #[test]
    fn dropped_and_errored_requests_trace_not_ok() {
        let name = "trace_err";
        let (router, metrics) = setup_named(name);
        let tele = Arc::new(Telemetry::new(0, 64, None));
        let batcher = Batcher::start_with_telemetry(
            router.clone(),
            metrics.clone(),
            BatcherConfig::default(),
            tele.clone(),
        );
        let h = batcher.handle();
        // mis-sized input: answered with a dimension error, traced ok=false
        let err = h.spmv(name, EngineKind::Hbp, vec![1.0; 3]).unwrap_err();
        assert!(!err.to_string().is_empty());
        let spans = tele.recent(16);
        assert_eq!(spans.len(), 1);
        assert!(!spans[0].ok);
        assert_eq!(spans[0].spmm_width, 1);
        // errored work stays out of the stage histograms
        assert!(metrics.snapshot().p50_queue_wait_secs.is_nan());
    }

    #[test]
    fn queue_depth_gauge_returns_to_zero() {
        let (router, metrics) = setup();
        let cols = router.get("m").unwrap().cols;
        let batcher = Batcher::start(router, metrics.clone(), BatcherConfig::default());
        let h = batcher.handle();
        for i in 0..4 {
            h.spmv("m", EngineKind::Hbp, random::vector(cols, i)).unwrap();
        }
        // every admission (+1) was drained by the dispatcher (-1)
        assert_eq!(metrics.snapshot().queue_depth, 0);
        drop(h);
    }
}

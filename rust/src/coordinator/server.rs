//! The serving front: in-process [`Coordinator`] API + line-delimited
//! JSON over TCP, sharded across N independent batchers.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"hello"}
//! <- {"ok":true, "proto":1, "features":["pipelining", ...], "shards":1}
//! -> {"op":"spmv", "matrix":"m1", "x":[...], "engine":"hbp", "deadline_ms":250}
//! <- {"ok":true, "y":[...], "resolved":"hbp"}
//! -> {"op":"update", "matrix":"m1", "ops":[{"kind":"scale_row","row":3,"factor":0.5}, ...]}
//! <- {"ok":true, "rows_touched":1, "blocks_touched":2, "blocks_total":40, "full_rebuild":false}
//! -> {"op":"list"}
//! <- {"ok":true, "matrices":[{"name":"m1","rows":...,"cols":...,"nnz":...}]}
//! -> {"op":"stats"}
//! <- {"ok":true, "stats":{..., "shards":[{"shard":0,...}]}}
//! -> {"op":"tune", "matrix":"m1"}
//! <- {"ok":true, "cache_hit":false, "decision":{"engine":"hbp",...},
//!     "features":{...}, "trials":{...}}
//! ```
//!
//! **Request ids.** Every request may carry an opaque `"id"` (any JSON
//! value); the reply echoes it verbatim. An id-tagged `spmv` is
//! *pipelined*: the connection thread submits it and reads the next
//! request without waiting, so replies may come back out of order and
//! the client demuxes by id ([`Connection`] does). Requests *without*
//! an id keep the original strict in-order semantics — they act as a
//! barrier, draining every in-flight pipelined reply first — so
//! pre-envelope clients (and all the existing `docs/PROTOCOL.md`
//! examples) behave exactly as before.
//!
//! **Sharding.** The coordinator runs `N ≥ 1` shards, each a private
//! [`Batcher`] (own bounded queue, own admission control, own panic
//! isolation) over the *shared* [`Router`] and tune cache. Connections
//! are assigned round-robin at accept time, so one shard's stall or
//! shed leaves the other shards' pipelines untouched. Per-shard
//! counters roll up into the global totals by construction
//! ([`ServiceMetrics::shard_of`]); the `stats` reply exposes the
//! breakdown under `"shards"`.
//!
//! Failure replies are typed: `{"ok":false, "code":..., "error":...}`
//! with `code` drawn from the stable taxonomy in [`super::error`]
//! (`bad_request`, `unknown_matrix`, `overloaded`, `deadline_exceeded`,
//! `shutting_down`, `internal`); `overloaded` sheds also carry
//! `retry_after_ms`.
//!
//! The normative spec — every op, every field, with examples executed
//! verbatim by `rust/tests/protocol_doc.rs` — lives in
//! `docs/PROTOCOL.md`, including the `hello` compatibility policy.
//!
//! `spmv` accepts `"engine":"auto"` (resolved to the matrix's tuned
//! decision); the default stays `"hbp"`. Every successful `spmv`
//! response carries `"resolved"`: the concrete engine the request
//! executed on, so a client can observe what its `auto` request merged
//! with in the batcher. An optional `deadline_ms` bounds how long the
//! request may wait in the batcher's queue before it is dropped with
//! `deadline_exceeded` instead of executed.
//!
//! The TCP front degrades instead of dying ([`ServerConfig`]): accept
//! errors are counted and survived, a connection cap sheds with one
//! `overloaded` line, a per-connection pipeline cap
//! ([`ServerConfig::max_pipeline`]) sheds the same way, over-long
//! request lines get `bad_request` and a disconnect, stalled clients
//! are timed out, and request handling is panic-isolated per request.
//! [`ServerHandle::shutdown`] stops the accept loop and drains
//! in-flight connections.
//!
//! Update op kinds mirror [`DeltaOp`]:
//! `{"kind":"set","row":R,"col":C,"value":V}`,
//! `{"kind":"scale_row","row":R,"factor":F}`,
//! `{"kind":"zero_row","row":R}`, and
//! `{"kind":"replace_row","row":R,"cols":[...],"values":[...]}`.

use super::batcher::{Batcher, BatcherConfig, BatcherHandle, SpmvReply};
use super::error::{error_reply, panic_message, reply_error, ServiceError};
use super::metrics::{MetricsSnapshot, ServiceMetrics};
use super::router::{EngineKind, Router};
use super::telemetry::{prom_text, Span, Telemetry};
use crate::preprocess::{DeltaOp, MatrixDelta, UpdateReport};
use crate::util::json::{num_arr, obj, Json};
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Wire-protocol version the `hello` op reports. Version 1 is the
/// request-id envelope: ids echo verbatim, id-tagged `spmv` pipelines.
pub const PROTO_VERSION: u64 = 1;

/// Feature tags the `hello` op advertises, for client feature-detection.
/// `"pipelining"` stays first — the executed protocol-doc examples
/// check the array's first element.
pub const PROTO_FEATURES: [&str; 7] = [
    "pipelining",
    "deadline_ms",
    "spmm_fuse",
    "auto_engine",
    "incremental_update",
    "telemetry",
    "csr_native_engines",
];

/// The in-process coordinator: shared router + N sharded batchers +
/// rolled-up metrics.
pub struct Coordinator {
    /// The matrix registry requests route through (shared by all shards).
    pub router: Arc<Router>,
    /// Global service counters — every shard's recordings roll up here,
    /// so totals always equal the sum over shards plus front-level
    /// events (accept errors, register-time tunes).
    pub metrics: Arc<ServiceMetrics>,
    /// Per-shard counters (each a [`ServiceMetrics::shard_of`] child of
    /// `metrics`), indexed by shard id.
    shard_metrics: Vec<Arc<ServiceMetrics>>,
    /// Per-shard trace rings (shared span sequence counter), indexed by
    /// shard id; drained and merge-sorted by the `trace` op.
    telemetry: Vec<Arc<Telemetry>>,
    // field order matters: `handles` must drop BEFORE `batchers`
    // (fields drop in declaration order) or Batcher::drop joins a
    // dispatcher that still sees a live sender and never exits.
    handles: Vec<BatcherHandle>,
    batchers: Vec<Batcher>,
    /// Round-robin cursor for shard assignment of in-process calls.
    rr: AtomicUsize,
}

impl Coordinator {
    /// Wrap a registered router in a single-shard batching pipeline,
    /// recording each registration's tune outcome in fresh metrics.
    pub fn new(router: Router, cfg: BatcherConfig) -> Coordinator {
        Coordinator::with_shards(router, cfg, 1)
    }

    /// [`Coordinator::new`] with `shards` independent batchers (clamped
    /// to at least 1). All shards share the router and tune cache; each
    /// gets its own bounded queue, dispatcher, and rolled-up metrics.
    pub fn with_shards(router: Router, cfg: BatcherConfig, shards: usize) -> Coordinator {
        let router = Arc::new(router);
        let metrics = Arc::new(ServiceMetrics::new());
        // registration happens before the router is shared, so every
        // tune outcome (and profiled HBP build) the registry holds is
        // recorded here exactly once — on the root: registration is
        // front-level work, not shard work
        for name in router.names() {
            let m = router.get(name).expect("registered matrix");
            metrics.record_tune(&m.tune);
            if let Some(profile) = m.build_profile() {
                metrics.record_build(&profile);
            }
        }
        let mut shard_metrics = Vec::new();
        let mut telemetry = Vec::new();
        let mut batchers = Vec::new();
        let mut handles = Vec::new();
        // one span sequence counter shared by every shard's telemetry,
        // so the trace op can merge the per-shard rings into one order
        let seq = Arc::new(std::sync::atomic::AtomicU64::new(0));
        for shard in 0..shards.max(1) {
            let m = Arc::new(ServiceMetrics::shard_of(metrics.clone()));
            let t = Arc::new(Telemetry::with_seq(
                shard,
                cfg.trace_capacity,
                cfg.slow_threshold,
                seq.clone(),
            ));
            let b = Batcher::start_with_telemetry(router.clone(), m.clone(), cfg, t.clone());
            handles.push(b.handle());
            shard_metrics.push(m);
            telemetry.push(t);
            batchers.push(b);
        }
        Coordinator {
            router,
            metrics,
            shard_metrics,
            telemetry,
            handles,
            batchers,
            rr: AtomicUsize::new(0),
        }
    }

    /// How many shards this coordinator runs.
    pub fn shards(&self) -> usize {
        self.handles.len()
    }

    /// Round-robin shard assignment for in-process calls.
    fn next_shard(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed) % self.handles.len()
    }

    /// Per-shard metric snapshots, indexed by shard id.
    pub fn shard_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.shard_metrics.iter().map(|m| m.snapshot()).collect()
    }

    /// Synchronous SpMV through the batching pipeline (round-robin
    /// across shards).
    pub fn spmv(&self, matrix: &str, engine: EngineKind, x: Vec<f64>) -> Result<Vec<f64>> {
        self.handles[self.next_shard()].spmv(matrix, engine, x)
    }

    /// Synchronous SpMV that also reports the concrete engine the
    /// request resolved to (what the protocol's `resolved` field
    /// carries).
    pub fn spmv_resolved(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
    ) -> Result<SpmvReply> {
        self.handles[self.next_shard()].spmv_resolved(matrix, engine, x)
    }

    /// [`Coordinator::spmv_resolved`] with an optional queueing deadline
    /// (milliseconds from now); a request still queued when its deadline
    /// passes is dropped with `deadline_exceeded` instead of executed.
    pub fn spmv_deadline(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
        deadline_ms: Option<u64>,
    ) -> Result<SpmvReply> {
        self.handles[self.next_shard()].spmv_deadline(matrix, engine, x, deadline_ms)
    }

    /// Synchronous matrix update through the batching pipeline (ordered
    /// with SpMV submissions on the same shard's queue).
    pub fn update(&self, matrix: &str, delta: MatrixDelta) -> Result<UpdateReport> {
        self.handles[self.next_shard()].update(matrix, delta)
    }

    /// A submission handle onto one of this coordinator's batchers
    /// (round-robin; use [`Coordinator::shard_handle`] to pick).
    pub fn handle(&self) -> BatcherHandle {
        self.shard_handle(self.next_shard())
    }

    /// The submission handle of a specific shard (index taken modulo
    /// the shard count).
    pub fn shard_handle(&self, shard: usize) -> BatcherHandle {
        self.batchers[shard % self.batchers.len()].handle()
    }

    /// Process one protocol request on a round-robin shard (shared by
    /// TCP and tests). Never panics: failures become
    /// `{"ok":false,"code":...,"error":...}` replies. The request's
    /// `"id"`, if any, is echoed on the reply verbatim.
    pub fn handle_json(&self, line: &str) -> Json {
        self.handle_json_on(self.next_shard(), line)
    }

    /// [`Coordinator::handle_json`] pinned to a shard — what a TCP
    /// connection (which keeps its accept-time shard for its lifetime)
    /// runs. A line that does not parse gets a `bad_request` reply with
    /// no id (there is no trustworthy envelope to echo from).
    pub fn handle_json_on(&self, shard: usize, line: &str) -> Json {
        let req = match Json::parse(line).context("parsing request JSON") {
            Ok(req) => req,
            Err(e) => return error_reply(&e),
        };
        let id = req.get("id").cloned();
        attach_id(self.handle_request(shard, &req), id)
    }

    /// Process one parsed request on a shard. Panic-isolated (the
    /// batcher already isolates engine panics; this catches everything
    /// else) so one poisoned request cannot take its connection thread
    /// down; a recovered panic is an `internal` reply counted against
    /// the shard it ran on. Does NOT attach the id — callers that own
    /// the envelope do ([`Coordinator::handle_json_on`], the pipelined
    /// connection loop).
    pub fn handle_request(&self, shard: usize, req: &Json) -> Json {
        let shard = shard % self.handles.len();
        match catch_unwind(AssertUnwindSafe(|| self.try_handle(shard, req))) {
            Ok(Ok(v)) => v,
            Ok(Err(e)) => error_reply(&e),
            Err(p) => {
                self.shard_metrics[shard].record_panic_recovered();
                self.shard_metrics[shard].record_error();
                error_reply(&anyhow::Error::new(ServiceError::internal(format!(
                    "request handling panicked (recovered): {}",
                    panic_message(p)
                ))))
            }
        }
    }

    fn try_handle(&self, shard: usize, req: &Json) -> Result<Json> {
        match req.req_str("op")? {
            "hello" => Ok(obj(&[
                ("ok", Json::Bool(true)),
                ("proto", Json::Num(PROTO_VERSION as f64)),
                (
                    "features",
                    Json::Arr(PROTO_FEATURES.iter().map(|f| Json::Str((*f).to_string())).collect()),
                ),
                ("shards", Json::Num(self.shards() as f64)),
            ])),
            "spmv" => {
                let p = parse_spmv(req)?;
                // the envelope id (when present) rides into the batcher
                // so the request's trace span echoes it
                let trace_id = req.get("id").map(|id| match id {
                    Json::Str(s) => s.clone(),
                    other => other.to_string(),
                });
                let rx = self.handles[shard].submit_spmv_traced(
                    &p.matrix,
                    p.engine,
                    p.x,
                    p.deadline_ms,
                    trace_id,
                )?;
                let reply = rx.recv().map_err(|_| {
                    anyhow::Error::new(ServiceError::shutting_down(
                        "batcher shut down before answering the request",
                    ))
                })??;
                Ok(spmv_reply_json(&reply))
            }
            "update" => {
                let matrix = req.req_str("matrix")?;
                let delta = delta_from_json(req)?;
                let report = self.handles[shard].update(matrix, delta)?;
                Ok(report_json(&report))
            }
            "list" => {
                let matrices: Vec<Json> = self
                    .router
                    .names()
                    .into_iter()
                    .filter_map(|n| {
                        let m = self.router.get(n).ok()?;
                        Some(obj(&[
                            ("name", Json::Str(n.to_string())),
                            ("rows", Json::Num(m.rows as f64)),
                            ("cols", Json::Num(m.cols as f64)),
                            ("nnz", Json::Num(m.nnz as f64)),
                            ("preprocess_secs", Json::Num(m.preprocess_secs)),
                        ]))
                    })
                    .collect();
                Ok(obj(&[("ok", Json::Bool(true)), ("matrices", Json::Arr(matrices))]))
            }
            "stats" => {
                let mut stats = self.metrics.snapshot().to_json();
                let shards: Vec<Json> = self
                    .shard_metrics
                    .iter()
                    .enumerate()
                    .map(|(i, m)| m.snapshot().shard_json(i))
                    .collect();
                if let Json::Obj(map) = &mut stats {
                    map.insert("shards".to_string(), Json::Arr(shards));
                }
                Ok(obj(&[("ok", Json::Bool(true)), ("stats", stats)]))
            }
            "tune" => {
                let matrix = req.req_str("matrix")?;
                let m = self.router.get(matrix)?;
                Ok(tune_json(&m.tune))
            }
            "trace" => {
                let limit = match req.get("limit") {
                    None => 32,
                    Some(v) => v.as_usize().context("\"limit\" must be a number")?,
                };
                // merge the per-shard rings by the shared sequence
                // counter, then keep the global newest `limit`
                let mut spans: Vec<Span> =
                    self.telemetry.iter().flat_map(|t| t.recent(limit)).collect();
                spans.sort_by_key(|s| s.seq);
                let skip = spans.len().saturating_sub(limit);
                let dropped: u64 = self.telemetry.iter().map(|t| t.dropped()).sum();
                Ok(obj(&[
                    ("ok", Json::Bool(true)),
                    ("dropped", Json::Num(dropped as f64)),
                    ("spans", Json::Arr(spans[skip..].iter().map(Span::to_json).collect())),
                ]))
            }
            "metrics" => {
                let prom = prom_text(&self.metrics, &self.shard_metrics);
                Ok(obj(&[("ok", Json::Bool(true)), ("prom", Json::Str(prom))]))
            }
            other => anyhow::bail!("unknown op {other:?}"),
        }
    }
}

/// A validated `spmv` request body (everything but the envelope).
struct SpmvParams {
    matrix: String,
    engine: EngineKind,
    x: Vec<f64>,
    deadline_ms: Option<u64>,
}

/// Validate an `spmv` request's fields — shared by the inline
/// (un-id'd) path and the pipelined path, so both reject malformed
/// requests with identical `bad_request` messages.
fn parse_spmv(req: &Json) -> Result<SpmvParams> {
    let matrix = req.req_str("matrix")?.to_string();
    let engine: EngineKind =
        req.get("engine").and_then(Json::as_str).unwrap_or("hbp").parse()?;
    let x: Vec<f64> = req
        .get("x")
        .and_then(Json::as_arr)
        .context("missing array field \"x\"")?
        .iter()
        .map(|v| v.as_f64().context("non-numeric x entry"))
        .collect::<Result<_>>()?;
    let deadline_ms = match req.get("deadline_ms") {
        None => None,
        Some(v) => {
            let n = v.as_f64().context("non-numeric \"deadline_ms\"")?;
            anyhow::ensure!(
                n.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(&n),
                "deadline_ms must be a non-negative integer, got {n}"
            );
            Some(n as u64)
        }
    };
    Ok(SpmvParams { matrix, engine, x, deadline_ms })
}

/// Serialize a successful SpMV result into the protocol reply.
fn spmv_reply_json(reply: &SpmvReply) -> Json {
    obj(&[
        ("ok", Json::Bool(true)),
        ("y", num_arr(&reply.y)),
        ("resolved", Json::Str(reply.resolved.to_string())),
    ])
}

/// Echo the request's opaque `"id"` onto a reply object, verbatim —
/// any JSON value (string, number, even null) round-trips untouched.
fn attach_id(mut reply: Json, id: Option<Json>) -> Json {
    if let Some(id) = id {
        if let Json::Obj(map) = &mut reply {
            map.insert("id".to_string(), id);
        }
    }
    reply
}

/// Strict index parse for update ops: `Json::as_usize` is a saturating
/// float cast (`-1` → 0, `3.9` → 3), which on a *write* endpoint would
/// silently mutate the wrong row — reject anything non-integral,
/// negative, or out of exact-f64 range instead.
fn req_index(op: &Json, key: &str, ctx: &str) -> Result<usize> {
    let n = op
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("{ctx}: missing numeric {key:?}"))?;
    anyhow::ensure!(
        n.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(&n),
        "{ctx}: {key} must be a non-negative integer, got {n}"
    );
    Ok(n as usize)
}

/// Parse the `ops` array of an `update` request into a [`MatrixDelta`].
fn delta_from_json(req: &Json) -> Result<MatrixDelta> {
    let ops = req
        .get("ops")
        .and_then(Json::as_arr)
        .context("missing array field \"ops\"")?;
    let mut delta = MatrixDelta::new();
    for (i, op) in ops.iter().enumerate() {
        let ctx = format!("ops[{i}]");
        let kind = op.req_str("kind").with_context(|| ctx.clone())?;
        let row = req_index(op, "row", &ctx)?;
        match kind {
            "set" => {
                let col = req_index(op, "col", &ctx)?;
                let value = op
                    .get("value")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("ops[{i}]: missing numeric \"value\""))?;
                delta = delta.set(row, col, value);
            }
            "scale_row" => {
                let factor = op
                    .get("factor")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("ops[{i}]: missing numeric \"factor\""))?;
                delta = delta.scale_row(row, factor);
            }
            "zero_row" => delta = delta.zero_row(row),
            "replace_row" => {
                let cols: Vec<u32> = op
                    .get("cols")
                    .and_then(Json::as_arr)
                    .with_context(|| format!("ops[{i}]: missing array \"cols\""))?
                    .iter()
                    .map(|v| {
                        let n = v.as_f64().with_context(|| format!("ops[{i}]: non-numeric col"))?;
                        anyhow::ensure!(
                            n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n),
                            "ops[{i}]: col must be a non-negative integer, got {n}"
                        );
                        Ok(n as u32)
                    })
                    .collect::<Result<_>>()?;
                let values: Vec<f64> = op
                    .get("values")
                    .and_then(Json::as_arr)
                    .with_context(|| format!("ops[{i}]: missing array \"values\""))?
                    .iter()
                    .map(|v| v.as_f64().with_context(|| format!("ops[{i}]: non-numeric value")))
                    .collect::<Result<_>>()?;
                delta = delta.replace_row(row, cols, values);
            }
            other => bail!("ops[{i}]: unknown kind {other:?}"),
        }
    }
    Ok(delta)
}

/// Serialize a delta into the protocol's `ops` array (client side).
fn delta_to_json(delta: &MatrixDelta) -> Json {
    let ops: Vec<Json> = delta
        .ops
        .iter()
        .map(|op| match op {
            DeltaOp::Set { row, col, value } => obj(&[
                ("kind", Json::Str("set".into())),
                ("row", Json::Num(*row as f64)),
                ("col", Json::Num(*col as f64)),
                ("value", Json::Num(*value)),
            ]),
            DeltaOp::ScaleRow { row, factor } => obj(&[
                ("kind", Json::Str("scale_row".into())),
                ("row", Json::Num(*row as f64)),
                ("factor", Json::Num(*factor)),
            ]),
            DeltaOp::ZeroRow { row } => obj(&[
                ("kind", Json::Str("zero_row".into())),
                ("row", Json::Num(*row as f64)),
            ]),
            DeltaOp::ReplaceRow { row, cols, values } => obj(&[
                ("kind", Json::Str("replace_row".into())),
                ("row", Json::Num(*row as f64)),
                (
                    "cols",
                    Json::Arr(cols.iter().map(|&c| Json::Num(c as f64)).collect()),
                ),
                ("values", num_arr(values)),
            ]),
        })
        .collect();
    Json::Arr(ops)
}

/// Serialize a registration's tuning record for the `tune` op.
fn tune_json(t: &crate::tune::TuneOutcome) -> Json {
    obj(&[
        ("ok", Json::Bool(true)),
        ("key", Json::Str(format!("{:016x}", t.key))),
        ("cache_hit", Json::Bool(t.cache_hit)),
        (
            "decision",
            obj(&[
                ("engine", Json::Str(t.decision.kind.to_string())),
                ("rows_per_block", Json::Num(t.decision.cfg.rows_per_block as f64)),
                ("cols_per_block", Json::Num(t.decision.cfg.cols_per_block as f64)),
                ("warp", Json::Num(t.decision.cfg.warp as f64)),
                ("trial_secs", Json::Num(t.decision.trial_secs)),
            ]),
        ),
        ("features", t.features.to_json()),
        (
            "trials",
            match &t.report {
                Some(report) => report.to_json(),
                None => Json::Null,
            },
        ),
        ("tune_secs", Json::Num(t.tune_secs)),
        (
            "phases",
            obj(&[
                ("features_secs", Json::Num(t.phases.features_secs)),
                ("trials_secs", Json::Num(t.phases.trials_secs)),
            ]),
        ),
    ])
}

fn report_json(report: &UpdateReport) -> Json {
    obj(&[
        ("ok", Json::Bool(true)),
        ("rows_touched", Json::Num(report.rows_touched as f64)),
        ("blocks_touched", Json::Num(report.blocks_touched as f64)),
        ("blocks_total", Json::Num(report.blocks_total as f64)),
        ("full_rebuild", Json::Bool(report.full_rebuild)),
    ])
}

/// Tunables for the TCP front's self-protection. Everything here exists
/// so a misbehaving *client* degrades its own service, not the server:
/// the connection cap bounds thread count, the read timeout unsticks
/// threads pinned by stalled clients, the line cap bounds per-request
/// memory, and the pipeline cap bounds per-connection waiter threads.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Maximum simultaneous connections; accepts beyond this get one
    /// `overloaded` reply line (with `retry_after_ms`) and are closed.
    pub max_conns: usize,
    /// Per-connection read timeout: a client silent this long
    /// mid-request is disconnected. `None` waits forever.
    pub read_timeout: Option<Duration>,
    /// Longest accepted request line in bytes. A longer line gets a
    /// `bad_request` reply and a disconnect — the remainder of the line
    /// was never read, so the stream cannot be resynchronized.
    pub max_line_bytes: usize,
    /// How long [`ServerHandle::shutdown`] waits for in-flight
    /// connections to finish before returning anyway.
    pub shutdown_grace: Duration,
    /// Most id-tagged `spmv` requests one connection may have in flight;
    /// beyond this the request is shed with `overloaded` (id echoed) —
    /// the pipelined analogue of the batcher's bounded queue.
    pub max_pipeline: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 256,
            read_timeout: Some(Duration::from_secs(60)),
            max_line_bytes: 8 * 1024 * 1024,
            shutdown_grace: Duration::from_secs(2),
            max_pipeline: 128,
        }
    }
}

/// Back-off hint on connection-limit and pipeline-limit sheds (the
/// batcher's queue sheds carry the configurable
/// `BatcherConfig::retry_after_ms` instead).
const CONN_RETRY_AFTER_MS: u64 = 50;

/// A running TCP server: its bound address plus shutdown control.
/// Dropping the handle also shuts the server down.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The address the listener actually bound (resolves `:0` ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, then give in-flight
    /// connections up to `shutdown_grace` to finish.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block until the accept loop exits (i.e. until something else
    /// triggers shutdown) — what the foreground `serve` does.
    pub fn wait(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the blocking accept() so the loop observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve the coordinator over TCP in a background accept thread,
/// returning the [`ServerHandle`] that controls it.
pub fn serve_with(
    coordinator: Arc<Coordinator>,
    addr: &str,
    cfg: ServerConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let accept_thread = {
        let shutdown = shutdown.clone();
        std::thread::Builder::new()
            .name("hbp-accept".into())
            .spawn(move || accept_loop(coordinator, listener, cfg, shutdown))
            .context("spawning accept thread")?
    };
    Ok(ServerHandle { addr, shutdown, accept_thread: Some(accept_thread) })
}

/// Serve the coordinator over TCP in the foreground (what `hbp serve`
/// runs). Returns only after shutdown is triggered elsewhere — in
/// practice, when the process exits.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str, cfg: ServerConfig) -> Result<()> {
    let handle = serve_with(coordinator, addr, cfg)?;
    eprintln!("hbp-spmv serving on {}", handle.addr());
    handle.wait();
    Ok(())
}

/// Serve on an ephemeral port, returning the bound address (tests/e2e).
/// The server runs until process exit; use [`serve_with`] (or
/// [`serve_background_with`]) when the caller needs shutdown control.
pub fn serve_background(coordinator: Arc<Coordinator>) -> Result<SocketAddr> {
    let handle = serve_background_with(coordinator, ServerConfig::default())?;
    let addr = handle.addr();
    // intentionally leak the handle: its Drop would stop the server
    std::mem::forget(handle);
    Ok(addr)
}

/// [`serve_background`] with explicit config and shutdown control.
pub fn serve_background_with(
    coordinator: Arc<Coordinator>,
    cfg: ServerConfig,
) -> Result<ServerHandle> {
    serve_with(coordinator, "127.0.0.1:0", cfg)
}

fn accept_loop(
    c: Arc<Coordinator>,
    listener: TcpListener,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) {
    let conns = Arc::new(AtomicUsize::new(0));
    let nshards = c.shards();
    // the accept loop is single-threaded, so a plain counter assigns
    // connections to shards round-robin: connection k -> shard k % N,
    // fixed for the connection's lifetime
    let mut conn_seq: usize = 0;
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                // a transient accept failure (ECONNABORTED, EMFILE, ...)
                // must not kill the server: count it, log it, go on
                c.metrics.record_accept_error();
                eprintln!("hbp-spmv: accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            break; // usually the shutdown poke connection itself
        }
        let shard = conn_seq % nshards;
        conn_seq += 1;
        if conns.load(Ordering::SeqCst) >= cfg.max_conns {
            // charged to the shard the connection would have landed on,
            // so the rolled-up totals still cover every shed
            c.shard_metrics[shard].record_shed();
            refuse_conn(stream, cfg.max_conns);
            continue;
        }
        conns.fetch_add(1, Ordering::SeqCst);
        let conn_c = c.clone();
        let conn_counter = conns.clone();
        let conn_shutdown = shutdown.clone();
        let spawned = std::thread::Builder::new().name("hbp-conn".into()).spawn(move || {
            let _ = handle_conn(conn_c, stream, shard, cfg, conn_shutdown);
            conn_counter.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            conns.fetch_sub(1, Ordering::SeqCst);
            c.metrics.record_accept_error();
        }
    }
    // drain: bounded wait for in-flight connections, then a final
    // metrics snapshot so a shutdown always leaves a service record
    let t = std::time::Instant::now();
    while conns.load(Ordering::SeqCst) > 0 && t.elapsed() < cfg.shutdown_grace {
        std::thread::sleep(Duration::from_millis(5));
    }
    let s = c.metrics.snapshot();
    eprintln!(
        "hbp-spmv: shutdown — {} requests, {} errors, {} shed, {} deadline drops, \
         {} panics recovered, {} accept errors",
        s.requests, s.errors, s.shed, s.deadline_drops, s.panics_recovered, s.accept_errors
    );
}

/// Over the connection cap: one `overloaded` line, then close.
fn refuse_conn(stream: TcpStream, max_conns: usize) {
    let e = anyhow::Error::new(ServiceError::overloaded(
        format!("connection limit reached ({max_conns} open)"),
        CONN_RETRY_AFTER_MS,
    ));
    let mut writer = stream;
    let _ = writer.write_all(error_reply(&e).to_string().as_bytes());
    let _ = writer.write_all(b"\n");
}

enum ReadOutcome {
    Line,
    Eof,
    TooLong,
}

/// `read_line` with a byte cap: reads at most `cap + 1` bytes, so an
/// oversized line is detected without buffering it — seeing `cap + 1`
/// bytes before the newline means the line is over the cap.
fn read_capped_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    cap: usize,
) -> std::io::Result<ReadOutcome> {
    let mut limited = std::io::Read::take(&mut *reader, cap as u64 + 1);
    let n = limited.read_line(line)?;
    if n == 0 {
        Ok(ReadOutcome::Eof)
    } else if n > cap {
        Ok(ReadOutcome::TooLong)
    } else {
        Ok(ReadOutcome::Line)
    }
}

/// Everything the per-connection loop needs, bundled so the loop and
/// its pipelined-dispatch helper share one signature.
struct ConnCtx<'a> {
    c: &'a Coordinator,
    shard: usize,
    cfg: ServerConfig,
    shutdown: &'a AtomicBool,
    /// Sender half of the connection's reply outbox (the writer thread
    /// owns the receiving half and the socket's write half).
    out_tx: &'a mpsc::Sender<String>,
    /// Id-tagged spmv requests submitted but not yet answered.
    inflight: &'a Arc<AtomicUsize>,
    /// Live reply-waiter threads; un-id'd requests join them (barrier).
    waiters: &'a mut Vec<std::thread::JoinHandle<()>>,
}

/// One TCP connection. A single writer thread owns the write half and
/// drains a reply outbox, so the reader loop and any number of
/// pipelined reply waiters can emit lines without interleaving bytes.
fn handle_conn(
    c: Arc<Coordinator>,
    stream: TcpStream,
    shard: usize,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(cfg.read_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let (out_tx, out_rx) = mpsc::channel::<String>();
    let writer = std::thread::Builder::new()
        .name("hbp-conn-writer".into())
        .spawn(move || {
            let mut w = stream;
            // runs until every sender (reader loop + waiters) is gone
            while let Ok(reply) = out_rx.recv() {
                if w.write_all(reply.as_bytes()).is_err() || w.write_all(b"\n").is_err() {
                    break; // client gone; senders' failed sends are ignored
                }
            }
        })
        .context("spawning connection writer")?;
    let inflight = Arc::new(AtomicUsize::new(0));
    let mut waiters = Vec::new();
    let res = conn_loop(
        &mut ConnCtx {
            c: &c,
            shard,
            cfg,
            shutdown: &shutdown,
            out_tx: &out_tx,
            inflight: &inflight,
            waiters: &mut waiters,
        },
        &mut reader,
    );
    // teardown order matters: waiters hold outbox senders, so join them
    // first, then drop ours so the writer's recv loop ends, then join it
    join_waiters(&mut waiters);
    drop(out_tx);
    let _ = writer.join();
    res
}

fn conn_loop(ctx: &mut ConnCtx<'_>, reader: &mut BufReader<TcpStream>) -> Result<()> {
    let mut line = String::new();
    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        line.clear();
        match read_capped_line(reader, &mut line, ctx.cfg.max_line_bytes) {
            Ok(ReadOutcome::Eof) => return Ok(()), // client closed
            Ok(ReadOutcome::Line) => {}
            Ok(ReadOutcome::TooLong) => {
                ctx.c.shard_metrics[ctx.shard].record_error();
                let e = anyhow::Error::new(ServiceError::bad_request(format!(
                    "request line exceeds {} bytes",
                    ctx.cfg.max_line_bytes
                )));
                let _ = ctx.out_tx.send(error_reply(&e).to_string());
                return Ok(()); // cannot resync past the unread remainder
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(()); // stalled client: reclaim the thread
            }
            Err(e) => return Err(e.into()),
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match Json::parse(trimmed).context("parsing request JSON") {
            Err(e) => {
                // unparseable: there is no trustworthy id to echo, so
                // treat it as an un-id'd (ordered) request
                join_waiters(ctx.waiters);
                let _ = ctx.out_tx.send(error_reply(&e).to_string());
            }
            Ok(req) => match req.get("id").cloned() {
                Some(id) => handle_tagged(ctx, &req, id),
                None => {
                    // un-id'd requests keep strict in-order semantics:
                    // drain every pipelined reply first (their outbox
                    // lines are queued before ours), then run inline
                    join_waiters(ctx.waiters);
                    let reply = ctx.c.handle_request(ctx.shard, &req);
                    let _ = ctx.out_tx.send(reply.to_string());
                }
            },
        }
        // reap finished waiters so the vec tracks only live pipelines
        ctx.waiters.retain(|h| !h.is_finished());
    }
}

/// Dispatch one id-tagged request. Tagged `spmv` pipelines: submit to
/// the shard's batcher, hand the reply receiver to a waiter thread, and
/// return to the read loop immediately. Every other tagged op answers
/// inline (still without blocking on outstanding spmv replies — tagged
/// replies may reorder freely).
fn handle_tagged(ctx: &mut ConnCtx<'_>, req: &Json, id: Json) {
    if req.get("op").and_then(Json::as_str) != Some("spmv") {
        let reply = attach_id(ctx.c.handle_request(ctx.shard, req), Some(id));
        let _ = ctx.out_tx.send(reply.to_string());
        return;
    }
    if ctx.inflight.load(Ordering::SeqCst) >= ctx.cfg.max_pipeline {
        ctx.c.shard_metrics[ctx.shard].record_shed();
        let e = anyhow::Error::new(ServiceError::overloaded(
            format!("pipeline limit reached ({} in flight)", ctx.cfg.max_pipeline),
            CONN_RETRY_AFTER_MS,
        ));
        let _ = ctx.out_tx.send(attach_id(error_reply(&e), Some(id)).to_string());
        return;
    }
    let params = match parse_spmv(req) {
        Ok(p) => p,
        Err(e) => {
            let _ = ctx.out_tx.send(attach_id(error_reply(&e), Some(id)).to_string());
            return;
        }
    };
    // the envelope id rides into the batcher so the request's span
    // echoes it (string ids verbatim, other JSON values serialized)
    let trace_id = Some(match &id {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    });
    let rx = match ctx.c.handles[ctx.shard].submit_spmv_traced(
        &params.matrix,
        params.engine,
        params.x,
        params.deadline_ms,
        trace_id,
    ) {
        Ok(rx) => rx,
        Err(e) => {
            // admission refusal (overloaded / shutting_down): answered
            // immediately; the batcher already recorded the shed
            let _ = ctx.out_tx.send(attach_id(error_reply(&e), Some(id)).to_string());
            return;
        }
    };
    ctx.inflight.fetch_add(1, Ordering::SeqCst);
    let shard_metrics = ctx.c.shard_metrics[ctx.shard].clone();
    shard_metrics.gauge_inflight_pipeline(1);
    let out = ctx.out_tx.clone();
    let inflight = ctx.inflight.clone();
    let id_on_fail = id.clone();
    let spawned = std::thread::Builder::new().name("hbp-conn-waiter".into()).spawn(move || {
        let result = match rx.recv() {
            Ok(r) => r,
            // the reply channel dying without an answer means the
            // batcher tore down mid-request
            Err(_) => Err(anyhow::Error::new(ServiceError::shutting_down(
                "batcher shut down before answering the request",
            ))),
        };
        let reply = match result {
            Ok(r) => spmv_reply_json(&r),
            Err(e) => error_reply(&e),
        };
        let _ = out.send(attach_id(reply, Some(id)).to_string());
        inflight.fetch_sub(1, Ordering::SeqCst);
        shard_metrics.gauge_inflight_pipeline(-1);
    });
    match spawned {
        Ok(h) => ctx.waiters.push(h),
        Err(_) => {
            // no waiter thread: answer the id inline rather than
            // silently dropping the reply (the computed result, if any,
            // lands in the dropped receiver and is discarded)
            ctx.inflight.fetch_sub(1, Ordering::SeqCst);
            ctx.c.shard_metrics[ctx.shard].gauge_inflight_pipeline(-1);
            let e = anyhow::Error::new(ServiceError::internal("failed to spawn reply waiter"));
            let _ = ctx.out_tx.send(attach_id(error_reply(&e), Some(id_on_fail)).to_string());
        }
    }
}

/// Barrier: block until every pipelined reply has been handed to the
/// writer's outbox (outbox FIFO then preserves reply-before-barrier
/// ordering on the wire).
fn join_waiters(waiters: &mut Vec<std::thread::JoinHandle<()>>) {
    for h in waiters.drain(..) {
        let _ = h.join();
    }
}

/// Client side: decode a successful spmv reply (or surface its typed
/// error).
fn spmv_reply_from_json(resp: &Json) -> Result<SpmvReply> {
    if resp.get("ok") != Some(&Json::Bool(true)) {
        return Err(reply_error(resp));
    }
    let y: Vec<f64> = resp
        .get("y")
        .and_then(Json::as_arr)
        .context("missing y")?
        .iter()
        .map(|v| v.as_f64().context("bad y entry"))
        .collect::<Result<_>>()?;
    let resolved: EngineKind = resp
        .get("resolved")
        .and_then(Json::as_str)
        .context("missing resolved")?
        .parse()?;
    Ok(SpmvReply { y, resolved })
}

/// A protocol connection: owns the socket and demuxes replies by
/// request `id`, so any number of [`SpmvTicket`]s can be in flight at
/// once.
///
/// ```no_run
/// # use hbp_spmv::coordinator::{Connection, EngineKind};
/// # fn demo() -> anyhow::Result<()> {
/// let mut conn = Connection::connect("127.0.0.1:7070")?;
/// let t1 = conn.spmv("m1", &[1.0, 2.0]).engine(EngineKind::Auto).submit()?;
/// let t2 = conn.spmv("m1", &[3.0, 4.0]).deadline_ms(250).submit()?;
/// let r2 = conn.wait(&t2)?; // replies may arrive in any order
/// let r1 = conn.wait(&t1)?; // ... an early reply is parked, not lost
/// # let _ = (r1, r2); Ok(()) }
/// ```
///
/// Replies that arrive while the caller waits on a *different* ticket
/// are parked and handed out when their ticket is waited on — nothing
/// is dropped, regardless of wire order.
pub struct Connection {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Generator for this connection's request ids (`"c0"`, `"c1"`, ...).
    next_id: u64,
    /// Ids submitted through this connection and not yet claimed.
    outstanding: HashSet<String>,
    /// Replies that arrived before their ticket was waited on.
    parked: HashMap<String, Json>,
}

impl Connection {
    /// Connect to a serving coordinator.
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Connection> {
        let stream = TcpStream::connect(addr)?;
        Ok(Connection {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            next_id: 0,
            outstanding: HashSet::new(),
            parked: HashMap::new(),
        })
    }

    /// The versioned handshake: send `{"op":"hello"}` and return the
    /// server's `{proto, features, shards}` reply for feature-detection.
    pub fn hello(&mut self) -> Result<Json> {
        let resp = self.call(&obj(&[("op", Json::Str("hello".into()))]))?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(reply_error(&resp));
        }
        Ok(resp)
    }

    /// Send one request object and read its reply. A request carrying a
    /// string `"id"` is matched by id (replies to other outstanding
    /// tickets are parked); an un-id'd request takes the next in-order
    /// reply, exactly like the pre-envelope protocol.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.send_line(&req.to_string())?;
        let want = req.get("id").and_then(Json::as_str).map(str::to_string);
        self.read_reply(want.as_deref())
    }

    /// Start building an SpMV request against a hosted matrix. Finish
    /// with [`SpmvBuilder::send`] (blocking round-trip) or
    /// [`SpmvBuilder::submit`] (pipelined; claim later via
    /// [`Connection::wait`]).
    pub fn spmv(&mut self, matrix: &str, x: &[f64]) -> SpmvBuilder<'_> {
        SpmvBuilder {
            conn: self,
            matrix: matrix.to_string(),
            x: x.to_vec(),
            engine: None,
            deadline_ms: None,
        }
    }

    /// Apply a delta to a hosted matrix, returning the server's report.
    pub fn update(&mut self, matrix: &str, delta: &MatrixDelta) -> Result<UpdateReport> {
        let req = obj(&[
            ("op", Json::Str("update".into())),
            ("matrix", Json::Str(matrix.into())),
            ("ops", delta_to_json(delta)),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(reply_error(&resp));
        }
        Ok(UpdateReport {
            rows_touched: resp.req_usize("rows_touched")?,
            blocks_touched: resp.req_usize("blocks_touched")?,
            blocks_total: resp.req_usize("blocks_total")?,
            full_rebuild: resp.get("full_rebuild") == Some(&Json::Bool(true)),
        })
    }

    /// Pipeline a whole batch: submit every `xs[i]` before reading any
    /// reply, then claim them in submission order. Replies are returned
    /// aligned with `xs` no matter what order the wire delivered them.
    pub fn pipeline(
        &mut self,
        matrix: &str,
        engine: EngineKind,
        xs: &[Vec<f64>],
    ) -> Result<Vec<SpmvReply>> {
        let mut tickets = Vec::with_capacity(xs.len());
        for x in xs {
            tickets.push(self.spmv(matrix, x).engine(engine).submit()?);
        }
        tickets.iter().map(|t| self.wait(t)).collect()
    }

    /// Block until the ticket's reply arrives (or surface its typed
    /// error). Replies to other tickets read along the way are parked.
    pub fn wait(&mut self, ticket: &SpmvTicket) -> Result<SpmvReply> {
        let resp = self.read_reply(Some(&ticket.id))?;
        spmv_reply_from_json(&resp)
    }

    /// How many replies arrived out of order and are parked awaiting
    /// their ticket's [`Connection::wait`] (observability for tests).
    pub fn parked(&self) -> usize {
        self.parked.len()
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Send one id-tagged spmv without reading anything back.
    fn submit_spmv(
        &mut self,
        matrix: &str,
        x: &[f64],
        engine: Option<EngineKind>,
        deadline_ms: Option<u64>,
    ) -> Result<SpmvTicket> {
        let id = format!("c{}", self.next_id);
        self.next_id += 1;
        let mut fields = vec![
            ("op", Json::Str("spmv".into())),
            ("matrix", Json::Str(matrix.into())),
            ("x", num_arr(x)),
            ("id", Json::Str(id.clone())),
        ];
        if let Some(engine) = engine {
            fields.push(("engine", Json::Str(engine.to_string())));
        }
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms as f64)));
        }
        self.send_line(&obj(&fields).to_string())?;
        self.outstanding.insert(id.clone());
        Ok(SpmvTicket { id })
    }

    /// The demux core: read reply lines until the wanted one shows up,
    /// parking replies that belong to other outstanding tickets.
    /// `want: None` (un-id'd call) returns the next reply as-is.
    fn read_reply(&mut self, want: Option<&str>) -> Result<Json> {
        if let Some(id) = want {
            if let Some(parked) = self.parked.remove(id) {
                return Ok(parked);
            }
        }
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "server closed the connection");
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let reply = Json::parse(trimmed).context("parsing reply JSON")?;
            let rid = reply.get("id").and_then(Json::as_str).map(str::to_string);
            if let Some(rid) = &rid {
                if self.outstanding.remove(rid.as_str()) && want != Some(rid.as_str()) {
                    // someone else's reply arrived first: park it
                    self.parked.insert(rid.clone(), reply);
                    continue;
                }
            } else if want.is_some() {
                bail!("untagged reply while waiting for id {want:?}: {reply}");
            }
            return Ok(reply);
        }
    }
}

/// Claim check for one in-flight pipelined SpMV; redeem with
/// [`Connection::wait`].
pub struct SpmvTicket {
    id: String,
}

impl SpmvTicket {
    /// The wire `id` the reply will carry.
    pub fn id(&self) -> &str {
        &self.id
    }
}

/// Typed builder for one SpMV request (created by
/// [`Connection::spmv`]): `conn.spmv("m1", &x).engine(auto).deadline_ms(250).send()`.
pub struct SpmvBuilder<'a> {
    conn: &'a mut Connection,
    matrix: String,
    x: Vec<f64>,
    engine: Option<EngineKind>,
    deadline_ms: Option<u64>,
}

impl SpmvBuilder<'_> {
    /// Request a specific engine (`Auto` resolves to the tuned
    /// decision). Unset, the server default (`hbp`) applies.
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Bound how long the request may queue before being dropped with
    /// `deadline_exceeded` instead of executed.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Blocking round-trip: send, then wait for this reply.
    pub fn send(self) -> Result<SpmvReply> {
        let SpmvBuilder { conn, matrix, x, engine, deadline_ms } = self;
        let ticket = conn.submit_spmv(&matrix, &x, engine, deadline_ms)?;
        conn.wait(&ticket)
    }

    /// Pipelined send: issue the request and return immediately with
    /// the [`SpmvTicket`] to [`Connection::wait`] on later.
    pub fn submit(self) -> Result<SpmvTicket> {
        let SpmvBuilder { conn, matrix, x, engine, deadline_ms } = self;
        conn.submit_spmv(&matrix, &x, engine, deadline_ms)
    }
}

/// The original one-shot blocking client, now a thin wrapper over
/// [`Connection`] — kept so pre-envelope call sites (examples, old
/// tests) compile unchanged.
pub struct Client {
    conn: Connection,
}

impl Client {
    /// Connect to a serving coordinator.
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        Ok(Client { conn: Connection::connect(addr)? })
    }

    /// Send one request object and read one response line.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.conn.call(req)
    }

    /// SpMV against a hosted matrix (default engine; the response's
    /// `resolved` field is available through [`Connection::spmv`]).
    pub fn spmv(&mut self, matrix: &str, x: &[f64]) -> Result<Vec<f64>> {
        self.conn.spmv(matrix, x).send().map(|r| r.y)
    }

    /// Apply a delta to a hosted matrix, returning the server's report.
    pub fn update(&mut self, matrix: &str, delta: &MatrixDelta) -> Result<UpdateReport> {
        self.conn.update(matrix, delta)
    }

    /// Upgrade to the full pipelining-capable connection API.
    pub fn into_connection(self) -> Connection {
        self.conn
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::partition::PartitionConfig;

    fn code_of(resp: &Json) -> &str {
        resp.get("code").and_then(Json::as_str).unwrap_or("<no code>")
    }

    fn coordinator() -> Coordinator {
        coordinator_shards(1)
    }

    fn coordinator_shards(n: usize) -> Coordinator {
        let mut router = Router::new(PartitionConfig::test_small(), 2);
        router.register("t", random::power_law_rows(40, 30, 2.0, 10, 3)).unwrap();
        Coordinator::with_shards(router, BatcherConfig::default(), n)
    }

    #[test]
    fn json_api_spmv_and_list() {
        let c = coordinator();
        let list = c.handle_json(r#"{"op":"list"}"#);
        assert_eq!(list.get("ok"), Some(&Json::Bool(true)));

        let x: Vec<f64> = (0..30).map(|i| i as f64 / 30.0).collect();
        let req = obj(&[
            ("op", Json::Str("spmv".into())),
            ("matrix", Json::Str("t".into())),
            ("x", num_arr(&x)),
        ]);
        let resp = c.handle_json(&req.to_string());
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("y").unwrap().as_arr().unwrap().len(), 40);
        // the default engine is explicit hbp, so it resolves to itself
        assert_eq!(resp.get("resolved").and_then(Json::as_str), Some("hbp"));

        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert!(stats.get("stats").unwrap().req_usize("requests").unwrap() >= 1);
    }

    #[test]
    fn json_api_update_round_trip() {
        let c = coordinator();
        let x: Vec<f64> = (0..30).map(|i| (i as f64 + 1.0) / 30.0).collect();
        let before = c.spmv("t", EngineKind::Hbp, x.clone()).unwrap();

        let resp = c.handle_json(
            r#"{"op":"update","matrix":"t","ops":[{"kind":"scale_row","row":0,"factor":2}]}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("full_rebuild"), Some(&Json::Bool(false)));
        assert!(resp.req_usize("blocks_total").unwrap() >= 1);

        let after = c.spmv("t", EngineKind::Hbp, x).unwrap();
        assert_eq!(after[0], 2.0 * before[0]);
        assert_eq!(&after[1..], &before[1..]);

        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert_eq!(stats.get("stats").unwrap().req_usize("updates").unwrap(), 1);
    }

    #[test]
    fn json_api_update_errors() {
        let c = coordinator();
        // missing ops array
        let r = c.handle_json(r#"{"op":"update","matrix":"t"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // unknown kind
        let r = c.handle_json(r#"{"op":"update","matrix":"t","ops":[{"kind":"nope","row":0}]}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // out-of-range row surfaces the router error
        let r = c.handle_json(
            r#"{"op":"update","matrix":"t","ops":[{"kind":"zero_row","row":4000}]}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // fractional / negative indices are rejected, not truncated onto
        // some other row
        for bad in [
            r#"{"op":"update","matrix":"t","ops":[{"kind":"zero_row","row":3.9}]}"#,
            r#"{"op":"update","matrix":"t","ops":[{"kind":"zero_row","row":-1}]}"#,
        ] {
            let r = c.handle_json(bad);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{bad}");
        }
        let frac_col = r#"{"ops":[{"kind":"replace_row","row":0,"cols":[1.5],"values":[2]}]}"#;
        assert!(delta_from_json(&Json::parse(frac_col).unwrap()).is_err());
        // still serving
        let x: Vec<f64> = vec![0.1; 30];
        assert!(c.spmv("t", EngineKind::Hbp, x).is_ok());
    }

    #[test]
    fn delta_json_round_trips() {
        let delta = MatrixDelta::new()
            .set(1, 2, 3.5)
            .scale_row(4, 0.5)
            .zero_row(7)
            .replace_row(2, vec![0, 5, 9], vec![1.0, -2.0, 3.0]);
        let req = obj(&[
            ("op", Json::Str("update".into())),
            ("matrix", Json::Str("t".into())),
            ("ops", delta_to_json(&delta)),
        ]);
        let parsed = delta_from_json(&Json::parse(&req.to_string()).unwrap()).unwrap();
        assert_eq!(parsed, delta);
    }

    #[test]
    fn json_api_tune_and_auto_engine() {
        let c = coordinator();
        let resp = c.handle_json(r#"{"op":"tune","matrix":"t"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("cache_hit"), Some(&Json::Bool(false)));
        let decision = resp.get("decision").expect("decision object");
        let engine = decision.req_str("engine").unwrap();
        assert!(
            ["hbp", "csr", "2d", "flat", "line-enhance"].contains(&engine),
            "decision is concrete: {engine}"
        );
        assert!(resp.get("features").unwrap().get("row_cv").is_some());
        assert!(
            resp.get("trials").unwrap().get("winner").is_some(),
            "register-time trials must be reported"
        );
        // registration-time tunes are visible in stats
        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert_eq!(stats.get("stats").unwrap().req_usize("tunes").unwrap(), 1);

        // "auto" routes to the decision and matches forcing that kind;
        // the reply names the concrete engine it resolved to
        let x: Vec<f64> = (0..30).map(|i| (i as f64) / 29.0).collect();
        let auto = c.spmv_resolved("t", EngineKind::Auto, x.clone()).unwrap();
        assert_eq!(auto.resolved.to_string(), engine, "reply reports the tuned decision");
        let forced = c.spmv("t", engine.parse().unwrap(), x).unwrap();
        assert_eq!(auto.y, forced, "auto and forced winner must be bit-identical");

        let unknown = c.handle_json(r#"{"op":"tune","matrix":"ghost"}"#);
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn json_api_errors() {
        let c = coordinator();
        let bad = c.handle_json("not json");
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&bad), "bad_request");
        let unknown = c.handle_json(r#"{"op":"nope"}"#);
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&unknown), "bad_request");
        let missing = c.handle_json(r#"{"op":"spmv","matrix":"zzz","x":[1]}"#);
        assert_eq!(missing.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&missing), "unknown_matrix");
        let ghost_tune = c.handle_json(r#"{"op":"tune","matrix":"ghost"}"#);
        assert_eq!(code_of(&ghost_tune), "unknown_matrix");
    }

    #[test]
    fn json_api_deadline_field() {
        let c = coordinator();
        let x_json: String =
            format!("[{}]", (0..30).map(|_| "0.1").collect::<Vec<_>>().join(","));

        // a zero deadline is already expired at admission
        let r = c.handle_json(&format!(
            r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":0}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
        assert_eq!(code_of(&r), "deadline_exceeded");

        // malformed deadlines are rejected before admission
        for bad in [
            format!(r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":-5}}"#),
            format!(r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":1.5}}"#),
        ] {
            let r = c.handle_json(&bad);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{bad}");
            assert_eq!(code_of(&r), "bad_request");
        }

        // a generous deadline serves normally
        let r = c.handle_json(&format!(
            r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":60000}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    #[test]
    fn hello_reports_protocol_and_features() {
        let c = coordinator_shards(3);
        let r = c.handle_json(r#"{"op":"hello"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("proto").and_then(Json::as_f64), Some(1.0));
        let features = r.get("features").unwrap().as_arr().unwrap();
        assert_eq!(
            features[0].as_str(),
            Some("pipelining"),
            "pipelining must stay the first advertised feature"
        );
        assert!(features.iter().any(|f| f.as_str() == Some("deadline_ms")));
        assert!(features.iter().any(|f| f.as_str() == Some("auto_engine")));
        assert!(features.iter().any(|f| f.as_str() == Some("csr_native_engines")));
        assert_eq!(r.get("shards").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn request_ids_echo_verbatim() {
        let c = coordinator();
        let x_json = format!("[{}]", vec!["0.1"; 30].join(","));

        // string id on a success
        let r = c.handle_json(&format!(
            r#"{{"op":"spmv","matrix":"t","x":{x_json},"id":"req-1"}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("id").and_then(Json::as_str), Some("req-1"));

        // the id is opaque: non-string values echo untouched
        let r = c.handle_json(r#"{"op":"list","id":17}"#);
        assert_eq!(r.get("id").and_then(Json::as_f64), Some(17.0));
        let r = c.handle_json(r#"{"op":"list","id":null}"#);
        assert_eq!(r.get("id"), Some(&Json::Null));

        // error replies echo the id too — that's what makes pipelined
        // failures attributable
        let r = c.handle_json(&format!(
            r#"{{"op":"spmv","matrix":"ghost","x":{x_json},"id":"e1"}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&r), "unknown_matrix");
        assert_eq!(r.get("id").and_then(Json::as_str), Some("e1"));

        // replies to un-id'd requests carry no id
        let r = c.handle_json(r#"{"op":"list"}"#);
        assert!(r.get("id").is_none());
    }

    #[test]
    fn stats_reports_shard_breakdown_summing_to_totals() {
        let c = coordinator_shards(4);
        let x_json = format!("[{}]", vec!["0.1"; 30].join(","));
        // an uneven spread: shard i serves i+1 requests
        for shard in 0..4 {
            for _ in 0..=shard {
                let r = c.handle_json_on(
                    shard,
                    &format!(r#"{{"op":"spmv","matrix":"t","x":{x_json}}}"#),
                );
                assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
            }
        }
        let stats = c.handle_json(r#"{"op":"stats"}"#);
        let stats = stats.get("stats").unwrap();
        let shards = stats.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 4);
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.req_usize("shard").unwrap(), i);
            assert_eq!(s.req_usize("requests").unwrap(), i + 1, "shard {i} request count");
        }
        // the breakdown sums to the global totals, counter by counter
        for key in ["requests", "errors", "shed", "deadline_drops", "panics_recovered"] {
            let sum: usize = shards.iter().map(|s| s.req_usize(key).unwrap()).sum();
            assert_eq!(sum, stats.req_usize(key).unwrap(), "shards must sum to global {key}");
        }
        // shard indices wrap instead of panicking
        let r = c.handle_json_on(
            11,
            &format!(r#"{{"op":"spmv","matrix":"t","x":{x_json}}}"#),
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }

    #[test]
    fn shard_parity_same_stream_same_results_and_totals() {
        // the same request stream through 1 shard and through 4 shards
        // must yield identical per-request replies and identical
        // rolled-up totals — sharding is a throughput choice, not a
        // semantics choice
        let c1 = coordinator();
        let c4 = coordinator_shards(4);
        let x_json = |seed: usize| {
            format!(
                "[{}]",
                (0..30).map(|i| format!("{}", (seed * 31 + i) as f64 / 97.0)).collect::<Vec<_>>().join(",")
            )
        };
        let mut stream = Vec::new();
        for i in 0..6 {
            stream.push(format!(r#"{{"op":"spmv","matrix":"t","x":{},"id":"s{i}"}}"#, x_json(i)));
        }
        stream.push(
            r#"{"op":"update","matrix":"t","ops":[{"kind":"scale_row","row":1,"factor":3}]}"#
                .to_string(),
        );
        for i in 6..9 {
            stream.push(format!(r#"{{"op":"spmv","matrix":"t","x":{}}}"#, x_json(i)));
        }
        stream.push(r#"{"op":"spmv","matrix":"ghost","x":[1]}"#.to_string());

        for (k, line) in stream.iter().enumerate() {
            let r1 = c1.handle_json(line);
            let r4 = c4.handle_json(line);
            assert_eq!(r1, r4, "request {k} diverged between 1 and 4 shards");
        }
        let s1 = c1.metrics.snapshot();
        let s4 = c4.metrics.snapshot();
        assert_eq!(s1.requests, s4.requests);
        assert_eq!(s1.updates, s4.updates);
        assert_eq!(s1.errors, s4.errors);
        assert_eq!(s1.shed, s4.shed);
        // and the 4-shard breakdown accounts for every request
        let per_shard: u64 = c4.shard_snapshots().iter().map(|s| s.requests).sum();
        assert_eq!(per_shard, s4.requests);
    }

    #[test]
    fn trace_op_returns_spans_with_echoed_ids() {
        let c = coordinator_shards(2);
        let x_json = format!("[{}]", vec!["0.1"; 30].join(","));
        // a fresh coordinator has no spans
        let r = c.handle_json(r#"{"op":"trace"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("spans").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(r.req_usize("dropped").unwrap(), 0);

        // requests on both shards, one id-tagged
        for shard in 0..2 {
            let r = c.handle_json_on(
                shard,
                &format!(r#"{{"op":"spmv","matrix":"t","x":{x_json},"id":"r{shard}"}}"#),
            );
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        }
        let r = c.handle_json(r#"{"op":"trace","limit":8}"#);
        let spans = r.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2, "one span per answered request");
        // merged across shards in global seq order
        let seqs: Vec<usize> = spans.iter().map(|s| s.req_usize("seq").unwrap()).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]));
        let shards_seen: HashSet<usize> =
            spans.iter().map(|s| s.req_usize("shard").unwrap()).collect();
        assert_eq!(shards_seen.len(), 2, "both shards' rings are drained");
        for s in spans {
            assert_eq!(s.get("ok"), Some(&Json::Bool(true)));
            assert!(s.get("id").unwrap().as_str().unwrap().starts_with('r'));
            assert_eq!(s.req_str("matrix").unwrap(), "t");
            assert_ne!(s.req_str("engine").unwrap(), "auto");
            // the span invariant holds on the wire
            let qw = s.get("queue_wait_secs").unwrap().as_f64().unwrap();
            let ex = s.get("execute_secs").unwrap().as_f64().unwrap();
            let rp = s.get("reply_secs").unwrap().as_f64().unwrap();
            let total = s.get("total_secs").unwrap().as_f64().unwrap();
            assert!((qw + ex + rp - total).abs() <= 1e-9 * total.max(1.0));
        }
        // limit truncates to the globally newest spans
        let r = c.handle_json(r#"{"op":"trace","limit":1}"#);
        let spans = r.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].req_usize("seq").unwrap(), *seqs.last().unwrap());
        // a bad limit is a typed error
        let r = c.handle_json(r#"{"op":"trace","limit":"many"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn metrics_op_returns_prometheus_text() {
        let c = coordinator();
        let x_json = format!("[{}]", vec!["0.1"; 30].join(","));
        let r = c.handle_json(&format!(r#"{{"op":"spmv","matrix":"t","x":{x_json}}}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let r = c.handle_json(r#"{"op":"metrics"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let text = r.req_str("prom").unwrap();
        assert!(text.contains("# TYPE hbp_requests_total counter"));
        assert!(text.contains("\nhbp_requests_total 1\n"));
        assert!(text.contains("hbp_shard_requests_total{shard=\"0\"} 1\n"));
        assert!(text.contains("hbp_request_latency_seconds_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("hbp_tunes_total 1\n"), "registration tune is visible");
    }

    #[test]
    fn inline_spmv_without_id_traces_with_null_id() {
        let c = coordinator();
        let x_json = format!("[{}]", vec!["0.1"; 30].join(","));
        let r = c.handle_json(&format!(r#"{{"op":"spmv","matrix":"t","x":{x_json}}}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let r = c.handle_json(r#"{"op":"trace"}"#);
        let spans = r.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("id"), Some(&Json::Null));
    }
}




//! The serving front: in-process [`Coordinator`] API + line-delimited
//! JSON over TCP.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"spmv", "matrix":"m1", "x":[...], "engine":"hbp", "deadline_ms":250}
//! <- {"ok":true, "y":[...], "resolved":"hbp"}
//! -> {"op":"update", "matrix":"m1", "ops":[{"kind":"scale_row","row":3,"factor":0.5}, ...]}
//! <- {"ok":true, "rows_touched":1, "blocks_touched":2, "blocks_total":40, "full_rebuild":false}
//! -> {"op":"list"}
//! <- {"ok":true, "matrices":[{"name":"m1","rows":...,"cols":...,"nnz":...}]}
//! -> {"op":"stats"}
//! <- {"ok":true, "stats":{...}}
//! -> {"op":"tune", "matrix":"m1"}
//! <- {"ok":true, "cache_hit":false, "decision":{"engine":"hbp",...},
//!     "features":{...}, "trials":{...}}
//! ```
//!
//! Failure replies are typed: `{"ok":false, "code":..., "error":...}`
//! with `code` drawn from the stable taxonomy in [`super::error`]
//! (`bad_request`, `unknown_matrix`, `overloaded`, `deadline_exceeded`,
//! `internal`); `overloaded` sheds also carry `retry_after_ms`.
//!
//! The normative spec — every op, every field, with examples executed
//! verbatim by `rust/tests/protocol_doc.rs` — lives in
//! `docs/PROTOCOL.md`.
//!
//! `spmv` accepts `"engine":"auto"` (resolved to the matrix's tuned
//! decision); the default stays `"hbp"`. Every successful `spmv`
//! response carries `"resolved"`: the concrete engine the request
//! executed on, so a client can observe what its `auto` request merged
//! with in the batcher. An optional `deadline_ms` bounds how long the
//! request may wait in the batcher's queue before it is dropped with
//! `deadline_exceeded` instead of executed.
//!
//! The TCP front degrades instead of dying ([`ServerConfig`]): accept
//! errors are counted and survived, a connection cap sheds with one
//! `overloaded` line, over-long request lines get `bad_request` and a
//! disconnect, stalled clients are timed out, and request handling is
//! panic-isolated per request. [`ServerHandle::shutdown`] stops the
//! accept loop and drains in-flight connections.
//!
//! Update op kinds mirror [`DeltaOp`]:
//! `{"kind":"set","row":R,"col":C,"value":V}`,
//! `{"kind":"scale_row","row":R,"factor":F}`,
//! `{"kind":"zero_row","row":R}`, and
//! `{"kind":"replace_row","row":R,"cols":[...],"values":[...]}`.

use super::batcher::{Batcher, BatcherConfig, BatcherHandle, SpmvReply};
use super::error::{error_reply, panic_message, reply_error, ServiceError};
use super::metrics::ServiceMetrics;
use super::router::{EngineKind, Router};
use crate::preprocess::{DeltaOp, MatrixDelta, UpdateReport};
use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The in-process coordinator: router + batcher + metrics.
pub struct Coordinator {
    /// The matrix registry requests route through.
    pub router: Arc<Router>,
    /// Service counters (requests, updates, tunes, batch groups).
    pub metrics: Arc<ServiceMetrics>,
    // field order matters: `handle` must drop BEFORE `batcher` (fields
    // drop in declaration order) or Batcher::drop joins a dispatcher
    // that still sees a live sender and never exits.
    handle: BatcherHandle,
    batcher: Batcher,
}

impl Coordinator {
    /// Wrap a registered router in the batching pipeline, recording
    /// each registration's tune outcome in fresh metrics.
    pub fn new(router: Router, cfg: BatcherConfig) -> Coordinator {
        let router = Arc::new(router);
        let metrics = Arc::new(ServiceMetrics::new());
        // registration happens before the router is shared, so every
        // tune outcome the registry holds is recorded here exactly once
        for name in router.names() {
            metrics.record_tune(&router.get(name).expect("registered matrix").tune);
        }
        let batcher = Batcher::start(router.clone(), metrics.clone(), cfg);
        let handle = batcher.handle();
        Coordinator { router, metrics, handle, batcher }
    }

    /// Synchronous SpMV through the batching pipeline.
    pub fn spmv(&self, matrix: &str, engine: EngineKind, x: Vec<f64>) -> Result<Vec<f64>> {
        self.handle.spmv(matrix, engine, x)
    }

    /// Synchronous SpMV that also reports the concrete engine the
    /// request resolved to (what the protocol's `resolved` field
    /// carries).
    pub fn spmv_resolved(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
    ) -> Result<SpmvReply> {
        self.handle.spmv_resolved(matrix, engine, x)
    }

    /// [`Coordinator::spmv_resolved`] with an optional queueing deadline
    /// (milliseconds from now); a request still queued when its deadline
    /// passes is dropped with `deadline_exceeded` instead of executed.
    pub fn spmv_deadline(
        &self,
        matrix: &str,
        engine: EngineKind,
        x: Vec<f64>,
        deadline_ms: Option<u64>,
    ) -> Result<SpmvReply> {
        self.handle.spmv_deadline(matrix, engine, x, deadline_ms)
    }

    /// Synchronous matrix update through the batching pipeline (ordered
    /// with SpMV submissions on the same queue).
    pub fn update(&self, matrix: &str, delta: MatrixDelta) -> Result<UpdateReport> {
        self.handle.update(matrix, delta)
    }

    /// A submission handle onto this coordinator's batcher.
    pub fn handle(&self) -> BatcherHandle {
        self.batcher.handle()
    }

    /// Process one protocol request (shared by TCP and tests). Never
    /// panics: failures become `{"ok":false,"code":...,"error":...}`
    /// replies, and a panic escaping the handler (the batcher already
    /// isolates engine panics; this catches everything else) is
    /// recovered into an `internal` reply so one poisoned request
    /// cannot take its connection thread down.
    pub fn handle_json(&self, line: &str) -> Json {
        match catch_unwind(AssertUnwindSafe(|| self.try_handle(line))) {
            Ok(Ok(v)) => v,
            Ok(Err(e)) => error_reply(&e),
            Err(p) => {
                self.metrics.record_panic_recovered();
                self.metrics.record_error();
                error_reply(&anyhow::Error::new(ServiceError::internal(format!(
                    "request handling panicked (recovered): {}",
                    panic_message(p)
                ))))
            }
        }
    }

    fn try_handle(&self, line: &str) -> Result<Json> {
        let req = Json::parse(line).context("parsing request JSON")?;
        match req.req_str("op")? {
            "spmv" => {
                let matrix = req.req_str("matrix")?;
                let engine: EngineKind =
                    req.get("engine").and_then(Json::as_str).unwrap_or("hbp").parse()?;
                let x: Vec<f64> = req
                    .get("x")
                    .and_then(Json::as_arr)
                    .context("missing array field \"x\"")?
                    .iter()
                    .map(|v| v.as_f64().context("non-numeric x entry"))
                    .collect::<Result<_>>()?;
                let deadline_ms = match req.get("deadline_ms") {
                    None => None,
                    Some(v) => {
                        let n = v.as_f64().context("non-numeric \"deadline_ms\"")?;
                        anyhow::ensure!(
                            n.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(&n),
                            "deadline_ms must be a non-negative integer, got {n}"
                        );
                        Some(n as u64)
                    }
                };
                let reply = self.spmv_deadline(matrix, engine, x, deadline_ms)?;
                Ok(obj(&[
                    ("ok", Json::Bool(true)),
                    ("y", crate::util::json::num_arr(&reply.y)),
                    ("resolved", Json::Str(reply.resolved.to_string())),
                ]))
            }
            "update" => {
                let matrix = req.req_str("matrix")?;
                let delta = delta_from_json(&req)?;
                let report = self.update(matrix, delta)?;
                Ok(report_json(&report))
            }
            "list" => {
                let matrices: Vec<Json> = self
                    .router
                    .names()
                    .into_iter()
                    .filter_map(|n| {
                        let m = self.router.get(n).ok()?;
                        Some(obj(&[
                            ("name", Json::Str(n.to_string())),
                            ("rows", Json::Num(m.rows as f64)),
                            ("cols", Json::Num(m.cols as f64)),
                            ("nnz", Json::Num(m.nnz as f64)),
                            ("preprocess_secs", Json::Num(m.preprocess_secs)),
                        ]))
                    })
                    .collect();
                Ok(obj(&[("ok", Json::Bool(true)), ("matrices", Json::Arr(matrices))]))
            }
            "stats" => Ok(obj(&[
                ("ok", Json::Bool(true)),
                ("stats", self.metrics.snapshot().to_json()),
            ])),
            "tune" => {
                let matrix = req.req_str("matrix")?;
                let m = self.router.get(matrix)?;
                Ok(tune_json(&m.tune))
            }
            other => anyhow::bail!("unknown op {other:?}"),
        }
    }
}

/// Strict index parse for update ops: `Json::as_usize` is a saturating
/// float cast (`-1` → 0, `3.9` → 3), which on a *write* endpoint would
/// silently mutate the wrong row — reject anything non-integral,
/// negative, or out of exact-f64 range instead.
fn req_index(op: &Json, key: &str, ctx: &str) -> Result<usize> {
    let n = op
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("{ctx}: missing numeric {key:?}"))?;
    anyhow::ensure!(
        n.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(&n),
        "{ctx}: {key} must be a non-negative integer, got {n}"
    );
    Ok(n as usize)
}

/// Parse the `ops` array of an `update` request into a [`MatrixDelta`].
fn delta_from_json(req: &Json) -> Result<MatrixDelta> {
    let ops = req
        .get("ops")
        .and_then(Json::as_arr)
        .context("missing array field \"ops\"")?;
    let mut delta = MatrixDelta::new();
    for (i, op) in ops.iter().enumerate() {
        let ctx = format!("ops[{i}]");
        let kind = op.req_str("kind").with_context(|| ctx.clone())?;
        let row = req_index(op, "row", &ctx)?;
        match kind {
            "set" => {
                let col = req_index(op, "col", &ctx)?;
                let value = op
                    .get("value")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("ops[{i}]: missing numeric \"value\""))?;
                delta = delta.set(row, col, value);
            }
            "scale_row" => {
                let factor = op
                    .get("factor")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("ops[{i}]: missing numeric \"factor\""))?;
                delta = delta.scale_row(row, factor);
            }
            "zero_row" => delta = delta.zero_row(row),
            "replace_row" => {
                let cols: Vec<u32> = op
                    .get("cols")
                    .and_then(Json::as_arr)
                    .with_context(|| format!("ops[{i}]: missing array \"cols\""))?
                    .iter()
                    .map(|v| {
                        let n = v.as_f64().with_context(|| format!("ops[{i}]: non-numeric col"))?;
                        anyhow::ensure!(
                            n.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&n),
                            "ops[{i}]: col must be a non-negative integer, got {n}"
                        );
                        Ok(n as u32)
                    })
                    .collect::<Result<_>>()?;
                let values: Vec<f64> = op
                    .get("values")
                    .and_then(Json::as_arr)
                    .with_context(|| format!("ops[{i}]: missing array \"values\""))?
                    .iter()
                    .map(|v| v.as_f64().with_context(|| format!("ops[{i}]: non-numeric value")))
                    .collect::<Result<_>>()?;
                delta = delta.replace_row(row, cols, values);
            }
            other => bail!("ops[{i}]: unknown kind {other:?}"),
        }
    }
    Ok(delta)
}

/// Serialize a delta into the protocol's `ops` array (client side).
fn delta_to_json(delta: &MatrixDelta) -> Json {
    let ops: Vec<Json> = delta
        .ops
        .iter()
        .map(|op| match op {
            DeltaOp::Set { row, col, value } => obj(&[
                ("kind", Json::Str("set".into())),
                ("row", Json::Num(*row as f64)),
                ("col", Json::Num(*col as f64)),
                ("value", Json::Num(*value)),
            ]),
            DeltaOp::ScaleRow { row, factor } => obj(&[
                ("kind", Json::Str("scale_row".into())),
                ("row", Json::Num(*row as f64)),
                ("factor", Json::Num(*factor)),
            ]),
            DeltaOp::ZeroRow { row } => obj(&[
                ("kind", Json::Str("zero_row".into())),
                ("row", Json::Num(*row as f64)),
            ]),
            DeltaOp::ReplaceRow { row, cols, values } => obj(&[
                ("kind", Json::Str("replace_row".into())),
                ("row", Json::Num(*row as f64)),
                (
                    "cols",
                    Json::Arr(cols.iter().map(|&c| Json::Num(c as f64)).collect()),
                ),
                ("values", crate::util::json::num_arr(values)),
            ]),
        })
        .collect();
    Json::Arr(ops)
}

/// Serialize a registration's tuning record for the `tune` op.
fn tune_json(t: &crate::tune::TuneOutcome) -> Json {
    obj(&[
        ("ok", Json::Bool(true)),
        ("key", Json::Str(format!("{:016x}", t.key))),
        ("cache_hit", Json::Bool(t.cache_hit)),
        (
            "decision",
            obj(&[
                ("engine", Json::Str(t.decision.kind.to_string())),
                ("rows_per_block", Json::Num(t.decision.cfg.rows_per_block as f64)),
                ("cols_per_block", Json::Num(t.decision.cfg.cols_per_block as f64)),
                ("warp", Json::Num(t.decision.cfg.warp as f64)),
                ("trial_secs", Json::Num(t.decision.trial_secs)),
            ]),
        ),
        ("features", t.features.to_json()),
        (
            "trials",
            match &t.report {
                Some(report) => report.to_json(),
                None => Json::Null,
            },
        ),
        ("tune_secs", Json::Num(t.tune_secs)),
    ])
}

fn report_json(report: &UpdateReport) -> Json {
    obj(&[
        ("ok", Json::Bool(true)),
        ("rows_touched", Json::Num(report.rows_touched as f64)),
        ("blocks_touched", Json::Num(report.blocks_touched as f64)),
        ("blocks_total", Json::Num(report.blocks_total as f64)),
        ("full_rebuild", Json::Bool(report.full_rebuild)),
    ])
}

/// Tunables for the TCP front's self-protection. Everything here exists
/// so a misbehaving *client* degrades its own service, not the server:
/// the connection cap bounds thread count, the read timeout unsticks
/// threads pinned by stalled clients, and the line cap bounds per-request
/// memory.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Maximum simultaneous connections; accepts beyond this get one
    /// `overloaded` reply line (with `retry_after_ms`) and are closed.
    pub max_conns: usize,
    /// Per-connection read timeout: a client silent this long
    /// mid-request is disconnected. `None` waits forever.
    pub read_timeout: Option<Duration>,
    /// Longest accepted request line in bytes. A longer line gets a
    /// `bad_request` reply and a disconnect — the remainder of the line
    /// was never read, so the stream cannot be resynchronized.
    pub max_line_bytes: usize,
    /// How long [`ServerHandle::shutdown`] waits for in-flight
    /// connections to finish before returning anyway.
    pub shutdown_grace: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 256,
            read_timeout: Some(Duration::from_secs(60)),
            max_line_bytes: 8 * 1024 * 1024,
            shutdown_grace: Duration::from_secs(2),
        }
    }
}

/// Back-off hint on connection-limit sheds (the batcher's queue sheds
/// carry the configurable `BatcherConfig::retry_after_ms` instead).
const CONN_RETRY_AFTER_MS: u64 = 50;

/// A running TCP server: its bound address plus shutdown control.
/// Dropping the handle also shuts the server down.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The address the listener actually bound (resolves `:0` ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, then give in-flight
    /// connections up to `shutdown_grace` to finish.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block until the accept loop exits (i.e. until something else
    /// triggers shutdown) — what the foreground `serve` does.
    pub fn wait(mut self) {
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the blocking accept() so the loop observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve the coordinator over TCP in a background accept thread,
/// returning the [`ServerHandle`] that controls it.
pub fn serve_with(
    coordinator: Arc<Coordinator>,
    addr: &str,
    cfg: ServerConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let accept_thread = {
        let shutdown = shutdown.clone();
        std::thread::Builder::new()
            .name("hbp-accept".into())
            .spawn(move || accept_loop(coordinator, listener, cfg, shutdown))
            .context("spawning accept thread")?
    };
    Ok(ServerHandle { addr, shutdown, accept_thread: Some(accept_thread) })
}

/// Serve the coordinator over TCP in the foreground (what `hbp serve`
/// runs). Returns only after shutdown is triggered elsewhere — in
/// practice, when the process exits.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str, cfg: ServerConfig) -> Result<()> {
    let handle = serve_with(coordinator, addr, cfg)?;
    eprintln!("hbp-spmv serving on {}", handle.addr());
    handle.wait();
    Ok(())
}

/// Serve on an ephemeral port, returning the bound address (tests/e2e).
/// The server runs until process exit; use [`serve_with`] (or
/// [`serve_background_with`]) when the caller needs shutdown control.
pub fn serve_background(coordinator: Arc<Coordinator>) -> Result<SocketAddr> {
    let handle = serve_background_with(coordinator, ServerConfig::default())?;
    let addr = handle.addr();
    // intentionally leak the handle: its Drop would stop the server
    std::mem::forget(handle);
    Ok(addr)
}

/// [`serve_background`] with explicit config and shutdown control.
pub fn serve_background_with(
    coordinator: Arc<Coordinator>,
    cfg: ServerConfig,
) -> Result<ServerHandle> {
    serve_with(coordinator, "127.0.0.1:0", cfg)
}

fn accept_loop(
    c: Arc<Coordinator>,
    listener: TcpListener,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) {
    let conns = Arc::new(AtomicUsize::new(0));
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                // a transient accept failure (ECONNABORTED, EMFILE, ...)
                // must not kill the server: count it, log it, go on
                c.metrics.record_accept_error();
                eprintln!("hbp-spmv: accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            break; // usually the shutdown poke connection itself
        }
        if conns.load(Ordering::SeqCst) >= cfg.max_conns {
            c.metrics.record_shed();
            refuse_conn(stream, cfg.max_conns);
            continue;
        }
        conns.fetch_add(1, Ordering::SeqCst);
        let conn_c = c.clone();
        let conn_counter = conns.clone();
        let conn_shutdown = shutdown.clone();
        let spawned = std::thread::Builder::new().name("hbp-conn".into()).spawn(move || {
            let _ = handle_conn(conn_c, stream, cfg, conn_shutdown);
            conn_counter.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            conns.fetch_sub(1, Ordering::SeqCst);
            c.metrics.record_accept_error();
        }
    }
    // drain: bounded wait for in-flight connections, then a final
    // metrics snapshot so a shutdown always leaves a service record
    let t = std::time::Instant::now();
    while conns.load(Ordering::SeqCst) > 0 && t.elapsed() < cfg.shutdown_grace {
        std::thread::sleep(Duration::from_millis(5));
    }
    let s = c.metrics.snapshot();
    eprintln!(
        "hbp-spmv: shutdown — {} requests, {} errors, {} shed, {} deadline drops, \
         {} panics recovered, {} accept errors",
        s.requests, s.errors, s.shed, s.deadline_drops, s.panics_recovered, s.accept_errors
    );
}

/// Over the connection cap: one `overloaded` line, then close.
fn refuse_conn(stream: TcpStream, max_conns: usize) {
    let e = anyhow::Error::new(ServiceError::overloaded(
        format!("connection limit reached ({max_conns} open)"),
        CONN_RETRY_AFTER_MS,
    ));
    let mut writer = stream;
    let _ = writer.write_all(error_reply(&e).to_string().as_bytes());
    let _ = writer.write_all(b"\n");
}

enum ReadOutcome {
    Line,
    Eof,
    TooLong,
}

/// `read_line` with a byte cap: reads at most `cap + 1` bytes, so an
/// oversized line is detected without buffering it — seeing `cap + 1`
/// bytes before the newline means the line is over the cap.
fn read_capped_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    cap: usize,
) -> std::io::Result<ReadOutcome> {
    let mut limited = std::io::Read::take(&mut *reader, cap as u64 + 1);
    let n = limited.read_line(line)?;
    if n == 0 {
        Ok(ReadOutcome::Eof)
    } else if n > cap {
        Ok(ReadOutcome::TooLong)
    } else {
        Ok(ReadOutcome::Line)
    }
}

fn handle_conn(
    c: Arc<Coordinator>,
    stream: TcpStream,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_read_timeout(cfg.read_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        line.clear();
        match read_capped_line(&mut reader, &mut line, cfg.max_line_bytes) {
            Ok(ReadOutcome::Eof) => return Ok(()), // client closed
            Ok(ReadOutcome::Line) => {}
            Ok(ReadOutcome::TooLong) => {
                c.metrics.record_error();
                let e = anyhow::Error::new(ServiceError::bad_request(format!(
                    "request line exceeds {} bytes",
                    cfg.max_line_bytes
                )));
                let _ = writer.write_all(error_reply(&e).to_string().as_bytes());
                let _ = writer.write_all(b"\n");
                return Ok(()); // cannot resync past the unread remainder
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(()); // stalled client: reclaim the thread
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = c.handle_json(line.trim());
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

/// A tiny blocking client for the protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a serving coordinator.
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request object and read one response line.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim())
    }

    /// SpMV against a hosted matrix (default engine; the response's
    /// `resolved` field is available through [`Client::call`]).
    pub fn spmv(&mut self, matrix: &str, x: &[f64]) -> Result<Vec<f64>> {
        let req = obj(&[
            ("op", Json::Str("spmv".into())),
            ("matrix", Json::Str(matrix.into())),
            ("x", crate::util::json::num_arr(x)),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            // typed: the returned error downcasts to ServiceError when
            // the reply carried a valid code
            return Err(reply_error(&resp));
        }
        resp.get("y")
            .and_then(Json::as_arr)
            .context("missing y")?
            .iter()
            .map(|v| v.as_f64().context("bad y entry"))
            .collect()
    }

    /// Apply a delta to a hosted matrix, returning the server's report.
    pub fn update(&mut self, matrix: &str, delta: &MatrixDelta) -> Result<UpdateReport> {
        let req = obj(&[
            ("op", Json::Str("update".into())),
            ("matrix", Json::Str(matrix.into())),
            ("ops", delta_to_json(delta)),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(reply_error(&resp));
        }
        Ok(UpdateReport {
            rows_touched: resp.req_usize("rows_touched")?,
            blocks_touched: resp.req_usize("blocks_touched")?,
            blocks_total: resp.req_usize("blocks_total")?,
            full_rebuild: resp.get("full_rebuild") == Some(&Json::Bool(true)),
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::partition::PartitionConfig;

    fn code_of(resp: &Json) -> &str {
        resp.get("code").and_then(Json::as_str).unwrap_or("<no code>")
    }

    fn coordinator() -> Coordinator {
        let mut router = Router::new(PartitionConfig::test_small(), 2);
        router.register("t", random::power_law_rows(40, 30, 2.0, 10, 3)).unwrap();
        Coordinator::new(router, BatcherConfig::default())
    }

    #[test]
    fn json_api_spmv_and_list() {
        let c = coordinator();
        let list = c.handle_json(r#"{"op":"list"}"#);
        assert_eq!(list.get("ok"), Some(&Json::Bool(true)));

        let x: Vec<f64> = (0..30).map(|i| i as f64 / 30.0).collect();
        let req = obj(&[
            ("op", Json::Str("spmv".into())),
            ("matrix", Json::Str("t".into())),
            ("x", crate::util::json::num_arr(&x)),
        ]);
        let resp = c.handle_json(&req.to_string());
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("y").unwrap().as_arr().unwrap().len(), 40);
        // the default engine is explicit hbp, so it resolves to itself
        assert_eq!(resp.get("resolved").and_then(Json::as_str), Some("hbp"));

        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert!(stats.get("stats").unwrap().req_usize("requests").unwrap() >= 1);
    }

    #[test]
    fn json_api_update_round_trip() {
        let c = coordinator();
        let x: Vec<f64> = (0..30).map(|i| (i as f64 + 1.0) / 30.0).collect();
        let before = c.spmv("t", EngineKind::Hbp, x.clone()).unwrap();

        let resp = c.handle_json(
            r#"{"op":"update","matrix":"t","ops":[{"kind":"scale_row","row":0,"factor":2}]}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("full_rebuild"), Some(&Json::Bool(false)));
        assert!(resp.req_usize("blocks_total").unwrap() >= 1);

        let after = c.spmv("t", EngineKind::Hbp, x).unwrap();
        assert_eq!(after[0], 2.0 * before[0]);
        assert_eq!(&after[1..], &before[1..]);

        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert_eq!(stats.get("stats").unwrap().req_usize("updates").unwrap(), 1);
    }

    #[test]
    fn json_api_update_errors() {
        let c = coordinator();
        // missing ops array
        let r = c.handle_json(r#"{"op":"update","matrix":"t"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // unknown kind
        let r = c.handle_json(r#"{"op":"update","matrix":"t","ops":[{"kind":"nope","row":0}]}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // out-of-range row surfaces the router error
        let r = c.handle_json(
            r#"{"op":"update","matrix":"t","ops":[{"kind":"zero_row","row":4000}]}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // fractional / negative indices are rejected, not truncated onto
        // some other row
        for bad in [
            r#"{"op":"update","matrix":"t","ops":[{"kind":"zero_row","row":3.9}]}"#,
            r#"{"op":"update","matrix":"t","ops":[{"kind":"zero_row","row":-1}]}"#,
        ] {
            let r = c.handle_json(bad);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{bad}");
        }
        let frac_col = r#"{"ops":[{"kind":"replace_row","row":0,"cols":[1.5],"values":[2]}]}"#;
        assert!(delta_from_json(&Json::parse(frac_col).unwrap()).is_err());
        // still serving
        let x: Vec<f64> = vec![0.1; 30];
        assert!(c.spmv("t", EngineKind::Hbp, x).is_ok());
    }

    #[test]
    fn delta_json_round_trips() {
        let delta = MatrixDelta::new()
            .set(1, 2, 3.5)
            .scale_row(4, 0.5)
            .zero_row(7)
            .replace_row(2, vec![0, 5, 9], vec![1.0, -2.0, 3.0]);
        let req = obj(&[
            ("op", Json::Str("update".into())),
            ("matrix", Json::Str("t".into())),
            ("ops", delta_to_json(&delta)),
        ]);
        let parsed = delta_from_json(&Json::parse(&req.to_string()).unwrap()).unwrap();
        assert_eq!(parsed, delta);
    }

    #[test]
    fn json_api_tune_and_auto_engine() {
        let c = coordinator();
        let resp = c.handle_json(r#"{"op":"tune","matrix":"t"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert_eq!(resp.get("cache_hit"), Some(&Json::Bool(false)));
        let decision = resp.get("decision").expect("decision object");
        let engine = decision.req_str("engine").unwrap();
        assert!(["hbp", "csr", "2d"].contains(&engine), "decision is concrete: {engine}");
        assert!(resp.get("features").unwrap().get("row_cv").is_some());
        assert!(
            resp.get("trials").unwrap().get("winner").is_some(),
            "register-time trials must be reported"
        );
        // registration-time tunes are visible in stats
        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert_eq!(stats.get("stats").unwrap().req_usize("tunes").unwrap(), 1);

        // "auto" routes to the decision and matches forcing that kind;
        // the reply names the concrete engine it resolved to
        let x: Vec<f64> = (0..30).map(|i| (i as f64) / 29.0).collect();
        let auto = c.spmv_resolved("t", EngineKind::Auto, x.clone()).unwrap();
        assert_eq!(auto.resolved.to_string(), engine, "reply reports the tuned decision");
        let forced = c.spmv("t", engine.parse().unwrap(), x).unwrap();
        assert_eq!(auto.y, forced, "auto and forced winner must be bit-identical");

        let unknown = c.handle_json(r#"{"op":"tune","matrix":"ghost"}"#);
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn json_api_errors() {
        let c = coordinator();
        let bad = c.handle_json("not json");
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&bad), "bad_request");
        let unknown = c.handle_json(r#"{"op":"nope"}"#);
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&unknown), "bad_request");
        let missing = c.handle_json(r#"{"op":"spmv","matrix":"zzz","x":[1]}"#);
        assert_eq!(missing.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(code_of(&missing), "unknown_matrix");
        let ghost_tune = c.handle_json(r#"{"op":"tune","matrix":"ghost"}"#);
        assert_eq!(code_of(&ghost_tune), "unknown_matrix");
    }

    #[test]
    fn json_api_deadline_field() {
        let c = coordinator();
        let x_json: String =
            format!("[{}]", (0..30).map(|_| "0.1").collect::<Vec<_>>().join(","));

        // a zero deadline is already expired at admission
        let r = c.handle_json(&format!(
            r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":0}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{r}");
        assert_eq!(code_of(&r), "deadline_exceeded");

        // malformed deadlines are rejected before admission
        for bad in [
            format!(r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":-5}}"#),
            format!(r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":1.5}}"#),
        ] {
            let r = c.handle_json(&bad);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{bad}");
            assert_eq!(code_of(&r), "bad_request");
        }

        // a generous deadline serves normally
        let r = c.handle_json(&format!(
            r#"{{"op":"spmv","matrix":"t","x":{x_json},"deadline_ms":60000}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    }
}

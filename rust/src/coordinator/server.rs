//! The serving front: in-process [`Coordinator`] API + line-delimited
//! JSON over TCP.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"spmv", "matrix":"m1", "x":[...], "engine":"hbp"}
//! <- {"ok":true, "y":[...]}
//! -> {"op":"list"}
//! <- {"ok":true, "matrices":[{"name":"m1","rows":...,"cols":...,"nnz":...}]}
//! -> {"op":"stats"}
//! <- {"ok":true, "stats":{...}}
//! ```

use super::batcher::{Batcher, BatcherConfig, BatcherHandle};
use super::metrics::ServiceMetrics;
use super::router::{EngineKind, Router};
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// The in-process coordinator: router + batcher + metrics.
pub struct Coordinator {
    pub router: Arc<Router>,
    pub metrics: Arc<ServiceMetrics>,
    // field order matters: `handle` must drop BEFORE `batcher` (fields
    // drop in declaration order) or Batcher::drop joins a dispatcher
    // that still sees a live sender and never exits.
    handle: BatcherHandle,
    batcher: Batcher,
}

impl Coordinator {
    pub fn new(router: Router, cfg: BatcherConfig) -> Coordinator {
        let router = Arc::new(router);
        let metrics = Arc::new(ServiceMetrics::new());
        let batcher = Batcher::start(router.clone(), metrics.clone(), cfg);
        let handle = batcher.handle();
        Coordinator { router, metrics, handle, batcher }
    }

    /// Synchronous SpMV through the batching pipeline.
    pub fn spmv(&self, matrix: &str, engine: EngineKind, x: Vec<f64>) -> Result<Vec<f64>> {
        self.handle.spmv(matrix, engine, x)
    }

    pub fn handle(&self) -> BatcherHandle {
        self.batcher.handle()
    }

    /// Process one protocol request (shared by TCP and tests).
    pub fn handle_json(&self, line: &str) -> Json {
        match self.try_handle(line) {
            Ok(v) => v,
            Err(e) => obj(&[
                ("ok", Json::Bool(false)),
                ("error", Json::Str(format!("{e:#}"))),
            ]),
        }
    }

    fn try_handle(&self, line: &str) -> Result<Json> {
        let req = Json::parse(line).context("parsing request JSON")?;
        match req.req_str("op")? {
            "spmv" => {
                let matrix = req.req_str("matrix")?;
                let engine = EngineKind::parse(
                    req.get("engine").and_then(Json::as_str).unwrap_or("hbp"),
                )?;
                let x: Vec<f64> = req
                    .get("x")
                    .and_then(Json::as_arr)
                    .context("missing array field \"x\"")?
                    .iter()
                    .map(|v| v.as_f64().context("non-numeric x entry"))
                    .collect::<Result<_>>()?;
                let y = self.spmv(matrix, engine, x)?;
                Ok(obj(&[
                    ("ok", Json::Bool(true)),
                    ("y", crate::util::json::num_arr(&y)),
                ]))
            }
            "list" => {
                let matrices: Vec<Json> = self
                    .router
                    .names()
                    .into_iter()
                    .map(|n| {
                        let m = self.router.get(n).unwrap();
                        obj(&[
                            ("name", Json::Str(n.to_string())),
                            ("rows", Json::Num(m.rows as f64)),
                            ("cols", Json::Num(m.cols as f64)),
                            ("nnz", Json::Num(m.nnz as f64)),
                            ("preprocess_secs", Json::Num(m.preprocess_secs)),
                        ])
                    })
                    .collect();
                Ok(obj(&[("ok", Json::Bool(true)), ("matrices", Json::Arr(matrices))]))
            }
            "stats" => Ok(obj(&[
                ("ok", Json::Bool(true)),
                ("stats", self.metrics.snapshot().to_json()),
            ])),
            other => anyhow::bail!("unknown op {other:?}"),
        }
    }
}

/// Serve the coordinator over TCP until the process exits. Binds to
/// `addr` (e.g. `"127.0.0.1:7700"`); one thread per connection.
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("hbp-spmv serving on {}", listener.local_addr()?);
    for stream in listener.incoming() {
        let stream = stream?;
        let c = coordinator.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(c, stream);
        });
    }
    Ok(())
}

/// Serve on an ephemeral port, returning the bound address (tests/e2e).
pub fn serve_background(coordinator: Arc<Coordinator>) -> Result<std::net::SocketAddr> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let c = coordinator.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(c, s);
                    });
                }
                Err(_) => break,
            }
        }
    });
    Ok(addr)
}

fn handle_conn(c: Arc<Coordinator>, stream: TcpStream) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let resp = c.handle_json(line.trim());
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

/// A tiny blocking client for the protocol (examples + tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim())
    }

    pub fn spmv(&mut self, matrix: &str, x: &[f64]) -> Result<Vec<f64>> {
        let req = obj(&[
            ("op", Json::Str("spmv".into())),
            ("matrix", Json::Str(matrix.into())),
            ("x", crate::util::json::num_arr(x)),
        ]);
        let resp = self.call(&req)?;
        anyhow::ensure!(
            resp.get("ok") == Some(&Json::Bool(true)),
            "server error: {resp}"
        );
        resp.get("y")
            .and_then(Json::as_arr)
            .context("missing y")?
            .iter()
            .map(|v| v.as_f64().context("bad y entry"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::random;
    use crate::partition::PartitionConfig;

    fn coordinator() -> Coordinator {
        let mut router = Router::new(PartitionConfig::test_small(), 2);
        router.register("t", random::power_law_rows(40, 30, 2.0, 10, 3)).unwrap();
        Coordinator::new(router, BatcherConfig::default())
    }

    #[test]
    fn json_api_spmv_and_list() {
        let c = coordinator();
        let list = c.handle_json(r#"{"op":"list"}"#);
        assert_eq!(list.get("ok"), Some(&Json::Bool(true)));

        let x: Vec<f64> = (0..30).map(|i| i as f64 / 30.0).collect();
        let req = obj(&[
            ("op", Json::Str("spmv".into())),
            ("matrix", Json::Str("t".into())),
            ("x", crate::util::json::num_arr(&x)),
        ]);
        let resp = c.handle_json(&req.to_string());
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("y").unwrap().as_arr().unwrap().len(), 40);

        let stats = c.handle_json(r#"{"op":"stats"}"#);
        assert!(stats.get("stats").unwrap().req_usize("requests").unwrap() >= 1);
    }

    #[test]
    fn json_api_errors() {
        let c = coordinator();
        let bad = c.handle_json("not json");
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
        let unknown = c.handle_json(r#"{"op":"nope"}"#);
        assert_eq!(unknown.get("ok"), Some(&Json::Bool(false)));
        let missing = c.handle_json(r#"{"op":"spmv","matrix":"zzz","x":[1]}"#);
        assert_eq!(missing.get("ok"), Some(&Json::Bool(false)));
    }
}

//! L3 serving coordinator.
//!
//! A small but real SpMV service in the vLLM-router mold: a matrix
//! registry with preprocessed engines ([`router`]), a dynamic batcher
//! that groups queued requests by matrix ([`batcher`]), latency metrics
//! ([`metrics`]), and a line-delimited-JSON TCP front plus an in-process
//! API ([`server`]). The request path is pure rust — the PJRT runtime
//! executes the AOT-compiled kernels, Python is long gone.
//!
//! Hosted matrices are **mutable**: the `update` request kind applies a
//! value-level [`crate::preprocess::MatrixDelta`] to every resident
//! engine under the matrix's write lock, with the HBP operand repaired
//! incrementally (touched blocks only) instead of rebuilt.
//!
//! Hosted matrices are also **autotuned**: registration runs the
//! [`crate::tune::Tuner`] (features → cost model → competitive trials,
//! short-circuited by a content-hash cache), builds only the decided
//! engine, and serves `EngineKind::Auto` requests through that
//! decision; the `tune` request kind reports the stored record.
//!
//! Batching is **tuning-aware**: the batcher resolves `Auto` through
//! the router's cached decision *before* grouping, so `auto` and
//! explicit requests naming the same resolved engine flush as one SpMV
//! batch ([`batcher`] has the details; `batch_groups`,
//! `batch_merged_auto`, and `mean_group_size` in [`metrics`] are the
//! observable evidence). See `docs/ARCHITECTURE.md` for the layer map
//! and `docs/PROTOCOL.md` for the wire spec.
//!
//! The front is **sharded and pipelined** ([`server`]): the
//! coordinator runs N independent batcher shards over the shared
//! router, connections are assigned round-robin at accept time, and
//! the wire protocol's opaque request-`id` envelope lets one
//! connection keep many `spmv` requests in flight with out-of-order
//! replies (`{"op":"hello"}` advertises `proto`/`features` for
//! feature-detection). Per-shard counters roll up into the global
//! [`metrics`] totals by construction; `stats` exposes the `shards`
//! breakdown.
//!
//! The service is **fault tolerant** by construction: admission control
//! sheds work the bounded queue cannot hold (`overloaded` +
//! `retry_after_ms`), per-request deadlines drop work nobody is waiting
//! for (`deadline_exceeded`), engine/worker panics are isolated to the
//! failing request (`internal`), and the TCP front survives accept
//! errors, stalled clients, and oversized lines ([`server`]). Every
//! degradation is a typed [`error::ErrorCode`] on the wire and a counter
//! in [`metrics`]; `docs/ARCHITECTURE.md` has the failure-modes matrix.
//!
//! `unwrap()` is banned in this tree (`clippy::unwrap_used`, enforced in
//! CI along with `tools/check_no_unwrap.py`): on the serving path a
//! panic is an outage, so every lock acquisition recovers from poison
//! and every fallible path returns a typed error instead.

#![warn(missing_docs)]
#![warn(clippy::unwrap_used)]

pub mod error;
pub mod metrics;
pub mod telemetry;
pub mod router;
pub mod batcher;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, BatcherHandle, SpmvReply};
pub use error::{ErrorCode, ServiceError};
pub use metrics::{MetricsSnapshot, ServiceMetrics};
pub use router::{EngineKind, Router};
pub use server::{
    serve, serve_background_with, serve_with, Client, Connection, Coordinator, ServerConfig,
    ServerHandle, SpmvBuilder, SpmvTicket, PROTO_FEATURES, PROTO_VERSION,
};
pub use telemetry::{prom_text, Span, Telemetry, TraceRing};

//! # hbp-spmv
//!
//! Reproduction of *"A Nonlinear Hash-based Optimization Method for SpMV on
//! GPUs"* (Yan et al., CS.DC 2025) as a three-layer Rust + JAX + Pallas
//! system.
//!
//! The paper introduces the **HBP (Hash-Based Partition)** sparse-matrix
//! format: a 2D-partitioned layout whose rows are reordered *within* each
//! block by a cheap **nonlinear hash** of their nonzero counts, so that rows
//! of similar length are executed by the same warp-sized group (balancing
//! intra-warp load without zero padding), plus a **mixed fixed/competitive
//! execution schedule** that balances load *between* blocks using actual
//! execution time and a ticket lock.
//!
//! Layer map:
//! - **L3 (this crate)** — preprocessing (hash reorder, 2D partition, format
//!   build), baselines (CSR, plain-2D, sort2D, DP2D), parallel execution
//!   engines, a warp-level GPU simulator for the paper's device-specific
//!   figures, the PJRT runtime that loads AOT artifacts, and the serving
//!   coordinator.
//! - **L2 (python/compile/model.py)** — the blocked SpMV compute graph in
//!   JAX, lowered once to HLO text (`make artifacts`).
//! - **L1 (python/compile/kernels/)** — the group-ELL block-SpMV Pallas
//!   kernel called from L2.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod util;
pub mod formats;
pub mod io;
pub mod gen;
pub mod hash;
pub mod partition;
pub mod preprocess;
pub mod exec;
pub mod tune;
pub mod sim;
pub mod runtime;
pub mod coordinator;
pub mod solvers;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

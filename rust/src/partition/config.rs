//! Partition configuration.

/// 2D-partitioning parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionConfig {
    /// Rows per block — the paper's row-direction size N = 512 ("to
    /// balance the preprocessing speed and hash mapping effect").
    pub rows_per_block: usize,
    /// Columns per block — the paper's column-direction size M = 4096
    /// (a double-precision vector segment of 4K fits the per-warp
    /// shared-memory budget of a 48KB-SM GPU).
    pub cols_per_block: usize,
    /// Warp size ω: rows executed in SIMT lockstep by one group.
    pub warp: usize,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig { rows_per_block: 512, cols_per_block: 4096, warp: 32 }
    }
}

impl PartitionConfig {
    /// A small config for unit tests (4 groups of 4 lanes per block).
    pub fn test_small() -> Self {
        PartitionConfig { rows_per_block: 16, cols_per_block: 32, warp: 4 }
    }

    /// Groups per full block (= rows_per_block / warp, the paper's 16).
    pub fn groups_per_block(&self) -> usize {
        self.rows_per_block.div_ceil(self.warp)
    }

    /// Validate invariants needed by the grouping logic.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.rows_per_block > 0, "rows_per_block = 0");
        anyhow::ensure!(self.cols_per_block > 0, "cols_per_block = 0");
        anyhow::ensure!(self.warp > 0, "warp = 0");
        anyhow::ensure!(
            self.rows_per_block % self.warp == 0,
            "rows_per_block {} must be a multiple of warp {}",
            self.rows_per_block,
            self.warp
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let c = PartitionConfig::default();
        assert_eq!(c.rows_per_block, 512);
        assert_eq!(c.cols_per_block, 4096);
        assert_eq!(c.warp, 32);
        assert_eq!(c.groups_per_block(), 16); // the paper's "16 groups"
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_misalignment() {
        let c = PartitionConfig { rows_per_block: 30, cols_per_block: 64, warp: 4 };
        assert!(c.validate().is_err());
        let z = PartitionConfig { rows_per_block: 0, cols_per_block: 64, warp: 4 };
        assert!(z.validate().is_err());
    }
}

//! Per-block views into a parent CSR matrix.
//!
//! Algorithm 2's data-preparation step: for every (row-block, col-block)
//! pair, find each row's sub-range of nonzeros falling inside the block's
//! column range. Because CSR rows store columns sorted, each row is split
//! across column blocks by a forward scan (one pass per row over its
//! nonzeros — the same O(nnz) bound as the paper's per-thread scan).

use super::BlockGrid;
use crate::formats::Csr;

/// A (row-block, col-block) view: for each local row, the `[start, end)`
/// range in the parent CSR arrays that falls inside this block.
#[derive(Clone, Debug)]
pub struct BlockView {
    pub bi: usize,
    pub bj: usize,
    /// Per local row: range into parent `col`/`data`.
    pub row_ranges: Vec<(usize, usize)>,
    pub nnz: usize,
}

impl BlockView {
    /// Per-local-row nonzero counts (the nonlinear hash input).
    pub fn row_nnz(&self) -> Vec<usize> {
        self.row_ranges.iter().map(|&(s, e)| e - s).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.nnz == 0
    }
}

/// Split a CSR matrix into non-empty block views, ordered column-major
/// (all row-blocks of column-block 0 first — the fixed-allocation order).
///
/// Single O(nnz + rows * col_blocks) pass.
pub fn block_views(m: &Csr, grid: &BlockGrid) -> Vec<BlockView> {
    let rb = grid.row_blocks;
    let cb = grid.col_blocks;
    // views[bj][local stuff]: build all in one sweep
    let mut views: Vec<Vec<BlockView>> = (0..cb)
        .map(|bj| {
            (0..rb)
                .map(|bi| BlockView {
                    bi,
                    bj,
                    row_ranges: vec![(0, 0); grid.rows_in(bi)],
                    nnz: 0,
                })
                .collect()
        })
        .collect();

    for r in 0..m.rows {
        let bi = r / grid.cfg.rows_per_block;
        let local = r - bi * grid.cfg.rows_per_block;
        let (rs, re) = (m.ptr[r], m.ptr[r + 1]);
        let mut k = rs;
        while k < re {
            let bj = grid.col_block_of(m.col[k] as usize);
            // scan to the end of this column block within the row
            let col_end = grid.col_range(bj).1;
            let start = k;
            while k < re && (m.col[k] as usize) < col_end {
                k += 1;
            }
            let v = &mut views[bj][bi];
            v.row_ranges[local] = (start, k);
            v.nnz += k - start;
        }
    }

    views
        .into_iter()
        .flatten()
        .filter(|v| !v.is_empty())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::partition::PartitionConfig;

    fn grid(rows: usize, cols: usize) -> BlockGrid {
        BlockGrid::new(rows, cols, PartitionConfig::test_small())
    }

    #[test]
    fn splits_rows_across_column_blocks() {
        // 16-col blocks of cfg.test_small() are 32 wide; use 64 cols => 2 col blocks
        let mut coo = Coo::new(4, 64);
        coo.push(0, 0, 1.0);
        coo.push(0, 31, 2.0);
        coo.push(0, 32, 3.0);
        coo.push(0, 63, 4.0);
        coo.push(3, 40, 5.0);
        let m = coo.to_csr();
        let g = grid(4, 64);
        let views = block_views(&m, &g);
        assert_eq!(views.len(), 2);
        let v0 = views.iter().find(|v| v.bj == 0).unwrap();
        let v1 = views.iter().find(|v| v.bj == 1).unwrap();
        assert_eq!(v0.nnz, 2);
        assert_eq!(v1.nnz, 3);
        assert_eq!(v0.row_nnz()[0], 2);
        assert_eq!(v1.row_nnz()[0], 2);
        assert_eq!(v1.row_nnz()[3], 1);
    }

    #[test]
    fn empty_blocks_dropped() {
        let mut coo = Coo::new(64, 64); // 4 row blocks x 2 col blocks
        coo.push(0, 0, 1.0); // only block (0,0) nonempty
        let m = coo.to_csr();
        let g = grid(64, 64);
        let views = block_views(&m, &g);
        assert_eq!(views.len(), 1);
        assert_eq!((views[0].bi, views[0].bj), (0, 0));
    }

    #[test]
    fn column_major_order() {
        let mut coo = Coo::new(64, 64);
        coo.push(0, 0, 1.0); // (0,0)
        coo.push(40, 0, 1.0); // (2,0)
        coo.push(0, 40, 1.0); // (0,1)
        let m = coo.to_csr();
        let views = block_views(&m, &grid(64, 64));
        let order: Vec<(usize, usize)> = views.iter().map(|v| (v.bj, v.bi)).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted, "views must be column-major sorted");
    }

    #[test]
    fn total_nnz_preserved() {
        let m = crate::gen::random::power_law_rows(100, 200, 2.0, 50, 5);
        let g = grid(100, 200);
        let views = block_views(&m, &g);
        let total: usize = views.iter().map(|v| v.nnz).sum();
        assert_eq!(total, m.nnz());
        // each row's per-block counts sum to the row's nnz
        for v in &views {
            for (local, &(s, e)) in v.row_ranges.iter().enumerate() {
                if s == e {
                    continue; // (0,0) sentinel: row has no entries in block
                }
                let r = v.bi * g.cfg.rows_per_block + local;
                assert!(e >= s && s >= m.ptr[r] && e <= m.ptr[r + 1]);
            }
        }
    }

    #[test]
    fn ranges_cover_correct_columns() {
        let m = crate::gen::random::uniform(50, 100, 0.1, 9);
        let g = grid(50, 100);
        for v in block_views(&m, &g) {
            let (cs, ce) = g.col_range(v.bj);
            for &(s, e) in &v.row_ranges {
                for k in s..e {
                    let c = m.col[k] as usize;
                    assert!(c >= cs && c < ce, "col {c} outside block [{cs},{ce})");
                }
            }
        }
    }
}

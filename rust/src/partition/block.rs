//! Per-block views into a parent CSR matrix.
//!
//! Algorithm 2's data-preparation step: for every (row-block, col-block)
//! pair, find each row's sub-range of nonzeros falling inside the block's
//! column range. Because CSR rows store columns sorted, each row is split
//! across column blocks by a forward scan (one pass per row over its
//! nonzeros — the same O(nnz) bound as the paper's per-thread scan).
//!
//! The planning structure is [`BlockMap`], a CSR-of-blocks: the grid's
//! **non-empty** blocks in column-major order, each owning a contiguous
//! run of sparse [`RowSeg`] row segments. Empty grid cells never
//! materialize anything — planning memory is O(non-empty blocks + row
//! segments) with O(col_blocks + row_blocks) scratch, never the old
//! O(row_blocks × col_blocks × rows_per_block) dense `Vec<Vec<BlockView>>`
//! (one `row_ranges` allocation per grid cell, empty or not).
//!
//! [`BlockView`] — a dense per-slot view of one block — survives as a
//! thin adapter over [`BlockMap`] for consumers that index by slot
//! (the 2D baseline engine, the Fig. 6 stddev bench).

use super::BlockGrid;
use crate::formats::Csr;

/// One row's nonzero run inside a single block: `[start, end)` into the
/// parent CSR `col`/`data` arrays. Only rows that actually have nonzeros
/// in the block get a segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowSeg {
    /// Row index local to the row-block.
    pub local_row: u32,
    pub start: usize,
    pub end: usize,
}

impl RowSeg {
    /// Nonzeros in this segment (always ≥ 1 for segments in a [`BlockMap`]).
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Per-block descriptor in the [`BlockMap`] plan.
#[derive(Clone, Copy, Debug)]
pub struct BlockEntry {
    /// Row-block index.
    pub bi: u32,
    /// Column-block index.
    pub bj: u32,
    /// Nonzeros in this block.
    pub nnz: usize,
    /// Start of this block's run in [`BlockMap::segs`] (rows ascending).
    pub seg_start: usize,
    /// End (exclusive) of this block's run in [`BlockMap::segs`].
    pub seg_end: usize,
}

/// CSR-of-blocks: the non-empty blocks of the 2D grid in column-major
/// order (the fixed-allocation order of §III-C), each owning a contiguous
/// ascending-row run of segments in `segs`.
#[derive(Clone, Debug, Default)]
pub struct BlockMap {
    pub blocks: Vec<BlockEntry>,
    pub segs: Vec<RowSeg>,
    /// Row-block index (CSR-style): `by_bi[bi_ptr[bi]..bi_ptr[bi+1]]`
    /// are the indices into `blocks` of row-block `bi`'s blocks,
    /// ascending. Built free of charge from the placement pass; it is
    /// what keeps [`BlockMap::blocks_for_rows`] proportional to the
    /// touched row-blocks' blocks rather than the whole block list.
    pub bi_ptr: Vec<usize>,
    pub by_bi: Vec<u32>,
}

impl BlockMap {
    /// Row segments of block index `b`.
    pub fn segs_of(&self, b: usize) -> &[RowSeg] {
        let e = &self.blocks[b];
        &self.segs[e.seg_start..e.seg_end]
    }

    /// Total nonzeros across all blocks (= the parent matrix nnz).
    pub fn total_nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Indices into [`BlockMap::blocks`] of the blocks that hold
    /// nonzeros of `row` — the incremental-update path's localization
    /// step. Convenience wrapper over [`BlockMap::blocks_for_rows`].
    pub fn blocks_for_row(&self, grid: &BlockGrid, row: usize) -> Vec<usize> {
        self.blocks_for_rows(grid, &[row])
    }

    /// Indices into [`BlockMap::blocks`] (ascending) of every block that
    /// holds nonzeros of any of `rows`. Rows bucket by row-block, the
    /// `bi_ptr`/`by_bi` index yields each touched row-block's blocks
    /// directly, and each candidate binary-searches its segments (sorted
    /// by `local_row`) — O(touched blocks), never a scan of the whole
    /// block list. Rows may repeat and may be unsorted; rows with no
    /// nonzeros match no block.
    pub fn blocks_for_rows(&self, grid: &BlockGrid, rows: &[usize]) -> Vec<usize> {
        use std::collections::BTreeMap;
        let mut touched: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        for &r in rows {
            debug_assert!(r < grid.rows, "row {r} out of range");
            let bi = grid.row_block_of(r);
            let local = (r - grid.row_range(bi).0) as u32;
            touched.entry(bi).or_default().push(local);
        }
        let mut out = Vec::new();
        for (&bi, locals) in &touched {
            if bi + 1 >= self.bi_ptr.len() {
                continue; // empty/default map, or bi beyond the plan
            }
            for &idx in &self.by_bi[self.bi_ptr[bi]..self.bi_ptr[bi + 1]] {
                let i = idx as usize;
                let segs = self.segs_of(i);
                if locals
                    .iter()
                    .any(|&lr| segs.binary_search_by_key(&lr, |s| s.local_row).is_ok())
                {
                    out.push(i);
                }
            }
        }
        // per-bucket runs are ascending but buckets are bi-ordered while
        // `blocks` is column-major — restore global block-index order
        out.sort_unstable();
        out
    }
}

/// Build the CSR-of-blocks plan in two O(nnz) passes (count, then place).
///
/// Pass 1 scans each row-block, tallying per-column-block nnz and
/// present-row counts for exactly the touched cells; flushed protos are
/// arranged column-major by counting placement (bi stays ascending within
/// a column because flushes happen in bi order — no comparison sort).
/// Pass 2 re-scans and scatters each row segment into its block's run.
pub fn block_map(m: &Csr, grid: &BlockGrid) -> BlockMap {
    let cb = grid.col_blocks;
    let rb = grid.row_blocks;

    struct Proto {
        bi: u32,
        bj: u32,
        nnz: usize,
        nsegs: usize,
    }
    let mut protos: Vec<Proto> = Vec::new();
    let mut nnz_in = vec![0usize; cb]; // per-bj tallies, reset at flush
    let mut segs_in = vec![0usize; cb];
    let mut touched: Vec<usize> = Vec::new();
    let mut per_col = vec![0usize; cb + 1]; // non-empty blocks per bj
    let mut total_segs = 0usize;

    for bi in 0..rb {
        let (r0, r1) = grid.row_range(bi);
        for r in r0..r1 {
            let (row_s, row_e) = (m.ptr[r], m.ptr[r + 1]);
            let mut k = row_s;
            while k < row_e {
                let bj = grid.col_block_of(m.col[k] as usize);
                let col_end = grid.col_range(bj).1;
                let start = k;
                while k < row_e && (m.col[k] as usize) < col_end {
                    k += 1;
                }
                if nnz_in[bj] == 0 {
                    touched.push(bj);
                }
                nnz_in[bj] += k - start;
                segs_in[bj] += 1;
            }
        }
        for &bj in &touched {
            protos.push(Proto {
                bi: bi as u32,
                bj: bj as u32,
                nnz: nnz_in[bj],
                nsegs: segs_in[bj],
            });
            per_col[bj + 1] += 1;
            total_segs += segs_in[bj];
            nnz_in[bj] = 0;
            segs_in[bj] = 0;
        }
        touched.clear();
    }

    // Column-major arrangement by counting placement.
    for j in 0..cb {
        per_col[j + 1] += per_col[j];
    }
    let nblocks = protos.len();
    let mut blocks = vec![BlockEntry { bi: 0, bj: 0, nnz: 0, seg_start: 0, seg_end: 0 }; nblocks];
    {
        let mut cursor: Vec<usize> = per_col[..cb].to_vec();
        for p in &protos {
            let at = cursor[p.bj as usize];
            cursor[p.bj as usize] += 1;
            // seg_start temporarily holds the count; prefix-summed below
            blocks[at] =
                BlockEntry { bi: p.bi, bj: p.bj, nnz: p.nnz, seg_start: p.nsegs, seg_end: 0 };
        }
    }
    let mut seg_acc = 0usize;
    for b in &mut blocks {
        let n = b.seg_start;
        b.seg_start = seg_acc;
        seg_acc += n;
        b.seg_end = seg_acc;
    }
    debug_assert_eq!(seg_acc, total_segs);

    // Pass 2 (place). The bj → block-index map is rebuilt per row-block
    // from a counting sort of block indices by bi; every segment's bj is
    // written before use because its block is in the current bi's bucket.
    // (bi_ptr/by_bi survive into the returned BlockMap as the row-block
    // index the incremental-update path localizes through.)
    let mut bi_ptr = vec![0usize; rb + 1];
    for b in &blocks {
        bi_ptr[b.bi as usize + 1] += 1;
    }
    for i in 0..rb {
        bi_ptr[i + 1] += bi_ptr[i];
    }
    let mut by_bi = vec![0u32; nblocks];
    {
        let mut cursor: Vec<usize> = bi_ptr[..rb].to_vec();
        for (idx, b) in blocks.iter().enumerate() {
            let at = &mut cursor[b.bi as usize];
            by_bi[*at] = idx as u32;
            *at += 1;
        }
    }

    let mut segs = vec![RowSeg { local_row: 0, start: 0, end: 0 }; total_segs];
    let mut seg_cursor: Vec<usize> = blocks.iter().map(|b| b.seg_start).collect();
    let mut block_of = vec![0u32; cb]; // bj → block index for the current bi
    for bi in 0..rb {
        for &idx in &by_bi[bi_ptr[bi]..bi_ptr[bi + 1]] {
            block_of[blocks[idx as usize].bj as usize] = idx;
        }
        let (r0, r1) = grid.row_range(bi);
        for r in r0..r1 {
            let local = (r - r0) as u32;
            let (row_s, row_e) = (m.ptr[r], m.ptr[r + 1]);
            let mut k = row_s;
            while k < row_e {
                let bj = grid.col_block_of(m.col[k] as usize);
                let col_end = grid.col_range(bj).1;
                let start = k;
                while k < row_e && (m.col[k] as usize) < col_end {
                    k += 1;
                }
                let b = block_of[bj] as usize;
                segs[seg_cursor[b]] = RowSeg { local_row: local, start, end: k };
                seg_cursor[b] += 1;
            }
        }
    }
    debug_assert!(blocks.iter().enumerate().all(|(i, b)| seg_cursor[i] == b.seg_end));

    BlockMap { blocks, segs, bi_ptr, by_bi }
}

/// A (row-block, col-block) view: for each local row (slot), the
/// `[start, end)` range in the parent CSR arrays that falls inside this
/// block. Dense over the block's rows — rows without nonzeros hold the
/// `(0, 0)` sentinel.
#[derive(Clone, Debug)]
pub struct BlockView {
    pub bi: usize,
    pub bj: usize,
    /// Per local row: range into parent `col`/`data`.
    pub row_ranges: Vec<(usize, usize)>,
    pub nnz: usize,
}

impl BlockView {
    /// Per-local-row nonzero counts (the nonlinear hash input).
    pub fn row_nnz(&self) -> Vec<usize> {
        self.row_ranges.iter().map(|&(s, e)| e - s).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.nnz == 0
    }
}

/// Split a CSR matrix into dense non-empty block views, ordered
/// column-major. Thin adapter over [`block_map`]: only non-empty blocks
/// ever materialize a `row_ranges` vector.
pub fn block_views(m: &Csr, grid: &BlockGrid) -> Vec<BlockView> {
    let map = block_map(m, grid);
    map.blocks
        .iter()
        .map(|e| {
            let mut row_ranges = vec![(0usize, 0usize); grid.rows_in(e.bi as usize)];
            for s in &map.segs[e.seg_start..e.seg_end] {
                row_ranges[s.local_row as usize] = (s.start, s.end);
            }
            BlockView { bi: e.bi as usize, bj: e.bj as usize, row_ranges, nnz: e.nnz }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;
    use crate::partition::PartitionConfig;

    fn grid(rows: usize, cols: usize) -> BlockGrid {
        BlockGrid::new(rows, cols, PartitionConfig::test_small())
    }

    #[test]
    fn splits_rows_across_column_blocks() {
        // 16-col blocks of cfg.test_small() are 32 wide; use 64 cols => 2 col blocks
        let mut coo = Coo::new(4, 64);
        coo.push(0, 0, 1.0);
        coo.push(0, 31, 2.0);
        coo.push(0, 32, 3.0);
        coo.push(0, 63, 4.0);
        coo.push(3, 40, 5.0);
        let m = coo.to_csr();
        let g = grid(4, 64);
        let views = block_views(&m, &g);
        assert_eq!(views.len(), 2);
        let v0 = views.iter().find(|v| v.bj == 0).unwrap();
        let v1 = views.iter().find(|v| v.bj == 1).unwrap();
        assert_eq!(v0.nnz, 2);
        assert_eq!(v1.nnz, 3);
        assert_eq!(v0.row_nnz()[0], 2);
        assert_eq!(v1.row_nnz()[0], 2);
        assert_eq!(v1.row_nnz()[3], 1);
    }

    #[test]
    fn empty_blocks_dropped() {
        let mut coo = Coo::new(64, 64); // 4 row blocks x 2 col blocks
        coo.push(0, 0, 1.0); // only block (0,0) nonempty
        let m = coo.to_csr();
        let g = grid(64, 64);
        let views = block_views(&m, &g);
        assert_eq!(views.len(), 1);
        assert_eq!((views[0].bi, views[0].bj), (0, 0));
    }

    #[test]
    fn column_major_order() {
        let mut coo = Coo::new(64, 64);
        coo.push(0, 0, 1.0); // (0,0)
        coo.push(40, 0, 1.0); // (2,0)
        coo.push(0, 40, 1.0); // (0,1)
        let m = coo.to_csr();
        let views = block_views(&m, &grid(64, 64));
        let order: Vec<(usize, usize)> = views.iter().map(|v| (v.bj, v.bi)).collect();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(order, sorted, "views must be column-major sorted");
    }

    #[test]
    fn total_nnz_preserved() {
        let m = crate::gen::random::power_law_rows(100, 200, 2.0, 50, 5);
        let g = grid(100, 200);
        let views = block_views(&m, &g);
        let total: usize = views.iter().map(|v| v.nnz).sum();
        assert_eq!(total, m.nnz());
        // each row's per-block counts sum to the row's nnz
        for v in &views {
            for (local, &(s, e)) in v.row_ranges.iter().enumerate() {
                if s == e {
                    continue; // (0,0) sentinel: row has no entries in block
                }
                let r = v.bi * g.cfg.rows_per_block + local;
                assert!(e >= s && s >= m.ptr[r] && e <= m.ptr[r + 1]);
            }
        }
    }

    #[test]
    fn ranges_cover_correct_columns() {
        let m = crate::gen::random::uniform(50, 100, 0.1, 9);
        let g = grid(50, 100);
        for v in block_views(&m, &g) {
            let (cs, ce) = g.col_range(v.bj);
            for &(s, e) in &v.row_ranges {
                for k in s..e {
                    let c = m.col[k] as usize;
                    assert!(c >= cs && c < ce, "col {c} outside block [{cs},{ce})");
                }
            }
        }
    }

    #[test]
    fn block_map_agrees_with_views() {
        let m = crate::gen::random::power_law_rows(100, 200, 2.0, 50, 5);
        let g = grid(100, 200);
        let map = block_map(&m, &g);
        let views = block_views(&m, &g);
        assert_eq!(map.blocks.len(), views.len());
        assert_eq!(map.total_nnz(), m.nnz());
        for (i, (e, v)) in map.blocks.iter().zip(&views).enumerate() {
            assert_eq!((e.bi as usize, e.bj as usize), (v.bi, v.bj));
            assert_eq!(e.nnz, v.nnz);
            let seg_nnz: usize = map.segs_of(i).iter().map(|s| s.len()).sum();
            assert_eq!(seg_nnz, e.nnz, "block {i} segment nnz");
        }
    }

    #[test]
    fn block_map_rows_ascending_and_nonempty() {
        let m = crate::gen::random::uniform(70, 130, 0.15, 13);
        let g = grid(70, 130);
        let map = block_map(&m, &g);
        for (i, e) in map.blocks.iter().enumerate() {
            let segs = map.segs_of(i);
            assert!(!segs.is_empty(), "block {i} has no segments");
            for s in segs {
                assert!(!s.is_empty(), "block {i} empty segment");
                assert!((s.local_row as usize) < g.rows_in(e.bi as usize));
            }
            for w in segs.windows(2) {
                assert!(w[0].local_row < w[1].local_row, "block {i} rows not ascending");
            }
        }
    }

    #[test]
    fn block_map_wide_matrix_only_touched_cells() {
        // 10 x 1000: 32-wide column blocks => 32 cells per row-block, but
        // only 2 columns are touched — planning must stay proportional to
        // the touched cells, not the grid.
        let mut coo = Coo::new(10, 1000);
        coo.push(0, 3, 1.0);
        coo.push(7, 990, 2.0);
        let m = coo.to_csr();
        let g = grid(10, 1000);
        let map = block_map(&m, &g);
        assert_eq!(map.blocks.len(), 2);
        assert_eq!(map.segs.len(), 2);
        assert_eq!(map.blocks[0].bj, 0);
        assert_eq!(map.blocks[1].bj as usize, 990 / g.cfg.cols_per_block);
    }

    #[test]
    fn blocks_for_rows_finds_exactly_the_holding_blocks() {
        let m = crate::gen::random::power_law_rows(100, 200, 2.0, 50, 41);
        let g = grid(100, 200);
        let map = block_map(&m, &g);
        for row in [0usize, 17, 50, 99] {
            let found = map.blocks_for_row(&g, row);
            // oracle: every block either holds the row's nonzeros or not
            for (i, e) in map.blocks.iter().enumerate() {
                let bi = g.row_block_of(row);
                let local = (row - g.row_range(bi).0) as u32;
                let holds = e.bi as usize == bi
                    && map.segs_of(i).iter().any(|s| s.local_row == local);
                assert_eq!(found.contains(&i), holds, "row {row} block {i}");
            }
        }
        // ascending + deduped even with repeated unsorted input rows
        let multi = map.blocks_for_rows(&g, &[99, 0, 99, 0, 17]);
        for w in multi.windows(2) {
            assert!(w[0] < w[1], "not ascending/deduped: {multi:?}");
        }
    }

    #[test]
    fn row_block_index_covers_blocks_exactly_once() {
        let m = crate::gen::random::power_law_rows(90, 180, 2.0, 40, 53);
        let g = grid(90, 180);
        let map = block_map(&m, &g);
        assert_eq!(map.bi_ptr.len(), g.row_blocks + 1);
        assert_eq!(map.by_bi.len(), map.blocks.len());
        let mut seen = vec![false; map.blocks.len()];
        for bi in 0..g.row_blocks {
            let bucket = &map.by_bi[map.bi_ptr[bi]..map.bi_ptr[bi + 1]];
            for w in bucket.windows(2) {
                assert!(w[0] < w[1], "bucket {bi} not ascending");
            }
            for &idx in bucket {
                assert_eq!(map.blocks[idx as usize].bi as usize, bi);
                assert!(!seen[idx as usize], "block {idx} in two buckets");
                seen[idx as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "index missed a block");
    }

    #[test]
    fn blocks_for_rows_zero_nnz_row_matches_nothing() {
        let mut coo = Coo::new(40, 40);
        coo.push(0, 0, 1.0);
        coo.push(39, 39, 2.0);
        let m = coo.to_csr();
        let g = grid(40, 40);
        let map = block_map(&m, &g);
        assert!(map.blocks_for_row(&g, 5).is_empty());
        assert_eq!(map.blocks_for_row(&g, 0).len(), 1);
        assert_eq!(map.blocks_for_row(&g, 39).len(), 1);
    }

    #[test]
    fn block_map_empty_matrix() {
        let m = Csr::empty(8, 8);
        let g = grid(8, 8);
        let map = block_map(&m, &g);
        assert!(map.is_empty());
        assert!(map.segs.is_empty());
        assert_eq!(map.total_nnz(), 0);
    }
}

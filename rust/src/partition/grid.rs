//! The block grid: shape bookkeeping for a 2D-partitioned matrix.

use super::PartitionConfig;

/// Grid of 2D-partition blocks over a `rows x cols` matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockGrid {
    pub rows: usize,
    pub cols: usize,
    pub cfg: PartitionConfig,
    pub row_blocks: usize,
    pub col_blocks: usize,
}

impl BlockGrid {
    pub fn new(rows: usize, cols: usize, cfg: PartitionConfig) -> Self {
        BlockGrid {
            rows,
            cols,
            cfg,
            row_blocks: rows.div_ceil(cfg.rows_per_block).max(1),
            col_blocks: cols.div_ceil(cfg.cols_per_block).max(1),
        }
    }

    /// Total block count (including blocks that may turn out empty).
    pub fn num_blocks(&self) -> usize {
        self.row_blocks * self.col_blocks
    }

    /// Row range `[start, end)` of row-block `bi` (edge-clamped).
    pub fn row_range(&self, bi: usize) -> (usize, usize) {
        let start = bi * self.cfg.rows_per_block;
        (start, (start + self.cfg.rows_per_block).min(self.rows))
    }

    /// Column range `[start, end)` of column-block `bj` (edge-clamped).
    pub fn col_range(&self, bj: usize) -> (usize, usize) {
        let start = bj * self.cfg.cols_per_block;
        (start, (start + self.cfg.cols_per_block).min(self.cols))
    }

    /// Number of rows in row-block `bi`.
    pub fn rows_in(&self, bi: usize) -> usize {
        let (s, e) = self.row_range(bi);
        e - s
    }

    /// Which column block a column index falls into.
    pub fn col_block_of(&self, col: usize) -> usize {
        col / self.cfg.cols_per_block
    }

    /// Which row block a row index falls into (the update path's
    /// touched-row → row-block mapping).
    pub fn row_block_of(&self, row: usize) -> usize {
        row / self.cfg.rows_per_block
    }

    /// Flat block index, column-major (the fixed-allocation order of
    /// §III-C: consecutive blocks share a column => vector-segment reuse).
    pub fn flat_col_major(&self, bi: usize, bj: usize) -> usize {
        bj * self.row_blocks + bi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::PartitionConfig;

    #[test]
    fn edge_clamping() {
        let g = BlockGrid::new(1000, 5000, PartitionConfig::default());
        assert_eq!(g.row_blocks, 2);
        assert_eq!(g.col_blocks, 2);
        assert_eq!(g.row_range(1), (512, 1000));
        assert_eq!(g.col_range(1), (4096, 5000));
        assert_eq!(g.rows_in(1), 488);
    }

    #[test]
    fn small_matrix_single_block() {
        let g = BlockGrid::new(10, 10, PartitionConfig::default());
        assert_eq!(g.num_blocks(), 1);
        assert_eq!(g.row_range(0), (0, 10));
    }

    #[test]
    fn col_block_lookup() {
        let g = BlockGrid::new(100, 10_000, PartitionConfig::default());
        assert_eq!(g.col_block_of(0), 0);
        assert_eq!(g.col_block_of(4095), 0);
        assert_eq!(g.col_block_of(4096), 1);
        assert_eq!(g.col_block_of(9999), 2);
    }

    #[test]
    fn row_block_lookup() {
        let g = BlockGrid::new(1000, 100, PartitionConfig::default());
        assert_eq!(g.row_block_of(0), 0);
        assert_eq!(g.row_block_of(511), 0);
        assert_eq!(g.row_block_of(512), 1);
        assert_eq!(g.row_block_of(999), 1);
        // consistent with row_range
        for r in [0usize, 511, 512, 999] {
            let bi = g.row_block_of(r);
            let (lo, hi) = g.row_range(bi);
            assert!(r >= lo && r < hi);
        }
    }

    #[test]
    fn col_major_ordering_groups_columns() {
        let g = BlockGrid::new(2000, 10_000, PartitionConfig::default());
        // blocks in the same column block are consecutive
        let a = g.flat_col_major(0, 0);
        let b = g.flat_col_major(1, 0);
        let c = g.flat_col_major(0, 1);
        assert_eq!(b, a + 1);
        assert!(c > b);
    }
}

//! 2D partitioning of sparse matrices (§III-A).
//!
//! Column partitioning bounds the x-vector segment a block touches
//! (shared-memory / VMEM locality); row partitioning bounds the scope of
//! hash reordering. The paper's defaults: column block M = 4096 (a 4K
//! vector segment of doubles fits a warp's shared-memory budget), row
//! block N = 512, warp ω = 32 → 16 groups per block.

pub mod config;
pub mod grid;
pub mod block;

pub use block::{block_map, block_views, BlockEntry, BlockMap, BlockView, RowSeg};
pub use config::PartitionConfig;
pub use grid::BlockGrid;

//! Warp-level GPU cost simulator.
//!
//! The paper's device-specific results (Figs 8/10 GFLOPS on Jetson AGX
//! Orin and RTX 4090, Table II Nsight memory counters) cannot be measured
//! here — there is no GPU. Per DESIGN.md §2 the substitution is a
//! **trace-driven analytical cost model** at warp granularity:
//!
//! - SIMT lockstep: a warp-group's round count is the *max* lane length —
//!   the divergence cost the nonlinear hash removes.
//! - Memory system: 128B DRAM transactions; element streams are costed by
//!   the lines they touch (coalesced layouts touch ~nnz*12/128, scattered
//!   layouts touch up to one line per lane per round); x-vector gathers
//!   are costed by *exact* distinct-line counts computed from the actual
//!   column indices (so banded matrices get their cache locality, and
//!   kron matrices get punished — the m3-vs-m4 crossover in the paper).
//! - Shared memory: block engines prefetch the x segment once per
//!   (warp, block) and then gather at cheap fixed latency.
//! - Scheduling: warp tasks are list-scheduled onto SM slots either
//!   statically (CSR, plain 2D) or greedily/earliest-free (HBP's
//!   competitive tail).
//! - Kernel time = max(schedule makespan, DRAM-bandwidth bound); Mem Busy
//!   and Mem Throughput follow the Nsight definitions on modeled bytes.
//!
//! This is a *cost model*, not a cycle-accurate simulator: absolute
//! numbers are indicative, relative orderings (who wins, where the
//! crossovers are) are the reproduction target. Constants live in
//! [`device::DeviceConfig`] with sources in comments.

pub mod device;
pub mod faults;
pub mod memory;
pub mod simt;
pub mod kernels;
pub mod metrics;

pub use device::DeviceConfig;
pub use kernels::{simulate_csr, simulate_hbp, simulate_spmv2d};
pub use metrics::SimReport;

//! Memory-system cost accounting.
//!
//! Two access regimes, following the CUDA memory model at the fidelity
//! the paper's Table II requires:
//!
//! - **Streaming** (coalesced): cost is carried by the global bandwidth
//!   bound; per-warp cycles are negligible next to latency-bound traffic.
//! - **Latency-bound** (scattered gathers): each distinct DRAM line is a
//!   transaction; a warp overlaps `mlp` of them, so cycles =
//!   `lines * latency / mlp`.
//!
//! Distinct-line counts for x-vector gathers are computed *exactly* from
//! the column indices each warp round touches — this is what gives banded
//! matrices their locality advantage under CSR and makes kron matrices
//! latency-bound, reproducing the paper's m3 vs m4 behaviour.

use super::device::DeviceConfig;

/// Accumulated memory-traffic statistics for one simulated kernel.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemTraffic {
    /// Bytes moved over DRAM (line-granular).
    pub dram_bytes: f64,
    /// Latency-bound transactions (scattered gathers).
    pub latency_transactions: f64,
    /// Shared-memory warp-wide accesses.
    pub smem_accesses: f64,
}

impl MemTraffic {
    pub fn add(&mut self, other: &MemTraffic) {
        self.dram_bytes += other.dram_bytes;
        self.latency_transactions += other.latency_transactions;
        self.smem_accesses += other.smem_accesses;
    }

    /// Per-warp latency-bound + shared-memory cycles.
    pub fn warp_cycles(&self, dev: &DeviceConfig) -> f64 {
        self.latency_transactions * dev.dram_latency_cycles / dev.mlp
            + self.smem_accesses * dev.smem_latency_cycles
    }
}

/// Count distinct `line_bytes`-sized lines touched by accessing 8-byte
/// elements at the given indices (indices are element offsets into an
/// f64 array). Exact, allocation-light for the warp-sized inputs it gets.
pub fn distinct_lines(indices: &[usize], line_bytes: usize) -> usize {
    let per_line = (line_bytes / 8).max(1);
    match indices.len() {
        0 => 0,
        1 => 1,
        _ => {
            let mut lines: Vec<usize> = indices.iter().map(|&i| i / per_line).collect();
            lines.sort_unstable();
            lines.dedup();
            lines.len()
        }
    }
}

/// Streaming traffic for `bytes` of coalesced transfer.
pub fn streamed(bytes: f64) -> MemTraffic {
    MemTraffic { dram_bytes: bytes, latency_transactions: 0.0, smem_accesses: 0.0 }
}

/// Scattered gather of `lines` distinct DRAM lines.
pub fn gathered(lines: usize, dev: &DeviceConfig) -> MemTraffic {
    MemTraffic {
        dram_bytes: (lines * dev.line_bytes) as f64,
        latency_transactions: lines as f64,
        smem_accesses: 0.0,
    }
}

/// `n` warp-wide shared-memory accesses.
pub fn shared(n: f64) -> MemTraffic {
    MemTraffic { dram_bytes: 0.0, latency_transactions: 0.0, smem_accesses: n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_lines_counts() {
        // 16 doubles per 128B line
        assert_eq!(distinct_lines(&[0, 1, 15], 128), 1);
        assert_eq!(distinct_lines(&[0, 16], 128), 2);
        assert_eq!(distinct_lines(&[], 128), 0);
        assert_eq!(distinct_lines(&[100, 100, 100], 128), 1);
        // widely scattered: one line each
        let scattered: Vec<usize> = (0..32).map(|i| i * 1000).collect();
        assert_eq!(distinct_lines(&scattered, 128), 32);
    }

    #[test]
    fn traffic_accumulates() {
        let dev = DeviceConfig::orin();
        let mut t = MemTraffic::default();
        t.add(&streamed(1024.0));
        t.add(&gathered(4, &dev));
        t.add(&shared(2.0));
        assert_eq!(t.dram_bytes, 1024.0 + 4.0 * 128.0);
        assert_eq!(t.latency_transactions, 4.0);
        let cycles = t.warp_cycles(&dev);
        assert!(cycles > 0.0);
        let expect = 4.0 * dev.dram_latency_cycles / dev.mlp + 2.0 * dev.smem_latency_cycles;
        assert!((cycles - expect).abs() < 1e-9);
    }
}

//! Trace-driven kernel models for the three engines.
//!
//! Each simulator walks the *actual* matrix/HBP structure, counts the
//! exact rounds, transactions and distinct x-lines every warp performs,
//! and reduces them to a kernel time via the SM-slot schedule and the
//! DRAM bandwidth bound.

use super::device::DeviceConfig;
use super::memory::{self, MemTraffic};
use super::metrics::SimReport;
use super::simt::{self, WarpTask};
use crate::formats::Csr;
use crate::preprocess::Hbp;

/// Bytes per stored nonzero (8B value + 4B column index).
const ELEM_BYTES: f64 = 12.0;

/// Finalize a report: kernel time = max(slot-schedule makespan, DRAM
/// bandwidth bound) for the SpMV phase; combine is bandwidth-bound.
fn finalize(
    dev: &DeviceConfig,
    makespan_cycles: f64,
    spmv_traffic: &MemTraffic,
    combine_bytes: f64,
    nnz: usize,
) -> SimReport {
    let sched_secs = dev.secs(makespan_cycles);
    let bw_secs = spmv_traffic.dram_bytes / (dev.dram_bw_gbps * 1e9);
    let spmv_secs = sched_secs.max(bw_secs);
    let combine_secs = combine_bytes / (dev.dram_bw_gbps * 1e9);
    SimReport {
        spmv_secs,
        combine_secs,
        dram_bytes: spmv_traffic.dram_bytes + combine_bytes,
        nnz,
    }
}

/// Simulate CSR SpMV (Algorithm 1): one thread per row, warps of 32
/// consecutive rows, static scheduling.
pub fn simulate_csr(m: &Csr, dev: &DeviceConfig) -> SimReport {
    let w = dev.warp_size;
    let mut tasks = Vec::with_capacity(m.rows.div_ceil(w));
    let mut total = MemTraffic::default();
    let mut cols_scratch: Vec<usize> = Vec::with_capacity(w);

    for warp_start in (0..m.rows).step_by(w) {
        let rows = warp_start..(warp_start + w).min(m.rows);
        let rounds = rows.clone().map(|r| m.row_nnz(r)).max().unwrap_or(0);
        let mut traffic = MemTraffic::default();

        // element loads: each lane streams its own row; CSR rows are
        // stored back-to-back, so the warp's element data is one
        // contiguous byte range (+1 line for boundary misalignment)
        let elem_bytes: f64 = rows.clone().map(|r| m.row_nnz(r) as f64 * ELEM_BYTES).sum();
        let elem_lines = (elem_bytes / dev.line_bytes as f64).ceil() + 1.0;
        traffic.add(&memory::streamed(elem_lines * dev.line_bytes as f64));

        // x gathers: per round, exact distinct lines over lanes' columns.
        // Latency is paid every round (gathers serialize on the memory
        // pipeline); DRAM *bytes* are paid once per distinct line per
        // warp (L2 catches the re-touches) — this split is what makes
        // divergent matrices slow AND low-throughput, as in Table II.
        let mut warp_lines: std::collections::HashSet<usize> = std::collections::HashSet::new();
        let per_line = (dev.line_bytes / 8).max(1);
        for k in 0..rounds {
            cols_scratch.clear();
            for r in rows.clone() {
                let (cols, _) = m.row(r);
                if let Some(&c) = cols.get(k) {
                    cols_scratch.push(c as usize);
                }
            }
            let lines = memory::distinct_lines(&cols_scratch, dev.line_bytes);
            let mut new_lines = 0usize;
            for &c in &cols_scratch {
                if warp_lines.insert(c / per_line) {
                    new_lines += 1;
                }
            }
            traffic.add(&MemTraffic {
                dram_bytes: (new_lines * dev.line_bytes) as f64,
                latency_transactions: lines as f64,
                smem_accesses: 0.0,
            });
        }

        // y write
        traffic.add(&memory::streamed(rows.len() as f64 * 8.0));

        let cycles = simt::compute_cycles(rounds, dev) + traffic.warp_cycles(dev);
        tasks.push(WarpTask { cycles });
        total.add(&traffic);
    }

    let makespan = simt::schedule_static(&tasks, dev.total_slots());
    finalize(dev, makespan, &total, 0.0, m.nnz())
}

/// Shared block-engine skeleton: walk an HBP structure, costing one warp
/// task per *block*; `coalesced` selects the HBP round-major layout
/// (streamed element loads) vs the plain-2D row-major layout (scattered
/// element gathers + divergent rounds computed from *natural* order).
fn simulate_blocks(
    hbp: &Hbp,
    dev: &DeviceConfig,
    coalesced: bool,
    competitive_frac: f64,
) -> SimReport {
    let w = hbp.grid.cfg.warp;
    let mut tasks = Vec::with_capacity(hbp.blocks.len());
    let mut total = MemTraffic::default();
    let mut total_slots = 0usize;

    for b in &hbp.blocks {
        let mut traffic = MemTraffic::default();
        let mut cycles = 0.0;

        // x-segment prefetch into shared memory, once per (warp, block):
        // coalesced stream of the block's column range ("a considerable
        // amount of unnecessary data", §IV-C — counted in full)
        let (cs, ce) = hbp.grid.col_range(b.bj as usize);
        traffic.add(&memory::streamed((ce - cs) as f64 * 8.0));

        // per-group lane walks
        for g in 0..b.ngroups {
            let slot_lo = g * w;
            let slot_hi = ((g + 1) * w).min(b.nrows);
            // lane lengths in execution order
            let mut lens = Vec::with_capacity(slot_hi - slot_lo);
            for s in slot_lo..slot_hi {
                if hbp.zero_row[b.slot_start + s] == -1 {
                    lens.push(0);
                } else {
                    // walk chain length via add_sign
                    let gp = hbp.begin_ptr[b.group_start + g];
                    let rank = (s - slot_lo) as i32 - hbp.zero_row[b.slot_start + s];
                    let mut j = gp + rank as usize;
                    let mut l = 1usize;
                    while hbp.add_sign[j] != -1 {
                        j += hbp.add_sign[j] as usize;
                        l += 1;
                    }
                    lens.push(l);
                }
            }
            let rounds = lens.iter().copied().max().unwrap_or(0);
            let group_nnz: usize = lens.iter().sum();

            if coalesced {
                // HBP: round-major layout => element loads stream
                let bytes = group_nnz as f64 * ELEM_BYTES;
                let lines = (bytes / dev.line_bytes as f64).ceil() + 1.0;
                traffic.add(&memory::streamed(lines * dev.line_bytes as f64));
            } else {
                // plain 2D: row-major layout => bytes stream (each lane's
                // row is contiguous, lines are reused across rounds like
                // CSR), but each round issues one partially-coalesced
                // gather per ~4 active lanes (adjacent rows rarely share
                // a line within a round)
                let bytes = group_nnz as f64 * ELEM_BYTES;
                let lines = (bytes / dev.line_bytes as f64).ceil() + 1.0;
                traffic.add(&memory::streamed(lines * dev.line_bytes as f64));
                for k in 0..rounds {
                    let active = lens.iter().filter(|&&l| l > k).count();
                    traffic.add(&MemTraffic {
                        dram_bytes: 0.0,
                        latency_transactions: (active as f64 / 4.0).ceil(),
                        smem_accesses: 0.0,
                    });
                }
            }
            // x gathers from shared memory: one warp-wide access per round
            traffic.add(&memory::shared(rounds as f64));
            cycles += simt::compute_cycles(rounds, dev);
        }

        // partial-vector write (streamed)
        traffic.add(&memory::streamed(b.nrows as f64 * 8.0));
        total_slots += b.nrows;

        cycles += traffic.warp_cycles(dev);
        tasks.push(WarpTask { cycles });
        total.add(&traffic);
    }

    let makespan = if competitive_frac > 0.0 {
        simt::schedule_mixed(&tasks, dev.total_slots(), competitive_frac)
    } else {
        simt::schedule_static(&tasks, dev.total_slots())
    };

    // combine: read partials + accumulate + write y (bandwidth-bound)
    let combine_bytes = total_slots as f64 * 8.0 * 2.0 + hbp.rows as f64 * 8.0;
    finalize(dev, makespan, &total, combine_bytes, hbp.nnz())
}

/// Simulate the HBP kernel (hash-reordered, coalesced layout, mixed
/// fixed/competitive schedule).
pub fn simulate_hbp(hbp: &Hbp, dev: &DeviceConfig, competitive_frac: f64) -> SimReport {
    simulate_blocks(hbp, dev, true, competitive_frac)
}

/// Simulate the plain 2D-partitioning kernel over an identity-ordered
/// HBP shell (no reorder, row-major element access, static schedule).
pub fn simulate_spmv2d(shell: &Hbp, dev: &DeviceConfig) -> SimReport {
    simulate_blocks(shell, dev, false, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{matrix_by_id, Scale};
    use crate::partition::PartitionConfig;
    use crate::preprocess::{build_hbp, build_hbp_with, IdentityReorder};

    fn sims(id: &str) -> (SimReport, SimReport, SimReport) {
        let (_, m) = matrix_by_id(id, Scale::Ci).unwrap();
        let dev = DeviceConfig::orin();
        let cfg = PartitionConfig::default();
        let hbp = build_hbp(&m, cfg);
        let shell = build_hbp_with(&m, cfg, &IdentityReorder);
        (
            simulate_csr(&m, &dev),
            simulate_spmv2d(&shell, &dev),
            simulate_hbp(&hbp, &dev, 0.25),
        )
    }

    #[test]
    fn hbp_beats_csr_on_scattered_kron() {
        // the paper's m4 story: scattered vector access kills CSR
        let (csr, _d2, hbp) = sims("m4");
        assert!(
            hbp.gflops() > csr.gflops(),
            "HBP {:.2} should beat CSR {:.2} GFLOPS on kron",
            hbp.gflops(),
            csr.gflops()
        );
    }

    #[test]
    fn csr_holds_on_banded_barrier() {
        // the paper's m3 story: banded locality favors CSR
        let (csr, _d2, hbp) = sims("m3");
        assert!(
            csr.gflops() > 0.8 * hbp.gflops(),
            "CSR {:.2} should stay competitive with HBP {:.2} on banded",
            csr.gflops(),
            hbp.gflops()
        );
    }

    #[test]
    fn hbp_beats_plain_2d() {
        let (_csr, d2, hbp) = sims("m2");
        assert!(
            hbp.gflops() > d2.gflops(),
            "HBP {:.2} should beat 2D {:.2}",
            hbp.gflops(),
            d2.gflops()
        );
    }

    #[test]
    fn hbp_raises_memory_throughput_on_saturating_circuit() {
        // Table II shape: circuit-matrix CSR throughput low (latency
        // bound), HBP high (streaming). Needs a matrix big enough to
        // saturate the device's warp slots — CI-scale suite matrices
        // underfill the 4090/Orin models, so generate one directly.
        let m = crate::gen::circuit::circuit(&crate::gen::circuit::CircuitConfig::asic_like(
            40_000, 7,
        ));
        let dev = DeviceConfig::orin();
        let cfg = PartitionConfig::default();
        let hbp = build_hbp(&m, cfg);
        let csr = simulate_csr(&m, &dev);
        let h = simulate_hbp(&hbp, &dev, 0.25);
        assert!(
            h.mem_throughput_gbps() > 1.5 * csr.mem_throughput_gbps(),
            "HBP throughput {:.1} should exceed CSR {:.1}",
            h.mem_throughput_gbps(),
            csr.mem_throughput_gbps()
        );
        // and HBP must also be faster in wall-clock terms here
        assert!(h.total_secs() < csr.total_secs());
    }

    #[test]
    fn faster_device_is_faster() {
        let (_, m) = matrix_by_id("m1", Scale::Ci).unwrap();
        let hbp = build_hbp(&m, PartitionConfig::default());
        let orin = simulate_hbp(&hbp, &DeviceConfig::orin(), 0.25);
        let ada = simulate_hbp(&hbp, &DeviceConfig::rtx4090(), 0.25);
        assert!(ada.total_secs() < orin.total_secs());
    }

    #[test]
    fn reports_are_deterministic() {
        let (a1, b1, c1) = sims("m9");
        let (a2, b2, c2) = sims("m9");
        assert_eq!(a1.total_secs(), a2.total_secs());
        assert_eq!(b1.total_secs(), b2.total_secs());
        assert_eq!(c1.total_secs(), c2.total_secs());
    }
}

//! Device models for the paper's two testbeds.

/// GPU device parameters for the cost model.
#[derive(Clone, Copy, Debug)]
pub struct DeviceConfig {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub num_sms: usize,
    /// Concurrently *executing* warp slots per SM (warp schedulers).
    pub warp_slots_per_sm: usize,
    /// SIMT width.
    pub warp_size: usize,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// Peak DRAM bandwidth, GB/s.
    pub dram_bw_gbps: f64,
    /// DRAM round-trip latency, cycles.
    pub dram_latency_cycles: f64,
    /// Memory-level parallelism: outstanding misses a warp slot
    /// effectively overlaps. This folds in latency hiding from warp
    /// oversubscription (resident warps >> executing warps), which the
    /// slot-level scheduler does not model explicitly.
    pub mlp: f64,
    /// Shared-memory access latency, cycles (per warp-wide access).
    pub smem_latency_cycles: f64,
    /// Cycles per FMA round (pipelined issue cost per warp instruction).
    pub fma_cycles: f64,
    /// DRAM transaction size, bytes.
    pub line_bytes: usize,
}

impl DeviceConfig {
    /// NVIDIA Jetson AGX Orin 64GB: 2048-core Ampere (16 SMs x 128),
    /// 4 warp schedulers/SM, ~1.3 GHz, 204.8 GB/s LPDDR5.
    pub fn orin() -> Self {
        DeviceConfig {
            name: "orin",
            num_sms: 16,
            warp_slots_per_sm: 4,
            warp_size: 32,
            clock_ghz: 1.3,
            dram_bw_gbps: 204.8,
            dram_latency_cycles: 600.0,
            mlp: 32.0,
            smem_latency_cycles: 30.0,
            fma_cycles: 4.0,
            line_bytes: 128,
        }
    }

    /// NVIDIA RTX 4090: 16384-core Ada (128 SMs x 128), 4 warp
    /// schedulers/SM, ~2.52 GHz boost, 1008 GB/s GDDR6X.
    pub fn rtx4090() -> Self {
        DeviceConfig {
            name: "rtx4090",
            num_sms: 128,
            warp_slots_per_sm: 4,
            warp_size: 32,
            clock_ghz: 2.52,
            dram_bw_gbps: 1008.0,
            dram_latency_cycles: 500.0,
            mlp: 48.0,
            smem_latency_cycles: 25.0,
            fma_cycles: 4.0,
            line_bytes: 128,
        }
    }

    /// Total concurrent warp slots.
    pub fn total_slots(&self) -> usize {
        self.num_sms * self.warp_slots_per_sm
    }

    /// DRAM bytes per core cycle (whole device).
    pub fn bytes_per_cycle(&self) -> f64 {
        self.dram_bw_gbps / self.clock_ghz
    }

    /// Seconds for a cycle count.
    pub fn secs(&self, cycles: f64) -> f64 {
        cycles / (self.clock_ghz * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        let o = DeviceConfig::orin();
        let r = DeviceConfig::rtx4090();
        assert!(r.num_sms > o.num_sms * 4);
        assert!(r.dram_bw_gbps > o.dram_bw_gbps * 3.0);
        assert_eq!(o.warp_size, 32);
        assert!(o.total_slots() < r.total_slots());
    }

    #[test]
    fn unit_conversions() {
        let o = DeviceConfig::orin();
        assert!((o.secs(1.3e9) - 1.0).abs() < 1e-9);
        assert!(o.bytes_per_cycle() > 100.0);
    }
}

//! SIMT warp tasks and SM-slot scheduling.

use super::device::DeviceConfig;

/// One warp's worth of work (a warp-group of rows, or a block phase).
#[derive(Clone, Copy, Debug, Default)]
pub struct WarpTask {
    /// Latency/compute cycles this warp occupies its slot.
    pub cycles: f64,
}

/// Static scheduling: tasks pre-chunked round-robin over slots (the CSR
/// and plain-2D model — no work stealing). Returns makespan cycles.
pub fn schedule_static(tasks: &[WarpTask], slots: usize) -> f64 {
    let slots = slots.max(1);
    let mut slot_time = vec![0.0f64; slots];
    for (i, t) in tasks.iter().enumerate() {
        slot_time[i % slots] += t.cycles;
    }
    slot_time.into_iter().fold(0.0, f64::max)
}

/// Dynamic/competitive scheduling: each task goes to the earliest-free
/// slot, in order — the behaviour of warps pulling tickets (§III-C).
/// `fixed_frac` of the tasks are first distributed statically (the fixed
/// part), the tail dynamically.
pub fn schedule_mixed(tasks: &[WarpTask], slots: usize, competitive_frac: f64) -> f64 {
    let slots = slots.max(1);
    let comp = ((tasks.len() as f64) * competitive_frac.clamp(0.0, 1.0)).round() as usize;
    let fixed_end = tasks.len() - comp.min(tasks.len());
    let mut slot_time = vec![0.0f64; slots];

    // fixed part: contiguous equal chunks (column-major adjacency)
    let base = fixed_end / slots;
    let rem = fixed_end % slots;
    let mut cursor = 0;
    for (w, st) in slot_time.iter_mut().enumerate() {
        let len = base + usize::from(w < rem);
        for t in &tasks[cursor..cursor + len] {
            *st += t.cycles;
        }
        cursor += len;
    }

    // competitive tail: earliest-free slot takes the next ticket
    for t in &tasks[fixed_end..] {
        let (idx, _) = slot_time
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        slot_time[idx] += t.cycles;
    }
    slot_time.into_iter().fold(0.0, f64::max)
}

/// Compute cycles for `rounds` FMA rounds.
pub fn compute_cycles(rounds: usize, dev: &DeviceConfig) -> f64 {
    rounds as f64 * dev.fma_cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tasks(cs: &[f64]) -> Vec<WarpTask> {
        cs.iter().map(|&c| WarpTask { cycles: c }).collect()
    }

    #[test]
    fn static_round_robin_makespan() {
        // slots=2: slot0 = 10+30 = 40, slot1 = 20+40 = 60
        let t = tasks(&[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(schedule_static(&t, 2), 60.0);
    }

    #[test]
    fn dynamic_beats_static_on_imbalance() {
        // one huge task + many small: static round-robin stacks smalls
        // behind the big one; dynamic routes around it
        let mut cs = vec![1000.0];
        cs.extend(std::iter::repeat_n(10.0, 99));
        let t = tasks(&cs);
        let stat = schedule_static(&t, 4);
        let dyn_ = schedule_mixed(&t, 4, 1.0);
        assert!(dyn_ < stat, "dynamic {dyn_} should beat static {stat}");
        assert!(dyn_ >= 1000.0); // can't beat the critical path
    }

    #[test]
    fn mixed_frac_zero_equals_chunked_static() {
        let t = tasks(&[5.0, 5.0, 5.0, 5.0, 5.0, 5.0]);
        // perfectly uniform: any schedule gives the same makespan
        assert_eq!(schedule_mixed(&t, 3, 0.0), 10.0);
        assert_eq!(schedule_mixed(&t, 3, 1.0), 10.0);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(schedule_static(&[], 4), 0.0);
        assert_eq!(schedule_mixed(&tasks(&[7.0]), 4, 0.5), 7.0);
    }
}

//! Fault-injection probes for the serving stack.
//!
//! The fault-tolerance layer (bounded admission, deadlines, panic
//! isolation — see `coordinator::batcher` and `coordinator::server`)
//! claims the service *degrades instead of dying*. This module makes
//! those claims testable: probes compiled into the dispatcher's flush
//! path fire only when a fault is **armed** for a specific matrix name,
//! so integration tests (and `hbp serve` via the `HBP_FAULTS` env var)
//! can stage a worker panic, a stalled flush, or an overload and assert
//! the structured degradation the protocol promises.
//!
//! Design constraints:
//!
//! - **Disarmed cost is one relaxed atomic load** per probe — the hot
//!   path pays nothing measurable for being testable.
//! - **Keyed by matrix name.** The registry is process-global (tests in
//!   one binary share it), so probes are scoped to the matrix they were
//!   armed for; tests arm uniquely-named matrices and cannot trip each
//!   other.
//! - **Panic probes are one-shot**: they disarm themselves when they
//!   fire, mirroring a transient fault — which is exactly what the
//!   "next request on the same matrix succeeds" recovery tests need.
//! - This module deliberately knows nothing about coordinator types
//!   (probes take `&str` matrix names), keeping the dependency
//!   direction `coordinator → sim` only.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// An injectable fault, armed per matrix name via [`arm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Panic on the dispatcher's execution path at the `nth` SpMV/SpMM
    /// probe against the matrix (1-based). One-shot: disarms on firing.
    PanicOnSpmv {
        /// Which probe hit fires the panic (1 = the next one).
        nth: u64,
    },
    /// Panic *inside a shared-pool worker* at the `nth` probe — drives
    /// the full containment chain: worker `catch_unwind` → generation
    /// re-raise on the submitter → batcher `catch_unwind` → typed
    /// `internal` reply. One-shot: disarms on firing.
    PanicInWorker {
        /// Which probe hit fires the panic (1 = the next one).
        nth: u64,
    },
    /// Sleep `millis` at each batch flush touching the matrix, upstream
    /// of the deadline check — lets tests fill the bounded queue or
    /// expire a deadline mid-queue deterministically. Stays armed until
    /// [`disarm`].
    SlowFlush {
        /// Sleep per flush, in milliseconds.
        millis: u64,
    },
}

struct Armed {
    fault: Fault,
    hits: u64,
}

/// Fast path: is *anything* armed at all? Keeps disarmed probes at one
/// relaxed load instead of a mutex acquisition.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> MutexGuard<'static, BTreeMap<String, Armed>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Armed>>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| Mutex::new(BTreeMap::new()))
        .lock()
        // a probe panicking on purpose must not wedge the registry
        .unwrap_or_else(|e| e.into_inner())
}

/// Arm `fault` for the matrix name (replacing any previous arming).
pub fn arm(matrix: &str, fault: Fault) {
    let mut reg = registry();
    reg.insert(matrix.to_string(), Armed { fault, hits: 0 });
    ANY_ARMED.store(true, Ordering::Relaxed);
}

/// Disarm whatever is armed for the matrix name (no-op if nothing is).
pub fn disarm(matrix: &str) {
    let mut reg = registry();
    reg.remove(matrix);
    if reg.is_empty() {
        ANY_ARMED.store(false, Ordering::Relaxed);
    }
}

/// Disarm everything (serve-loop hygiene, not used by tests — tests
/// disarm their own matrix names to stay isolated).
pub fn disarm_all() {
    let mut reg = registry();
    reg.clear();
    ANY_ARMED.store(false, Ordering::Relaxed);
}

/// Arm faults from the `HBP_FAULTS` env var (used by `hbp serve` so an
/// operator can rehearse degradation against a live server). Format:
/// comma-separated `kind=matrix:n` entries, e.g.
/// `panic_spmv=m1:3,slow_flush=m2:50,panic_worker=m1:1` — `n` is the
/// 1-based hit for the panic kinds and milliseconds for `slow_flush`.
/// Returns how many faults were armed; malformed entries are skipped.
pub fn arm_from_env() -> usize {
    match std::env::var("HBP_FAULTS") {
        Ok(spec) => {
            let faults = parse_faults(&spec);
            let n = faults.len();
            for (matrix, fault) in faults {
                arm(&matrix, fault);
            }
            n
        }
        Err(_) => 0,
    }
}

/// Parse an `HBP_FAULTS` spec (pure, testable part of [`arm_from_env`]).
pub fn parse_faults(spec: &str) -> Vec<(String, Fault)> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let Some((kind, rest)) = entry.split_once('=') else { continue };
        let Some((matrix, n)) = rest.rsplit_once(':') else { continue };
        let Ok(n) = n.parse::<u64>() else { continue };
        let fault = match kind.trim() {
            "panic_spmv" => Fault::PanicOnSpmv { nth: n.max(1) },
            "panic_worker" => Fault::PanicInWorker { nth: n.max(1) },
            "slow_flush" => Fault::SlowFlush { millis: n },
            _ => continue,
        };
        out.push((matrix.trim().to_string(), fault));
    }
    out
}

/// Execution-path probe, called by the batcher inside its
/// `catch_unwind` scope just before the engine runs. Counts hits for
/// the matrix; on the armed `nth` hit it panics (directly, or inside a
/// shared-pool worker for [`Fault::PanicInWorker`]), disarming itself
/// first so the matrix's next request demonstrates recovery.
pub fn spmv_probe(matrix: &str) {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return;
    }
    // decide + disarm under the lock, fire AFTER releasing it: the
    // intentional panic must not leave the registry lock poisoned
    let fire = {
        let mut reg = registry();
        let Some(armed) = reg.get_mut(matrix) else { return };
        match armed.fault {
            Fault::PanicOnSpmv { nth } | Fault::PanicInWorker { nth } => {
                armed.hits += 1;
                if armed.hits >= nth {
                    let fault = armed.fault;
                    reg.remove(matrix);
                    if reg.is_empty() {
                        ANY_ARMED.store(false, Ordering::Relaxed);
                    }
                    Some(fault)
                } else {
                    None
                }
            }
            Fault::SlowFlush { .. } => None,
        }
    };
    match fire {
        Some(Fault::PanicOnSpmv { .. }) => {
            panic!("fault injection: panic_spmv armed for {matrix:?}")
        }
        Some(Fault::PanicInWorker { .. }) => {
            // panic in worker 0; the pool contains it and the
            // generation re-raises on this (the submitting) thread
            crate::util::pool::shared_pool(2).run_generation(|w, _| {
                if w == 0 {
                    panic!("fault injection: panic_worker armed");
                }
            });
        }
        _ => {}
    }
}

/// Flush-path probe, called once per batch group before the deadline
/// check; sleeps while a [`Fault::SlowFlush`] is armed for the matrix.
pub fn slow_flush(matrix: &str) {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return;
    }
    let millis = {
        let reg = registry();
        match reg.get(matrix) {
            Some(Armed { fault: Fault::SlowFlush { millis }, .. }) => Some(*millis),
            _ => None,
        }
    };
    if let Some(ms) = millis {
        std::thread::sleep(Duration::from_millis(ms));
    }
}

/// Build a syntactically valid `spmv` request line padded with input
/// values until it is at least `min_len` bytes — the "oversized
/// request" client fault for exercising the server's line cap.
pub fn oversized_request(matrix: &str, min_len: usize) -> String {
    let mut s = format!("{{\"op\":\"spmv\",\"matrix\":{matrix:?},\"x\":[");
    while s.len() < min_len {
        s.push_str("0.0,");
    }
    s.push_str("0.0]}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_probes_are_noops() {
        // names no test ever arms
        spmv_probe("faults_never_armed");
        slow_flush("faults_never_armed");
    }

    #[test]
    fn panic_probe_fires_on_nth_hit_and_disarms() {
        arm("faults_nth", Fault::PanicOnSpmv { nth: 3 });
        spmv_probe("faults_nth"); // 1
        spmv_probe("faults_nth"); // 2
        let p = std::panic::catch_unwind(|| spmv_probe("faults_nth")); // 3: fires
        assert!(p.is_err(), "third probe must panic");
        // one-shot: the fault disarmed itself
        spmv_probe("faults_nth");
    }

    #[test]
    fn slow_flush_sleeps_only_while_armed() {
        arm("faults_slow", Fault::SlowFlush { millis: 30 });
        let t = std::time::Instant::now();
        slow_flush("faults_slow");
        assert!(t.elapsed() >= Duration::from_millis(25));
        disarm("faults_slow");
        let t = std::time::Instant::now();
        slow_flush("faults_slow");
        assert!(t.elapsed() < Duration::from_millis(25));
    }

    #[test]
    fn worker_panic_reraises_on_submitter() {
        arm("faults_worker", Fault::PanicInWorker { nth: 1 });
        let p = std::panic::catch_unwind(|| spmv_probe("faults_worker"));
        assert!(p.is_err(), "the pool re-raises the contained worker panic");
        // pool and registry both survive
        spmv_probe("faults_worker");
    }

    #[test]
    fn parses_env_spec() {
        let faults = parse_faults("panic_spmv=m1:3, slow_flush=m2:50,panic_worker=m1:1");
        assert_eq!(
            faults,
            vec![
                ("m1".to_string(), Fault::PanicOnSpmv { nth: 3 }),
                ("m2".to_string(), Fault::SlowFlush { millis: 50 }),
                ("m1".to_string(), Fault::PanicInWorker { nth: 1 }),
            ]
        );
        // malformed entries are skipped; the rightmost colon splits, so
        // matrix names containing colons still parse
        assert_eq!(parse_faults("bogus,panic_spmv=x,slow_flush=a:b:c"), Vec::new());
        assert_eq!(
            parse_faults("slow_flush=a:b:5"),
            vec![("a:b".to_string(), Fault::SlowFlush { millis: 5 })]
        );
        assert_eq!(parse_faults(""), Vec::new());
    }

    #[test]
    fn oversized_request_is_valid_json_of_requested_size() {
        let line = oversized_request("demo", 4096);
        assert!(line.len() >= 4096);
        let parsed = crate::util::json::Json::parse(&line).expect("stays valid JSON");
        assert_eq!(parsed.get("op").and_then(|v| v.as_str()), Some("spmv"));
    }
}

//! Simulation outputs: the quantities the paper reports.

use super::device::DeviceConfig;

/// Result of simulating one SpMV kernel on a device model.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimReport {
    /// SpMV-phase seconds.
    pub spmv_secs: f64,
    /// Combine-phase seconds (0 for CSR).
    pub combine_secs: f64,
    /// Modeled DRAM bytes moved.
    pub dram_bytes: f64,
    /// Matrix nonzeros (for GFLOPS).
    pub nnz: usize,
}

impl SimReport {
    pub fn total_secs(&self) -> f64 {
        self.spmv_secs + self.combine_secs
    }

    /// The paper's GFLOPS metric `2*nnz / t` over SpMV+combine.
    pub fn gflops(&self) -> f64 {
        crate::util::timer::spmv_gflops(self.nnz, self.total_secs())
    }

    /// Nsight-style "Mem Busy": fraction of kernel time DRAM was needed
    /// at peak bandwidth.
    pub fn mem_busy(&self, dev: &DeviceConfig) -> f64 {
        if self.total_secs() <= 0.0 {
            return 0.0;
        }
        let bw_time = self.dram_bytes / (dev.dram_bw_gbps * 1e9);
        (bw_time / self.total_secs()).min(1.0)
    }

    /// Nsight-style "Mem Throughput" in GB/s: achieved bytes over time.
    pub fn mem_throughput_gbps(&self) -> f64 {
        if self.total_secs() <= 0.0 {
            return 0.0;
        }
        self.dram_bytes / self.total_secs() / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_metrics() {
        let r = SimReport {
            spmv_secs: 1.0,
            combine_secs: 1.0,
            dram_bytes: 200e9,
            nnz: 1_000_000_000,
        };
        assert!((r.gflops() - 1.0).abs() < 1e-9);
        assert!((r.mem_throughput_gbps() - 100.0).abs() < 1e-9);
        let dev = DeviceConfig::orin(); // 204.8 GB/s
        let busy = r.mem_busy(&dev);
        assert!((busy - (200.0 / 204.8 / 2.0)).abs() < 1e-6);
    }

    #[test]
    fn zero_time_is_safe() {
        let r = SimReport::default();
        assert_eq!(r.mem_throughput_gbps(), 0.0);
        assert_eq!(r.mem_busy(&DeviceConfig::orin()), 0.0);
    }
}

//! The nonlinear hash function: Aggregation ∘ Dispersion ∘ Linear mapping.

/// Number of aggregation buckets. The paper fixes the aggregation range to
/// `0..=8` ("we artificially stipulate that the aggregation maps most
/// numbers of nonzero elements to within the range of 0 to 8"; rows
/// exceeding 8 are treated as 8).
pub const NUM_BUCKETS: usize = 9;

/// Parameters of the nonlinear hash.
///
/// Per the paper: `a` and `c` are *dynamic* — determined by sampling the
/// input block; `b` and `d` are *fixed* — derived from the row-partition
/// size before the program runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HashParams {
    /// Aggregation shift: rows with `nnz >> a` equal share a bucket.
    pub a: u32,
    /// Dispersion stride: bucket `k`'s region starts at `k * c`.
    pub c: usize,
    /// Linear-mapping multiplier (fixed, odd — a cheap bijective mixer).
    pub b: usize,
    /// Linear-mapping offset (fixed).
    pub d: usize,
    /// Table length (= rows in the block's hash table).
    pub table_len: usize,
}

impl HashParams {
    /// Fixed-parameter defaults for a given table length (`b`, `d` follow
    /// the row-partition size; `a`, `c` here are fallbacks that
    /// [`crate::hash::sampling::sample_params`] overrides per block).
    pub fn fixed_for(table_len: usize) -> HashParams {
        HashParams {
            a: 0,
            c: region_size(table_len),
            // odd full-width multiplier (golden-ratio hash): bijective on
            // u32 and entropy-rich in the top bits, which the
            // multiply-shift reduction in `linear` relies on
            b: 0x9E37_79B1,
            d: 0x85EB_CA6B,
            table_len: table_len.max(1),
        }
    }
}

/// Region size per bucket so that `NUM_BUCKETS` regions tile the table.
pub fn region_size(table_len: usize) -> usize {
    (table_len / NUM_BUCKETS).max(1)
}

/// The nonlinear hash function of Fig. 3.
#[derive(Clone, Copy, Debug)]
pub struct NonlinearHash {
    pub params: HashParams,
}

impl NonlinearHash {
    pub fn new(params: HashParams) -> Self {
        NonlinearHash { params }
    }

    /// **Aggregation**: nonlinear map of the row's nonzero count to a
    /// bucket in `0..NUM_BUCKETS`. Low-cost bit shift (Fig. 4): with
    /// `a = 2`, rows with nnz in `4k..4k+3` aggregate together. Extreme
    /// rows clamp to bucket 8 and are "treated as rows assigned to 8".
    #[inline]
    pub fn aggregate(&self, nnz: usize) -> usize {
        (nnz >> self.params.a).min(NUM_BUCKETS - 1)
    }

    /// **Dispersion**: spread bucket `k` to table region `[k*c, (k+1)*c)`.
    /// The mapping range never exceeds the current block's table.
    #[inline]
    pub fn disperse(&self, bucket: usize) -> usize {
        (bucket * self.params.c).min(self.params.table_len - 1)
    }

    /// **Linear mapping**: fine adjustment within the bucket region to
    /// spread distinct nnz values that aggregated together, lowering
    /// collision-probe cost. The paper notes the modulo "can also be
    /// replaced by other methods such as bit-shifting": we use the
    /// multiply-shift reduction `((b*nnz + d) * region) >> 32` — the
    /// same uniform fine placement without an integer division on the
    /// preprocessing hot path (§Perf, Fig. 7).
    #[inline]
    pub fn linear(&self, nnz: usize) -> usize {
        let region = region_size(self.params.table_len);
        let mixed = self.params.b.wrapping_mul(nnz).wrapping_add(self.params.d) as u32;
        ((mixed as u64 * region as u64) >> 32) as usize
    }

    /// Full hash: preferred slot for a row with `nnz` nonzeros.
    ///
    /// By construction `disperse(k) + linear(_) <= 9 * region <=
    /// table_len`, so no final reduction is needed.
    #[inline]
    pub fn slot(&self, nnz: usize) -> usize {
        let s = self.disperse(self.aggregate(nnz)) + self.linear(nnz);
        debug_assert!(s < self.params.table_len.max(1) || self.params.table_len == 0);
        s.min(self.params.table_len - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn h(table_len: usize, a: u32) -> NonlinearHash {
        let mut p = HashParams::fixed_for(table_len);
        p.a = a;
        NonlinearHash::new(p)
    }

    #[test]
    fn aggregation_groups_similar_lengths() {
        let h = h(512, 2);
        // a=2: nnz 4..=7 share bucket 1 (Fig. 4's 4k..4k+3 example)
        assert_eq!(h.aggregate(4), 1);
        assert_eq!(h.aggregate(7), 1);
        assert_ne!(h.aggregate(8), h.aggregate(7));
    }

    #[test]
    fn aggregation_clamps_extremes() {
        let h = h(512, 0);
        assert_eq!(h.aggregate(100_000), NUM_BUCKETS - 1);
        assert_eq!(h.aggregate(8), NUM_BUCKETS - 1);
    }

    #[test]
    fn dispersion_orders_buckets() {
        let h = h(512, 0);
        // smaller buckets land earlier: execution order favors light rows
        let mut prev = 0;
        for b in 0..NUM_BUCKETS {
            let s = h.disperse(b);
            assert!(s >= prev, "dispersion not monotone at bucket {b}");
            assert!(s < 512);
            prev = s;
        }
    }

    #[test]
    fn slot_in_range_always() {
        for table_len in [1usize, 2, 9, 31, 512, 513] {
            let hh = h(table_len, 1);
            for nnz in 0..2000 {
                let s = hh.slot(nnz);
                assert!(s < table_len, "slot {s} out of table {table_len}");
            }
        }
    }

    #[test]
    fn same_nnz_same_slot() {
        let h = h(512, 3);
        assert_eq!(h.slot(77), h.slot(77));
    }

    #[test]
    fn nearby_lengths_map_to_same_region() {
        let h = h(512, 3); // buckets of width 8
        let region = region_size(512);
        let s16 = h.slot(16) / region;
        let s17 = h.slot(17) / region;
        let s23 = h.slot(23) / region;
        assert_eq!(s16, s17);
        assert_eq!(s16, s23);
        // and a much longer row maps to a later region
        let s200 = h.slot(200) / region;
        assert!(s200 > s16);
    }

    #[test]
    fn linear_mapping_spreads_within_region() {
        let h = h(512, 3);
        // distinct nnz in the same bucket should rarely collide before probing
        let slots: std::collections::HashSet<usize> = (16..24).map(|n| h.slot(n)).collect();
        assert!(slots.len() >= 6, "linear mapping not spreading: {slots:?}");
    }
}

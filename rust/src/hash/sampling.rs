//! Runtime sampling of the dynamic hash parameters `a` and `c` (§III-B:
//! "a and c are dynamically determined based on the input matrix and
//! sampled during program execution").
//!
//! `a` is chosen so that ~90% of sampled rows aggregate inside the
//! `0..=8` bucket range ("we allowed the existence of a small number of
//! rows that exceed 8 after mapping"); `c` tiles the buckets across the
//! block's table. As blocks get denser `a` grows, widening each bucket —
//! which is exactly when the linear-mapping stage starts doing the fine
//! placement work.

use super::nonlinear::{HashParams, NUM_BUCKETS};

/// Maximum rows sampled per block: sampling is O(1), not O(rows).
pub const SAMPLE_CAP: usize = 64;

/// Derive per-block hash parameters from the block's row nonzero counts.
///
/// `row_nnz` are the per-row in-block counts; `table_len` the block's
/// table size (== number of row slots). Deterministic; the `seed`
/// parameter is kept for API stability (sampling uses a fixed stride,
/// which is both deterministic and allocation-light — this sits on the
/// preprocessing hot path measured by Fig. 7).
pub fn sample_params(row_nnz: &[usize], table_len: usize, seed: u64) -> HashParams {
    let _ = seed;
    let mut p = HashParams::fixed_for(table_len);
    if row_nnz.is_empty() {
        return p;
    }

    // strided sample of up to SAMPLE_CAP rows into a stack buffer
    let mut buf = [0usize; SAMPLE_CAP];
    let n = row_nnz.len();
    let count = n.min(SAMPLE_CAP);
    let stride = n / count;
    for (i, b) in buf[..count].iter_mut().enumerate() {
        *b = row_nnz[i * stride];
    }
    let sample = &mut buf[..count];

    // p90 of sampled lengths ("avoid the influence of extreme values");
    // selection, not a full sort — O(SAMPLE_CAP)
    let k = (count * 9 / 10).min(count - 1);
    sample.select_nth_unstable(k);
    let p90 = sample[k];

    // choose a so that p90 >> a <= 8, i.e. buckets cover the common range
    let mut a = 0u32;
    while (p90 >> a) >= NUM_BUCKETS {
        a += 1;
    }
    p.a = a;
    p.c = super::nonlinear::region_size(table_len);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::NonlinearHash;

    #[test]
    fn sparse_block_gets_small_a() {
        let lens = vec![1usize; 100];
        let p = sample_params(&lens, 128, 1);
        assert_eq!(p.a, 0);
    }

    #[test]
    fn dense_block_gets_larger_a() {
        let lens = vec![100usize; 100];
        let p = sample_params(&lens, 128, 1);
        // 100 >> a <= 8 -> a = 4
        assert_eq!(p.a, 4);
    }

    #[test]
    fn p90_ignores_extreme_tail() {
        // 95 short rows + 5 hubs: `a` should track the short rows
        let mut lens = vec![3usize; 95];
        lens.extend([50_000; 5]);
        let p = sample_params(&lens, 512, 7);
        assert!(p.a <= 1, "a={} pulled up by outliers", p.a);
        // hubs clamp into the top bucket
        let h = NonlinearHash::new(p);
        assert_eq!(h.aggregate(50_000), NUM_BUCKETS - 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let lens: Vec<usize> = (0..1000).map(|i| i % 37).collect();
        assert_eq!(sample_params(&lens, 512, 5), sample_params(&lens, 512, 5));
    }

    #[test]
    fn empty_block_ok() {
        let p = sample_params(&[], 512, 0);
        assert_eq!(p.table_len, 512);
    }

    #[test]
    fn most_rows_within_buckets() {
        // the sampling contract: >= ~90% of rows aggregate below the clamp
        let mut rng = crate::util::Rng::new(3);
        let lens: Vec<usize> = (0..2000).map(|_| rng.power_law(2.0, 400)).collect();
        let p = sample_params(&lens, 512, 11);
        let h = NonlinearHash::new(p);
        let clamped = lens.iter().filter(|&&l| (l >> p.a) >= NUM_BUCKETS).count();
        assert!(
            clamped * 100 / lens.len() <= 15,
            "{clamped}/{} rows clamp to the top bucket (a={})",
            lens.len(),
            p.a
        );
        // and the hash still separates the common lengths
        assert_ne!(h.slot(1), h.slot(lens.iter().copied().max().unwrap()));
    }
}

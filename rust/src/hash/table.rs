//! Per-block hash table with collision resolution.
//!
//! The table has exactly one slot per row of the block, so inserting all
//! rows yields a **permutation**: slot order = execution order,
//! `output_hash[slot] = original row`. A colliding row takes the *first
//! free slot at or after* its hashed slot (wrapping) — the same final
//! placement as linear probing, which keeps collided rows adjacent to
//! their bucket region and preserves the aggregation property the warp
//! grouping depends on.
//!
//! The free-slot search uses a union-find "next free pointer" with path
//! compression, so a block full of identical row lengths inserts in
//! near-O(R) instead of linear probing's O(R^2) — the "search strategies
//! after collisions" refinement the paper's Discussion section calls for
//! (ablation: `benches/ablation_hash_params.rs` reports probe counts).

use super::nonlinear::NonlinearHash;

/// Slot value marking an empty table entry.
const EMPTY: u32 = u32::MAX;

/// A per-block hash table mapping rows to execution slots.
#[derive(Clone, Debug)]
pub struct HashTable {
    slots: Vec<u32>,
    /// Union-find parent: free slots are self-parented roots; occupied
    /// slots point (transitively) to the next free slot at-or-after them.
    parent: Vec<u32>,
    len: usize,
    inserted: usize,
    /// Total parent-chain hops (collision-cost metric for ablations).
    pub probe_steps: usize,
}

impl HashTable {
    pub fn new(len: usize) -> Self {
        HashTable {
            slots: vec![EMPTY; len],
            parent: (0..len as u32).collect(),
            len,
            inserted: 0,
            probe_steps: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// First free slot reachable from `s` (free slots are self-parented).
    fn find(&mut self, s: usize) -> usize {
        let mut root = s;
        while self.parent[root] as usize != root {
            root = self.parent[root] as usize;
            self.probe_steps += 1;
        }
        // path compression
        let mut cur = s;
        while self.parent[cur] as usize != root {
            let next = self.parent[cur] as usize;
            self.parent[cur] = root as u32;
            cur = next;
        }
        root
    }

    /// Insert `row` (block-local index) with `nnz` nonzeros using hash
    /// `h`; returns the slot assigned. Panics if the table is full.
    pub fn insert(&mut self, h: &NonlinearHash, row: u32, nnz: usize) -> usize {
        assert!(self.inserted < self.len, "hash table full: {} rows inserted", self.len);
        let f = self.find(h.slot(nnz));
        debug_assert_eq!(self.slots[f], EMPTY);
        self.slots[f] = row;
        self.inserted += 1;
        if self.inserted < self.len {
            // point past this slot; wraps to 0 at the end of the table
            let next = (f + 1) % self.len;
            let next_root = self.find(next);
            self.parent[f] = next_root as u32;
        }
        f
    }

    /// Occupied fraction.
    pub fn occupancy(&self) -> f64 {
        self.inserted as f64 / self.len.max(1) as f64
    }

    /// Finish: return `output_hash` — slot-indexed original row ids.
    /// Every slot must be filled (insert all rows first); verified here.
    pub fn into_output_hash(self) -> Vec<u32> {
        debug_assert!(
            self.slots.iter().all(|&s| s != EMPTY),
            "hash table finalized with empty slots"
        );
        self.slots
    }

    /// Access the slot array before finalization (tests/metrics).
    pub fn slots(&self) -> &[u32] {
        &self.slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::{sample_params, NonlinearHash};
    use crate::util::Rng;

    fn hash_for(lens: &[usize], table: usize) -> NonlinearHash {
        NonlinearHash::new(sample_params(lens, table, 42))
    }

    #[test]
    fn all_rows_get_distinct_slots() {
        let lens: Vec<usize> = (0..128).map(|i| i % 11).collect();
        let h = hash_for(&lens, 128);
        let mut t = HashTable::new(128);
        for (r, &l) in lens.iter().enumerate() {
            t.insert(&h, r as u32, l);
        }
        assert!((t.occupancy() - 1.0).abs() < 1e-12);
        let out = t.into_output_hash();
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..128).collect::<Vec<u32>>());
    }

    #[test]
    fn matches_linear_probing_placement() {
        // reference: naive linear probing
        let mut rng = Rng::new(77);
        let lens: Vec<usize> = (0..256).map(|_| rng.power_law(2.0, 200)).collect();
        let h = hash_for(&lens, 256);
        let mut naive = vec![EMPTY; 256];
        for (r, &l) in lens.iter().enumerate() {
            let mut s = h.slot(l);
            while naive[s] != EMPTY {
                s = (s + 1) % 256;
            }
            naive[s] = r as u32;
        }
        let mut t = HashTable::new(256);
        for (r, &l) in lens.iter().enumerate() {
            t.insert(&h, r as u32, l);
        }
        assert_eq!(t.into_output_hash(), naive);
    }

    #[test]
    fn similar_rows_cluster() {
        // two populations: 100 short rows, 28 long rows
        let mut lens = vec![2usize; 100];
        lens.extend(vec![300usize; 28]);
        let h = hash_for(&lens, 128);
        let mut t = HashTable::new(128);
        let mut short_slots = vec![];
        let mut long_slots = vec![];
        for (r, &l) in lens.iter().enumerate() {
            let s = t.insert(&h, r as u32, l);
            if l == 2 {
                short_slots.push(s);
            } else {
                long_slots.push(s);
            }
        }
        let short_mean: f64 = short_slots.iter().sum::<usize>() as f64 / short_slots.len() as f64;
        let long_mean: f64 = long_slots.iter().sum::<usize>() as f64 / long_slots.len() as f64;
        assert!(
            long_mean > short_mean + 10.0,
            "long rows should land later: short {short_mean:.1} long {long_mean:.1}"
        );
    }

    #[test]
    #[should_panic(expected = "full")]
    fn overfull_table_panics() {
        let h = hash_for(&[1, 1, 1], 2);
        let mut t = HashTable::new(2);
        t.insert(&h, 0, 1);
        t.insert(&h, 1, 1);
        t.insert(&h, 2, 1);
    }

    #[test]
    fn identical_keys_insert_in_near_linear_time() {
        // the degenerate case that costs O(R^2) under plain linear probing
        let lens = vec![5usize; 4096];
        let h = hash_for(&lens, 4096);
        let mut t = HashTable::new(4096);
        for (r, &l) in lens.iter().enumerate() {
            t.insert(&h, r as u32, l);
        }
        assert!(
            t.probe_steps < 4096 * 8,
            "union-find probing should be near-linear: {} steps",
            t.probe_steps
        );
    }

    #[test]
    fn probe_steps_bounded_on_random_input() {
        let mut rng = Rng::new(9);
        let lens: Vec<usize> = (0..512).map(|_| rng.power_law(2.0, 256)).collect();
        let h = hash_for(&lens, 512);
        let mut t = HashTable::new(512);
        for (r, &l) in lens.iter().enumerate() {
            t.insert(&h, r as u32, l);
        }
        assert!(
            t.probe_steps < 512 * 8,
            "excessive probing: {} steps",
            t.probe_steps
        );
    }

    #[test]
    fn wrapping_across_table_end() {
        // force hashes near the end so placement must wrap to slot 0
        let lens = vec![1000usize; 4]; // all clamp to the top bucket
        let h = hash_for(&lens, 4);
        let mut t = HashTable::new(4);
        for (r, &l) in lens.iter().enumerate() {
            t.insert(&h, r as u32, l);
        }
        let out = t.into_output_hash();
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
    }
}

//! The paper's nonlinear hash (§III-B, Fig. 3).
//!
//! Input: the nonzero count of each row inside a 2D-partitioned block.
//! Output: the row's slot in a per-block hash table whose index order *is*
//! the execution order. Rows with similar nonzero counts land in nearby
//! slots, so the warp-sized groups formed by consecutive slots have
//! near-uniform per-lane work — the lightweight replacement for sorting /
//! dynamic-programming reordering.
//!
//! Three stages (Fig. 3):
//! 1. **Aggregation** — nonlinear bucketing `min(nnz >> a, 8)`; `a` is
//!    sampled from the input so that most rows land in buckets 0..=8.
//! 2. **Dispersion** — spread buckets across the table: `bucket * c`,
//!    where `c` is the bucket region size derived from the table length.
//! 3. **Linear mapping** — fine placement inside the region
//!    (`(b * nnz + d) mod region`) plus linear probing on collision.

pub mod nonlinear;
pub mod sampling;
pub mod table;

pub use nonlinear::{HashParams, NonlinearHash};
pub use sampling::sample_params;
pub use table::HashTable;

//! MatrixMarket (`.mtx`) reader/writer.
//!
//! The University of Florida Sparse Matrix Collection (the paper's test
//! set, Table I) distributes matrices in this format. The offline build
//! can't download them, so benches default to the synthetic suite in
//! [`crate::gen::suite`] — but users with local copies of ASIC_680k et al.
//! can pass them to the CLI and every experiment runs on the real thing.
//!
//! Supported: `matrix coordinate real|integer|pattern general|symmetric|
//! skew-symmetric`, `%` comments, 1-based indices. Dense (`array`) files
//! and complex fields are rejected with a clear error.

use crate::formats::Coo;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a MatrixMarket file into COO.
pub fn read_matrix_market(path: impl AsRef<Path>) -> Result<Coo> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    parse(BufReader::new(f))
}

/// Parse MatrixMarket text (for tests and in-memory use).
pub fn read_matrix_market_str(text: &str) -> Result<Coo> {
    parse(BufReader::new(text.as_bytes()))
}

fn parse<R: BufRead>(mut r: R) -> Result<Coo> {
    let mut header = String::new();
    r.read_line(&mut header).context("reading header")?;
    let h: Vec<String> = header
        .trim()
        .to_ascii_lowercase()
        .split_whitespace()
        .map(String::from)
        .collect();
    if h.len() < 5 || !h[0].starts_with("%%matrixmarket") {
        bail!("not a MatrixMarket file: {header:?}");
    }
    if h[1] != "matrix" {
        bail!("unsupported object {:?}", h[1]);
    }
    if h[2] != "coordinate" {
        bail!("only `coordinate` format supported, got {:?}", h[2]);
    }
    let field = match h[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => bail!("unsupported field {other:?} (complex not supported)"),
    };
    let symmetry = match h[4].as_str() {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => bail!("unsupported symmetry {other:?}"),
    };

    // size line: first non-comment, non-empty line
    let mut size_line = String::new();
    loop {
        size_line.clear();
        if r.read_line(&mut size_line)? == 0 {
            bail!("missing size line");
        }
        let t = size_line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break;
        }
    }
    let dims: Vec<usize> = size_line
        .trim()
        .split_whitespace()
        .map(|t| t.parse().with_context(|| format!("bad size token {t:?}")))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("size line must be `rows cols nnz`, got {size_line:?}");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::new(rows, cols);
    let mut seen = 0usize;
    let mut line = String::new();
    while seen < nnz {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            bail!("expected {nnz} entries, file ended after {seen}");
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("missing row")?.parse()?;
        let j: usize = it.next().context("missing col")?.parse()?;
        let v: f64 = match field {
            Field::Pattern => 1.0,
            _ => it.next().context("missing value")?.parse()?,
        };
        if i == 0 || j == 0 || i > rows || j > cols {
            bail!("entry ({i},{j}) out of range for {rows}x{cols} (1-based)");
        }
        coo.push(i - 1, j - 1, v);
        if symmetry != Symmetry::General && i != j {
            let mirrored = if symmetry == Symmetry::SkewSymmetric {
                -v
            } else {
                v
            };
            coo.push(j - 1, i - 1, mirrored);
        }
        seen += 1;
    }
    Ok(coo)
}

/// Write COO as `matrix coordinate real general` (1-based).
pub fn write_matrix_market(path: impl AsRef<Path>, m: &Coo) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by hbp-spmv")?;
    writeln!(f, "{} {} {}", m.rows, m.cols, m.nnz())?;
    for k in 0..m.nnz() {
        writeln!(f, "{} {} {:.17e}", m.row[k] + 1, m.col[k] + 1, m.data[k])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 2\n\
                    1 1 1.5\n\
                    3 2 -2.0\n";
        let coo = read_matrix_market_str(text).unwrap();
        assert_eq!(coo.rows, 3);
        assert_eq!(coo.nnz(), 2);
        let csr = coo.to_csr();
        assert_eq!(csr.get(0, 0), 1.5);
        assert_eq!(csr.get(2, 1), -2.0);
    }

    #[test]
    fn parses_symmetric_mirrors() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    3 3 3\n\
                    1 1 1.0\n\
                    2 1 5.0\n\
                    3 3 2.0\n";
        let csr = read_matrix_market_str(text).unwrap().to_csr();
        assert_eq!(csr.nnz(), 4); // diagonal not duplicated
        assert_eq!(csr.get(0, 1), 5.0);
        assert_eq!(csr.get(1, 0), 5.0);
    }

    #[test]
    fn parses_skew_symmetric() {
        let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n\
                    2 2 1\n\
                    2 1 3.0\n";
        let csr = read_matrix_market_str(text).unwrap().to_csr();
        assert_eq!(csr.get(1, 0), 3.0);
        assert_eq!(csr.get(0, 1), -3.0);
    }

    #[test]
    fn parses_pattern_as_ones() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    2 2 2\n\
                    1 2\n\
                    2 1\n";
        let csr = read_matrix_market_str(text).unwrap().to_csr();
        assert_eq!(csr.get(0, 1), 1.0);
        assert_eq!(csr.get(1, 0), 1.0);
    }

    #[test]
    fn rejects_array_format_and_bad_header() {
        let array = "%%MatrixMarket matrix array real general\n2 2\n1\n2\n3\n4\n";
        assert!(read_matrix_market_str(array).is_err());
        assert!(read_matrix_market_str("not a header\n1 1 0\n").is_err());
        let complex = "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 0\n";
        assert!(read_matrix_market_str(complex).is_err());
    }

    #[test]
    fn rejects_out_of_range() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_matrix_market_str(text).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_str(text).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut m = Coo::new(4, 3);
        m.push(0, 0, 1.25);
        m.push(3, 2, -7.5);
        m.push(1, 1, 0.125);
        let dir = std::env::temp_dir().join("hbp_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.mtx");
        write_matrix_market(&path, &m).unwrap();
        let back = read_matrix_market(&path).unwrap();
        assert_eq!(back.to_csr(), m.to_csr());
    }
}

//! Matrix I/O: MatrixMarket text files (the UF Sparse Matrix Collection's
//! distribution format) and a compact binary cache for fast bench reloads.

pub mod matrix_market;
pub mod binfmt;

pub use matrix_market::{read_matrix_market, read_matrix_market_str, write_matrix_market};
pub use binfmt::{read_bin, write_bin};

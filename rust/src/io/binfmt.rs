//! Compact binary CSR cache.
//!
//! Benches over the full-scale synthetic suite regenerate multi-million-nnz
//! matrices; caching them as little-endian binary CSR makes re-runs cheap.
//! Layout (all little-endian):
//!
//! ```text
//! magic  u64  = 0x4850_4253_504d_5631  ("HPBSPMV1")
//! rows   u64
//! cols   u64
//! nnz    u64
//! ptr    (rows+1) x u64
//! col    nnz x u32
//! data   nnz x f64
//! ```

use crate::formats::Csr;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: u64 = 0x4850_4253_504d_5631;

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Write CSR to the binary cache format.
pub fn write_bin(path: impl AsRef<Path>, m: &Csr) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path.as_ref())?);
    write_u64(&mut w, MAGIC)?;
    write_u64(&mut w, m.rows as u64)?;
    write_u64(&mut w, m.cols as u64)?;
    write_u64(&mut w, m.nnz() as u64)?;
    for &p in &m.ptr {
        write_u64(&mut w, p as u64)?;
    }
    for &c in &m.col {
        w.write_all(&c.to_le_bytes())?;
    }
    for &d in &m.data {
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

/// Read CSR from the binary cache format (validates invariants).
pub fn read_bin(path: impl AsRef<Path>) -> Result<Csr> {
    let mut r = BufReader::new(
        std::fs::File::open(path.as_ref()).with_context(|| format!("opening {:?}", path.as_ref()))?,
    );
    if read_u64(&mut r)? != MAGIC {
        bail!("bad magic in {:?}", path.as_ref());
    }
    let rows = read_u64(&mut r)? as usize;
    let cols = read_u64(&mut r)? as usize;
    let nnz = read_u64(&mut r)? as usize;

    let mut ptr = Vec::with_capacity(rows + 1);
    for _ in 0..=rows {
        ptr.push(read_u64(&mut r)? as usize);
    }
    let mut colbuf = vec![0u8; nnz * 4];
    r.read_exact(&mut colbuf)?;
    let col: Vec<u32> = colbuf
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut databuf = vec![0u8; nnz * 8];
    r.read_exact(&mut databuf)?;
    let data: Vec<f64> = databuf
        .chunks_exact(8)
        .map(|b| f64::from_le_bytes(b.try_into().unwrap()))
        .collect();

    let m = Csr { rows, cols, ptr, col, data };
    m.validate().context("binary CSR failed validation")?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;

    #[test]
    fn roundtrip() {
        let mut coo = Coo::new(5, 7);
        coo.push(0, 6, 1.0);
        coo.push(4, 0, -2.5);
        coo.push(2, 3, 1e-17);
        let m = coo.to_csr();
        let dir = std::env::temp_dir().join("hbp_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bin");
        write_bin(&path, &m).unwrap();
        let back = read_bin(&path).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("hbp_bin_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"garbagegarbagegarbage_____________").unwrap();
        assert!(read_bin(&path).is_err());
    }

    #[test]
    fn missing_file_is_error() {
        assert!(read_bin("/nonexistent/x.bin").is_err());
    }
}

//! Diagonal (DIA) format — the paper's introduction cites it as the
//! format that wins on banded/diagonal matrices (our barrier2-3 / ohne2
//! FEM generators produce exactly that structure). Kept as a baseline and
//! to sanity-check the banded generators.

use super::{Csr, MatrixInfo};

/// DIA sparse matrix: a set of stored diagonals.
#[derive(Clone, Debug, PartialEq)]
pub struct Dia {
    pub rows: usize,
    pub cols: usize,
    /// Offsets of stored diagonals (0 = main, +k upper, -k lower), sorted.
    pub offsets: Vec<i64>,
    /// `offsets.len() x rows` values, diagonal-major; entry `(d, r)` is
    /// `A[r, r + offsets[d]]` (0 where out of range).
    pub data: Vec<f64>,
    pub nnz: usize,
}

impl Dia {
    /// Build from CSR. Returns `None` when the matrix needs more than
    /// `max_diags` distinct diagonals (DIA would blow up storage).
    pub fn from_csr(m: &Csr, max_diags: usize) -> Option<Self> {
        let mut present = std::collections::BTreeSet::new();
        for r in 0..m.rows {
            let (cols, _) = m.row(r);
            for &c in cols {
                present.insert(c as i64 - r as i64);
                if present.len() > max_diags {
                    return None;
                }
            }
        }
        let offsets: Vec<i64> = present.into_iter().collect();
        let index_of: std::collections::HashMap<i64, usize> =
            offsets.iter().enumerate().map(|(i, &o)| (o, i)).collect();
        let mut data = vec![0.0; offsets.len() * m.rows];
        for r in 0..m.rows {
            let (cols, vals) = m.row(r);
            for (c, v) in cols.iter().zip(vals) {
                let d = index_of[&(*c as i64 - r as i64)];
                data[d * m.rows + r] = *v;
            }
        }
        Some(Dia { rows: m.rows, cols: m.cols, offsets, data, nnz: m.nnz() })
    }

    pub fn info(&self) -> MatrixInfo {
        MatrixInfo { rows: self.rows, cols: self.cols, nnz: self.nnz }
    }

    pub fn num_diags(&self) -> usize {
        self.offsets.len()
    }

    /// Serial DIA SpMV.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.fill(0.0);
        for (d, &off) in self.offsets.iter().enumerate() {
            let band = &self.data[d * self.rows..(d + 1) * self.rows];
            for r in 0..self.rows {
                let c = r as i64 + off;
                if c >= 0 && (c as usize) < self.cols {
                    y[r] += band[r] * x[c as usize];
                }
            }
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.offsets.len() * 8 + self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;

    fn tridiag(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 2.0);
            if i > 0 {
                coo.push(i, i - 1, -1.0);
            }
            if i + 1 < n {
                coo.push(i, i + 1, -1.0);
            }
        }
        coo.to_csr()
    }

    #[test]
    fn tridiagonal_has_three_diags() {
        let d = Dia::from_csr(&tridiag(5), 10).unwrap();
        assert_eq!(d.offsets, vec![-1, 0, 1]);
        assert_eq!(d.num_diags(), 3);
    }

    #[test]
    fn spmv_matches_csr() {
        let m = tridiag(7);
        let d = Dia::from_csr(&m, 10).unwrap();
        let x: Vec<f64> = (0..7).map(|i| i as f64 + 1.0).collect();
        let mut yc = vec![0.0; 7];
        let mut yd = vec![0.0; 7];
        m.spmv(&x, &mut yc);
        d.spmv(&x, &mut yd);
        assert_eq!(yc, yd);
    }

    #[test]
    fn refuses_too_many_diagonals() {
        // anti-diagonal-ish scatter needs n distinct diagonals
        let mut coo = Coo::new(6, 6);
        for i in 0..6 {
            coo.push(i, 5 - i, 1.0);
        }
        let m = coo.to_csr();
        assert!(Dia::from_csr(&m, 3).is_none());
        assert!(Dia::from_csr(&m, 6).is_some());
    }

    #[test]
    fn rectangular_matrix() {
        let mut coo = Coo::new(3, 5);
        coo.push(0, 2, 1.0);
        coo.push(2, 4, 2.0);
        let m = coo.to_csr();
        let d = Dia::from_csr(&m, 4).unwrap();
        let x = [1.0, 1.0, 3.0, 1.0, 5.0];
        let mut yc = vec![0.0; 3];
        let mut yd = vec![0.0; 3];
        m.spmv(&x, &mut yc);
        d.spmv(&x, &mut yd);
        assert_eq!(yc, yd);
    }
}

//! Dense row-major matrix — the correctness oracle for every SpMV engine.

/// Dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Dense {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Dense matrix-vector product: the ground truth all sparse engines
    /// are checked against.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            y[r] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
}

/// Max |a-b| over two vectors — used in engine equivalence checks.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Relative closeness check with tolerance scaled to magnitude; SpMV sums
/// differ by association order across engines, so exact equality is wrong.
pub fn allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs().max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spmv_identity() {
        let mut m = Dense::zeros(3, 3);
        for i in 0..3 {
            m.set(i, i, 1.0);
        }
        let y = m.spmv(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn nnz_counts_nonzero() {
        let mut m = Dense::zeros(2, 2);
        m.set(0, 1, 5.0);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn allclose_tolerances() {
        assert!(allclose(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, 1e-9));
        assert!(!allclose(&[1.0], &[1.1], 1e-9, 1e-9));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-9, 1e-9));
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}

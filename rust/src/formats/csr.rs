//! Compressed Sparse Row (CSR): the paper's primary baseline format
//! (Algorithm 1) and the canonical input to HBP preprocessing.

use super::{Coo, Dense, MatrixInfo};

/// CSR sparse matrix.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// `ptr[i]..ptr[i+1]` is the index range of row `i`; `len == rows+1`.
    pub ptr: Vec<usize>,
    pub col: Vec<u32>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Empty matrix of the given shape.
    pub fn empty(rows: usize, cols: usize) -> Self {
        Csr { rows, cols, ptr: vec![0; rows + 1], col: vec![], data: vec![] }
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn info(&self) -> MatrixInfo {
        MatrixInfo { rows: self.rows, cols: self.cols, nnz: self.nnz() }
    }

    /// Number of nonzeros in row `i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.ptr[i + 1] - self.ptr[i]
    }

    /// (columns, values) of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let r = self.ptr[i]..self.ptr[i + 1];
        (&self.col[r.clone()], &self.data[r])
    }

    /// Value at `(r, c)` or 0.0 — O(log nnz_row); test/debug helper.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&(c as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Validate structural invariants (monotone ptr, sorted in-range
    /// columns). Used by property tests and after deserialization.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ptr.len() == self.rows + 1, "ptr length");
        anyhow::ensure!(*self.ptr.last().unwrap() == self.nnz(), "ptr end != nnz");
        anyhow::ensure!(self.col.len() == self.data.len(), "col/data length");
        for i in 0..self.rows {
            anyhow::ensure!(self.ptr[i] <= self.ptr[i + 1], "ptr not monotone at {i}");
            anyhow::ensure!(self.ptr[i + 1] <= self.nnz(), "ptr[{}] out of bounds", i + 1);
            let (cols, _) = self.row(i);
            for w in cols.windows(2) {
                anyhow::ensure!(w[0] < w[1], "row {i} columns not strictly sorted");
            }
            if let Some(&c) = cols.last() {
                anyhow::ensure!((c as usize) < self.cols, "row {i} column {c} out of range");
            }
        }
        Ok(())
    }

    /// Serial CSR SpMV (the paper's Algorithm 1). The parallel versions
    /// live in [`crate::exec::csr`].
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut sum = 0.0;
            for (c, v) in cols.iter().zip(vals) {
                sum += v * x[*c as usize];
            }
            y[i] = sum;
        }
    }

    /// Per-row nonzero counts (input to the nonlinear hash).
    pub fn row_lengths(&self) -> Vec<usize> {
        (0..self.rows).map(|i| self.row_nnz(i)).collect()
    }

    /// Transpose (CSR -> CSR of the transpose) — used by symmetric checks.
    pub fn transpose(&self) -> Csr {
        let mut ptr = vec![0usize; self.cols + 1];
        for &c in &self.col {
            ptr[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            ptr[i + 1] += ptr[i];
        }
        let mut col = vec![0u32; self.nnz()];
        let mut data = vec![0f64; self.nnz()];
        let mut cursor = ptr.clone();
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                let k = cursor[*c as usize];
                col[k] = r as u32;
                data[k] = *v;
                cursor[*c as usize] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, ptr, col, data }
    }

    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c as usize, *v);
            }
        }
        coo
    }

    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals) {
                d.set(r, *c as usize, *v);
            }
        }
        d
    }

    /// Approximate in-memory footprint in bytes (storage-cost tables).
    pub fn storage_bytes(&self) -> usize {
        self.ptr.len() * std::mem::size_of::<usize>()
            + self.col.len() * 4
            + self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [1 0 2]
        // [0 0 0]
        // [3 4 0]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(2, 0, 3.0);
        coo.push(2, 1, 4.0);
        coo.to_csr()
    }

    #[test]
    fn row_access() {
        let m = sample();
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
        let (cols, vals) = m.row(2);
        assert_eq!(cols, &[0, 1]);
        assert_eq!(vals, &[3.0, 4.0]);
    }

    #[test]
    fn spmv_matches_manual() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 0.0, 11.0]);
    }

    #[test]
    fn validate_ok_and_detects_bad() {
        let m = sample();
        m.validate().unwrap();
        let mut bad = m.clone();
        bad.col[0] = 99;
        assert!(bad.validate().is_err());
        let mut bad2 = m.clone();
        bad2.ptr[1] = 5;
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn transpose_values() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.get(0, 0), 1.0);
        assert_eq!(t.get(2, 0), 2.0);
        assert_eq!(t.get(1, 2), 4.0);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(d.get(2, 1), 4.0);
        assert_eq!(d.get(1, 1), 0.0);
        let back = m.to_coo().to_csr();
        assert_eq!(m, back);
    }

    #[test]
    fn row_lengths_match() {
        let m = sample();
        assert_eq!(m.row_lengths(), vec![2, 0, 2]);
    }
}

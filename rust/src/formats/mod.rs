//! Sparse-matrix storage formats.
//!
//! The paper's baseline universe: COO (interchange), CSR (the main SpMV
//! baseline, Algorithm 1), ELL and DIA (classic formats discussed in the
//! introduction), plus a dense matrix used as the test oracle. The paper's
//! own HBP format lives in [`crate::preprocess`] because its construction
//! *is* the preprocessing step being benchmarked.
//!
//! Conventions: `u32` column/row indices, `f64` values (the paper stores
//! doubles — its shared-memory sizing argument in §III-A assumes 8-byte
//! elements).

pub mod coo;
pub mod csr;
pub mod ell;
pub mod dia;
pub mod dense;

pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use dia::Dia;
pub use ell::Ell;

/// Shape + nnz summary shared by all formats.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatrixInfo {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
}

impl MatrixInfo {
    /// Density in `[0,1]`.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz as f64 / (self.rows as f64 * self.cols as f64)
        }
    }
}

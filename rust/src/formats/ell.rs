//! ELLPACK (ELL) format: fixed row width with zero padding. Discussed in
//! the paper's introduction as the classic format that wins when row
//! lengths are uniform — and whose padding blow-up on skewed matrices is
//! exactly what HBP's hash grouping avoids. We keep it both as a baseline
//! and to *measure* that padding blow-up (storage ablation).

use super::{Csr, MatrixInfo};

/// ELL sparse matrix: `rows x width` slots, column-index `u32::MAX`
/// marking padding.
#[derive(Clone, Debug, PartialEq)]
pub struct Ell {
    pub rows: usize,
    pub cols: usize,
    pub width: usize,
    /// Row-major `rows * width` column indices (`PAD` = padding slot).
    pub col: Vec<u32>,
    pub data: Vec<f64>,
    pub nnz: usize,
}

impl Ell {
    pub const PAD: u32 = u32::MAX;

    /// Build from CSR; width = max row length.
    pub fn from_csr(m: &Csr) -> Self {
        let width = (0..m.rows).map(|i| m.row_nnz(i)).max().unwrap_or(0);
        let mut col = vec![Self::PAD; m.rows * width];
        let mut data = vec![0.0; m.rows * width];
        for r in 0..m.rows {
            let (cols, vals) = m.row(r);
            for (k, (c, v)) in cols.iter().zip(vals).enumerate() {
                col[r * width + k] = *c;
                data[r * width + k] = *v;
            }
        }
        Ell { rows: m.rows, cols: m.cols, width, col, data, nnz: m.nnz() }
    }

    pub fn info(&self) -> MatrixInfo {
        MatrixInfo { rows: self.rows, cols: self.cols, nnz: self.nnz }
    }

    /// Fraction of slots that are padding — the storage-efficiency metric
    /// HBP's grouping is designed to keep low per group.
    pub fn padding_ratio(&self) -> f64 {
        let slots = self.rows * self.width;
        if slots == 0 {
            0.0
        } else {
            1.0 - self.nnz as f64 / slots as f64
        }
    }

    /// Serial ELL SpMV.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let mut sum = 0.0;
            for k in 0..self.width {
                let c = self.col[r * self.width + k];
                if c != Self::PAD {
                    sum += self.data[r * self.width + k] * x[c as usize];
                }
            }
            y[r] = sum;
        }
    }

    pub fn storage_bytes(&self) -> usize {
        self.col.len() * 4 + self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Coo;

    fn sample() -> Csr {
        let mut coo = Coo::new(3, 4);
        coo.push(0, 0, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(1, 1, 3.0);
        coo.push(2, 0, 4.0);
        coo.push(2, 2, 5.0);
        coo.push(2, 3, 6.0);
        coo.to_csr()
    }

    #[test]
    fn width_is_max_row_len() {
        let e = Ell::from_csr(&sample());
        assert_eq!(e.width, 3);
        assert_eq!(e.nnz, 6);
    }

    #[test]
    fn padding_ratio_counts_empty_slots() {
        let e = Ell::from_csr(&sample());
        // 3 rows * width 3 = 9 slots, 6 filled
        assert!((e.padding_ratio() - 3.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn spmv_matches_csr() {
        let m = sample();
        let e = Ell::from_csr(&m);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut yc = [0.0; 3];
        let mut ye = [0.0; 3];
        m.spmv(&x, &mut yc);
        e.spmv(&x, &mut ye);
        assert_eq!(yc, ye);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::empty(2, 2);
        let e = Ell::from_csr(&m);
        assert_eq!(e.width, 0);
        let mut y = [9.0, 9.0];
        e.spmv(&[0.0, 0.0], &mut y);
        assert_eq!(y, [0.0, 0.0]);
    }
}

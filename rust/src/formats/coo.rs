//! Coordinate (COO) format: the interchange representation every
//! generator and parser produces first.

use super::{Csr, MatrixInfo};

/// Coordinate-format sparse matrix (struct-of-arrays).
///
/// Entries may be unsorted and may contain duplicates until
/// [`Coo::normalize`] is called; conversion to CSR normalizes implicitly.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row: Vec<u32>,
    pub col: Vec<u32>,
    pub data: Vec<f64>,
}

impl Coo {
    /// Empty matrix of the given shape.
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, row: vec![], col: vec![], data: vec![] }
    }

    /// Construct from parallel arrays. Panics on length mismatch or
    /// out-of-range indices (debug).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        row: Vec<u32>,
        col: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        assert_eq!(row.len(), col.len());
        assert_eq!(row.len(), data.len());
        debug_assert!(row.iter().all(|&r| (r as usize) < rows));
        debug_assert!(col.iter().all(|&c| (c as usize) < cols));
        Coo { rows, cols, row, col, data }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(
            r < self.rows && c < self.cols,
            "({r},{c}) out of {}x{}",
            self.rows,
            self.cols
        );
        self.row.push(r as u32);
        self.col.push(c as u32);
        self.data.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn info(&self) -> MatrixInfo {
        MatrixInfo { rows: self.rows, cols: self.cols, nnz: self.nnz() }
    }

    /// Sort entries row-major and sum duplicates. Zero-valued entries are
    /// kept (UF matrices contain explicit zeros; the paper counts them as
    /// stored nonzeros).
    pub fn normalize(&mut self) {
        let n = self.nnz();
        let mut idx: Vec<usize> = (0..n).collect();
        // tie-break on the original index: duplicate entries sum in
        // insertion order, so mirrored entries (symmetrize) sum in the
        // same order on both sides of the diagonal -> bitwise symmetry
        idx.sort_unstable_by_key(|&i| (self.row[i], self.col[i], i));
        let mut row = Vec::with_capacity(n);
        let mut col = Vec::with_capacity(n);
        let mut data = Vec::with_capacity(n);
        for i in idx {
            if let (Some(&lr), Some(&lc)) = (row.last(), col.last()) {
                if lr == self.row[i] && lc == self.col[i] {
                    *data.last_mut().unwrap() += self.data[i];
                    continue;
                }
            }
            row.push(self.row[i]);
            col.push(self.col[i]);
            data.push(self.data[i]);
        }
        self.row = row;
        self.col = col;
        self.data = data;
    }

    /// Mirror entries across the diagonal (for `%%MatrixMarket ...
    /// symmetric` files and the paper's symmetric kron_g500 matrices).
    /// Diagonal entries are not duplicated. Normalizes first so each cell
    /// ends up with at most two addends — commutativity of IEEE addition
    /// then guarantees *bitwise* symmetry of the result.
    pub fn symmetrize(&mut self) {
        self.normalize();
        let n = self.nnz();
        for i in 0..n {
            if self.row[i] != self.col[i] {
                self.row.push(self.col[i]);
                self.col.push(self.row[i]);
                self.data.push(self.data[i]);
            }
        }
    }

    /// Convert to CSR (normalizes first).
    pub fn to_csr(&self) -> Csr {
        let mut c = self.clone();
        c.normalize();
        let mut ptr = vec![0usize; c.rows + 1];
        for &r in &c.row {
            ptr[r as usize + 1] += 1;
        }
        for i in 0..c.rows {
            ptr[i + 1] += ptr[i];
        }
        Csr { rows: c.rows, cols: c.cols, ptr, col: c.col, data: c.data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_info() {
        let mut m = Coo::new(3, 4);
        m.push(0, 0, 1.0);
        m.push(2, 3, 2.0);
        let info = m.info();
        assert_eq!(info, MatrixInfo { rows: 3, cols: 4, nnz: 2 });
        assert!((info.density() - 2.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_sorts_and_sums_duplicates() {
        let mut m = Coo::new(2, 2);
        m.push(1, 1, 1.0);
        m.push(0, 1, 2.0);
        m.push(1, 1, 3.0);
        m.normalize();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.row, vec![0, 1]);
        assert_eq!(m.col, vec![1, 1]);
        assert_eq!(m.data, vec![2.0, 4.0]);
    }

    #[test]
    fn symmetrize_mirrors_off_diagonal() {
        let mut m = Coo::new(3, 3);
        m.push(0, 1, 5.0);
        m.push(1, 1, 7.0);
        m.symmetrize();
        m.normalize();
        assert_eq!(m.nnz(), 3);
        let csr = m.to_csr();
        assert_eq!(csr.get(1, 0), 5.0);
        assert_eq!(csr.get(0, 1), 5.0);
        assert_eq!(csr.get(1, 1), 7.0);
    }

    #[test]
    fn to_csr_roundtrip_values() {
        let mut m = Coo::new(3, 3);
        m.push(2, 0, 9.0);
        m.push(0, 2, 3.0);
        m.push(1, 1, 4.0);
        let csr = m.to_csr();
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.get(2, 0), 9.0);
        assert_eq!(csr.get(0, 2), 3.0);
        assert_eq!(csr.get(1, 1), 4.0);
        assert_eq!(csr.get(0, 0), 0.0);
    }

    #[test]
    fn empty_matrix_converts() {
        let m = Coo::new(4, 4);
        let csr = m.to_csr();
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.ptr, vec![0; 5]);
    }
}
